//! §Perf — batched evaluation throughput: worker scaling of
//! [`ParallelSim`] over the `SurrogateSim` sweep, memo-cache hit
//! throughput, end-to-end batched `joint_search`, and the parallel
//! service clients. The headline number is the 8-worker speedup over
//! the serial evaluator on one fixed 512-sample batch (target: >= 2x
//! on a machine with >= 4 cores; see ISSUE acceptance).

use std::time::Instant;

use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::{
    joint_search, Evaluator, ParallelSim, RewardCfg, SearchCfg, SurrogateSim,
};
use nahas::service::{Server, ServiceEvaluator};
use nahas::util::Rng;

const BATCH: usize = 512;

fn s2() -> NasSpace {
    NasSpace::new(NasSpaceId::EfficientNet)
}

fn fixed_batch() -> Vec<(Vec<usize>, Vec<usize>)> {
    let space = s2();
    let has = HasSpace::new();
    let mut rng = Rng::new(3);
    (0..BATCH).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect()
}

fn time_batch(ev: &mut dyn Evaluator, batch: &[(Vec<usize>, Vec<usize>)]) -> (f64, usize) {
    let t0 = Instant::now();
    let results = ev.evaluate_batch(batch);
    let dt = t0.elapsed().as_secs_f64();
    (batch.len() as f64 / dt, results.iter().filter(|r| r.valid).count())
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("batched evaluation sweep: {BATCH} samples, {cores} cores available\n");
    let batch = fixed_batch();

    // Serial baseline (the trait's default evaluate_batch loop).
    let mut serial = SurrogateSim::new(s2(), 3);
    let (serial_tput, serial_valid) = time_batch(&mut serial, &batch);
    println!("  SurrogateSim serial      {serial_tput:>8.0} samples/s  (1.00x)");

    // Worker scaling (fresh evaluator per row: cold cache each time).
    for workers in [2usize, 4, 8] {
        let mut par = ParallelSim::new(s2(), 3, workers);
        let (tput, valid) = time_batch(&mut par, &batch);
        assert_eq!(valid, serial_valid, "parallel result set diverged from serial");
        println!(
            "  ParallelSim workers={workers}    {tput:>8.0} samples/s  ({:.2}x)",
            tput / serial_tput
        );
        if workers == 8 && cores >= 4 && tput / serial_tput < 2.0 {
            println!("    !! expected >= 2x at 8 workers on a >= 4-core machine");
        }
    }

    // Memo-cache throughput: replay the identical batch on a warm cache.
    let mut warm = ParallelSim::new(s2(), 3, 8);
    let _ = warm.evaluate_batch(&batch);
    let (hit_tput, _) = time_batch(&mut warm, &batch);
    let st = warm.stats();
    println!(
        "  memo-cache replay        {hit_tput:>8.0} samples/s  ({:.2}x, {} hits / {} reqs)\n",
        hit_tput / serial_tput,
        st.cache_hits,
        st.requests
    );

    // End-to-end: the batch-structured joint_search driver, serial vs
    // 8 workers (PPO resamples as it converges, so the cache also
    // contributes here).
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(&s2(), &has);
    let cfg = SearchCfg::new(600, RewardCfg::latency(0.4), 7);

    let mut ev = SurrogateSim::new(s2(), 7);
    let mut ctl = PpoController::new(&cards);
    let out = joint_search(&mut ev, &mut ctl, &layout, None, None, &cfg);
    let base = out.samples_per_s();
    println!("  joint_search serial      {base:>8.0} samples/s  (1.00x)");

    let mut ev = ParallelSim::new(s2(), 7, 8);
    let mut ctl = PpoController::new(&cards);
    let out = joint_search(&mut ev, &mut ctl, &layout, None, None, &cfg);
    println!(
        "  joint_search workers=8   {:>8.0} samples/s  ({:.2}x, {:.0}% cache hits)\n",
        out.samples_per_s(),
        out.samples_per_s() / base,
        out.eval_stats.hit_rate() * 100.0
    );

    // Parallel service clients (paper §4.1) against an in-process server.
    let server = Server::spawn("127.0.0.1:0").expect("spawn simulator service");
    for workers in [1usize, 8] {
        let mut remote =
            ServiceEvaluator::connect(&server.addr.to_string(), NasSpaceId::EfficientNet, 3, workers)
                .expect("connect service clients");
        let (tput, valid) = time_batch(&mut remote, &batch);
        assert_eq!(valid, serial_valid, "service result set diverged from local");
        println!("  ServiceEvaluator x{workers:<2}      {tput:>8.0} samples/s");
    }
    server.stop();
}
