//! Ablation — hard (p=0, q=-1) vs soft (p=q=-0.07) constraint reward
//! (paper §3.4 defines both; §4.5 uses soft for the HAS phase and hard
//! for the NAS phase). Measures feasibility rate, best feasible
//! accuracy and boundary-tracking behaviour at equal budgets.

use nahas::bench::Table;
use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::{joint_search, ConstraintMode, RewardCfg, SearchCfg, SurrogateSim};

fn main() {
    let mut table = Table::new(&[
        "Reward",
        "Seed",
        "Feasible rate(%)",
        "Best feasible top-1(%)",
        "Tail mean latency(ms)",
    ]);
    let t_ms = 0.5;
    for mode in [ConstraintMode::Hard, ConstraintMode::Soft] {
        for seed in [1u64, 2, 3] {
            let space = NasSpace::new(NasSpaceId::EfficientNet);
            let has = HasSpace::new();
            let (cards, layout) = JointLayout::cards(&space, &has);
            let mut ev = SurrogateSim::new(space, seed);
            let mut ctl = PpoController::new(&cards);
            let mut reward = RewardCfg::latency(t_ms);
            if mode == ConstraintMode::Soft {
                reward = reward.soft();
            }
            let cfg = SearchCfg::new(1500, reward, seed);
            let out = joint_search(&mut ev, &mut ctl, &layout, None, None, &cfg);
            let feasible =
                out.history.iter().filter(|s| cfg.reward.feasible(&s.result)).count();
            let tail: Vec<_> =
                out.history.iter().rev().take(300).filter(|s| s.result.valid).collect();
            let tail_lat =
                tail.iter().map(|s| s.result.latency_ms).sum::<f64>() / tail.len().max(1) as f64;
            table.row(vec![
                format!("{mode:?}"),
                format!("{seed}"),
                format!("{:.1}", 100.0 * feasible as f64 / out.history.len() as f64),
                out.best_feasible
                    .map(|b| format!("{:.2}", b.result.acc * 100.0))
                    .unwrap_or_else(|| "-".into()),
                format!("{tail_lat:.3}"),
            ]);
        }
    }
    println!("Ablation — hard vs soft constraint reward (1500 samples, target {t_ms} ms):");
    table.print();
    println!(
        "\nexpected: hard concentrates samples under the target (higher feasible rate); \
         soft trades feasibility for exploring the latency boundary"
    );
}
