//! §Perf — broker admission overlap: the same multi-scenario sweep run
//! with strictly serial admission (`--broker-inflight 1`, the pre-PR-5
//! dispatch path) and with full overlap (limit = the parallel
//! backend's worker capacity).
//!
//! The win comes from *coalescing*: at limit 1 every backend call
//! carries at most one scenario's controller batch (here deliberately
//! small — 4 samples against 8 workers, so half the pool idles), while
//! with overlap the batches that pile up behind a dispatch merge into
//! the next one and fill the pool. Scenarios use distinct controller
//! seeds so they explore distinct keys — the cross-scenario cache
//! cannot hide the dispatch behavior.
//!
//! Both runs must be bit-identical (admission changes scheduling,
//! never results) and perform the same number of backend evaluations;
//! the bench asserts both. Record the printed trajectory row in
//! `docs/BENCH_TRAJECTORY.md`.

use std::time::Instant;

use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::{
    run_sweep, ControllerKind, EvalBroker, ParallelSim, RewardCfg, Scenario, SweepOutcome,
};

const SAMPLES: usize = 240;
const BATCH: usize = 4;
const WORKERS: usize = 8;
const EVAL_SEED: u64 = 3;

fn scenarios() -> Vec<Scenario> {
    // Distinct controller seeds: each scenario samples its own region
    // of the joint space, so the sweep's cost is real backend work.
    [(0.3, 11u64), (0.4, 22), (0.5, 33), (0.6, 44), (0.7, 55), (0.8, 66)]
        .into_iter()
        .map(|(target, seed)| {
            Scenario::new(
                format!("lat{target}ms-s{seed}"),
                NasSpaceId::EfficientNet,
                RewardCfg::latency(target),
                seed,
            )
            .samples(SAMPLES)
            .batch(BATCH)
            .controller(ControllerKind::Random)
        })
        .collect()
}

fn run(inflight: Option<usize>) -> (SweepOutcome, f64, EvalBroker) {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let backend = ParallelSim::new(space, EVAL_SEED, WORKERS);
    let mut broker = EvalBroker::new(Box::new(backend));
    if let Some(n) = inflight {
        broker = broker.with_inflight_limit(n);
    }
    let scs = scenarios();
    let t0 = Instant::now();
    let out = run_sweep(&broker, &scs);
    (out, t0.elapsed().as_secs_f64(), broker)
}

fn main() {
    println!(
        "broker overlap: {} scenarios x {SAMPLES} samples, batch {BATCH}, \
         parallel backend with {WORKERS} workers\n",
        scenarios().len()
    );

    let (serial, serial_s, serial_broker) = run(Some(1));
    let sov = serial_broker.overlap_stats();
    println!(
        "  inflight 1: {serial_s:>6.2}s  {} evals over {} dispatches \
         ({:.1} keys/dispatch, peak {} admitted)",
        serial.eval_stats.evals,
        sov.dispatches,
        serial.eval_stats.evals as f64 / sov.dispatches.max(1) as f64,
        sov.peak_admitted,
    );

    let (overlap, overlap_s, overlap_broker) = run(None);
    let oov = overlap_broker.overlap_stats();
    println!(
        "  inflight {}: {overlap_s:>6.2}s  {} evals over {} dispatches \
         ({:.1} keys/dispatch, peak {} admitted, {} coalesced)",
        oov.inflight_limit,
        overlap.eval_stats.evals,
        oov.dispatches,
        overlap.eval_stats.evals as f64 / oov.dispatches.max(1) as f64,
        oov.peak_admitted,
        oov.coalesced_dispatches,
    );

    // Admission changes scheduling, never results: bit-identical
    // trajectories and identical backend work.
    assert_eq!(serial.eval_stats.requests, overlap.eval_stats.requests);
    assert_eq!(
        serial.eval_stats.evals, overlap.eval_stats.evals,
        "dedup must be interleaving-independent"
    );
    for (a, b) in serial.outcomes.iter().zip(&overlap.outcomes) {
        assert_eq!(a.search.history.len(), b.search.history.len());
        for (x, y) in a.search.history.iter().zip(&b.search.history) {
            assert_eq!(x.nas_d, y.nas_d, "{}: sampled decisions diverged", a.scenario.name);
            assert_eq!(x.reward.to_bits(), y.reward.to_bits(), "{}", a.scenario.name);
        }
        assert_eq!(a.frontier, b.frontier, "{}: frontier diverged", a.scenario.name);
    }
    assert_eq!(sov.peak_admitted, 1, "limit 1 must stay strictly serial");

    let speedup = serial_s / overlap_s.max(1e-9);
    println!("\n  speedup: {speedup:.2}x (inflight 1 / inflight {})", oov.inflight_limit);
    println!("\n  trajectory row (docs/BENCH_TRAJECTORY.md):");
    println!(
        "  | perf_broker_overlap | inflight 1: {serial_s:.2}s | inflight {}: {overlap_s:.2}s \
         | {speedup:.2}x | {} coalesced / {} dispatches |",
        oov.inflight_limit, oov.coalesced_dispatches, oov.dispatches
    );
}
