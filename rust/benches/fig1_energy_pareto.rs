//! Fig. 1 — Chip energy (power x latency) vs ImageNet top-1.
//!
//! Regenerates the figure's three series: NAHAS joint search,
//! platform-aware NAS on the fixed baseline accelerator, and the manual
//! EdgeTPU / MobileNet models — all costed by the same simulator.
//! Paper headline: NAHAS reduces energy up to 2x at matched accuracy.
//! Writes results/fig1_energy_pareto.csv.

use nahas::accel::{simulate_network, AcceleratorConfig};
use nahas::bench::Table;
use nahas::has::HasSpace;
use nahas::metrics;
use nahas::nas::{baselines, NasSpace, NasSpaceId};
use nahas::pareto::{frontier, Point};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::{joint_search, RewardCfg, SearchCfg, SurrogateSim};
use nahas::trainer::surrogate;

fn search(fixed_hw: bool, t_mj: f64, samples: usize, seed: u64) -> Option<(f64, f64)> {
    // Best of two controller seeds (the paper reports its best search).
    let mut best: Option<(f64, f64)> = None;
    for s in 0..2u64 {
        let space = NasSpace::new(NasSpaceId::Evolved);
        let has = HasSpace::new();
        let (cards, layout) = JointLayout::cards(&space, &has);
        let free = if fixed_hw { cards[..layout.nas_len].to_vec() } else { cards };
        let mut ev = SurrogateSim::new(space, seed);
        let mut ctl = PpoController::new(&free);
        let cfg = SearchCfg::new(samples, RewardCfg::energy(t_mj), seed + 131 * s);
        let baseline = fixed_hw.then(|| has.baseline_decisions());
        let out = joint_search(&mut ev, &mut ctl, &layout, baseline.as_deref(), None, &cfg);
        if let Some(b) = out.best_feasible {
            let cand = (b.result.acc * 100.0, b.result.energy_mj);
            if best.map(|x| cand.0 > x.0).unwrap_or(true) {
                best = Some(cand);
            }
        }
    }
    best
}

fn main() {
    let t0 = std::time::Instant::now();
    let mut rows = Vec::new();
    let mut table = Table::new(&["Series", "Target(mJ)", "Top-1(%)", "Energy(mJ)"]);
    let mut nahas_pts = Vec::new();
    let mut pa_pts = Vec::new();

    let targets = [0.6, 0.8, 1.0, 1.25, 1.5, 2.0];
    for (i, &t) in targets.iter().enumerate() {
        let seed = 100 + i as u64;
        if let Some((acc, e)) = search(false, t, 3000, seed) {
            table.row(vec!["NAHAS".into(), format!("{t}"), format!("{acc:.1}"), format!("{e:.3}")]);
            rows.push(vec!["nahas".into(), format!("{t}"), format!("{acc:.3}"), format!("{e:.4}")]);
            nahas_pts.push(Point::new(acc, e, format!("{t}")));
        }
        if let Some((acc, e)) = search(true, t, 3000, seed) {
            table.row(vec![
                "Platform-aware NAS".into(),
                format!("{t}"),
                format!("{acc:.1}"),
                format!("{e:.3}"),
            ]);
            rows.push(vec![
                "platform-aware".into(),
                format!("{t}"),
                format!("{acc:.3}"),
                format!("{e:.4}"),
            ]);
            pa_pts.push(Point::new(acc, e, format!("{t}")));
        }
    }
    let base_hw = AcceleratorConfig::baseline();
    let mut manual_pts = Vec::new();
    for (name, net) in [
        ("MobileNetV2", baselines::mobilenet_v2(1.0)),
        ("MobileNetV2-1.4", baselines::mobilenet_v2(1.4)),
        ("Manual-EdgeTPU-S", baselines::manual_edgetpu(false)),
        ("Manual-EdgeTPU-M", baselines::manual_edgetpu(true)),
        ("EfficientNet-B0", baselines::efficientnet(0, false)),
        ("EfficientNet-B1", baselines::efficientnet(1, false)),
    ] {
        let rep = simulate_network(&base_hw, &net).unwrap();
        let acc = surrogate::imagenet_accuracy(&net, 0);
        table.row(vec![
            format!("Manual: {name}"),
            "-".into(),
            format!("{acc:.1}"),
            format!("{:.3}", rep.energy_mj),
        ]);
        rows.push(vec![name.into(), String::new(), format!("{acc:.3}"), format!("{:.4}", rep.energy_mj)]);
        manual_pts.push(Point::new(acc, rep.energy_mj, name.to_string()));
    }

    println!("Fig. 1 — Chip Energy vs ImageNet top-1 (surrogate fidelity, 2000 samples/point):");
    table.print();

    // Headline check (the paper's Fig. 1 claim): NAHAS vs "other
    // platform-aware NAS, or manually crafted efficient ConvNets" —
    // max energy reduction at matched accuracy.
    let nf = frontier(&nahas_pts);
    let mut others = pa_pts.clone();
    others.extend(manual_pts.iter().cloned());
    let mut best_ratio: f64 = 1.0;
    let mut at: String = String::new();
    for p in &others {
        // cheapest NAHAS point at >= this accuracy
        if let Some(n) = nf.iter().filter(|n| n.acc >= p.acc - 0.05).map(|n| n.cost).fold(
            None::<f64>,
            |m, c| Some(m.map_or(c, |m| m.min(c))),
        ) {
            if p.cost / n > best_ratio {
                best_ratio = p.cost / n;
                at = format!("vs {} ({:.1}% top-1)", p.tag, p.acc);
            }
        }
    }
    println!(
        "\nmax energy reduction at matched accuracy: {best_ratio:.2}x {at} (paper: up to 2x)"
    );
    metrics::write_csv(
        "results/fig1_energy_pareto.csv",
        &["series", "target_mj", "top1", "energy_mj"],
        &rows,
    )
    .unwrap();
    println!("took {:.1}s; results/fig1_energy_pareto.csv written", t0.elapsed().as_secs_f64());
}
