//! Table 3 — detailed comparison with SoTA across three size regimes
//! (small 0.3 ms / 0.7 mJ, medium 0.5 ms / 1.0 mJ, large 0.7 ms /
//! 1.5 mJ): manual + platform-aware baselines vs NAHAS variants:
//!
//!   * "fixed accelerator" — NAS on the baseline hw (IBN-only or fused);
//!   * "NAHAS multi-trial" — PPO joint search (IBN-only and fused);
//!   * "NAHAS oneshot" — REINFORCE controller with the learned cost
//!     model as the latency oracle (the oneshot regime at ImageNet
//!     scale; the true weight-sharing oneshot runs on the proxy supernet
//!     in examples/oneshot_e2e.rs).
//!
//! Every row reports accuracy, latency and energy with ratio-to-best,
//! like the paper. Writes results/table3_sota.csv.

use nahas::accel::{simulate_network, AcceleratorConfig};
use nahas::bench::Table;
use nahas::costmodel::{generate_dataset, CostModel};
use nahas::has::HasSpace;
use nahas::metrics;
use nahas::nas::{baselines, NasSpace, NasSpaceId};
use nahas::runtime::Runtime;
use nahas::search::evaluator::{CostModelEval, Evaluator};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::reinforce::ReinforceController;
use nahas::search::{joint_search, Controller, RewardCfg, SearchCfg, SurrogateSim};
use nahas::trainer::surrogate;

struct Row {
    name: String,
    acc: f64,
    lat: f64,
    energy: f64,
}

fn baseline_row(name: &str, net: &nahas::model::NetworkIr) -> Row {
    let rep = simulate_network(&AcceleratorConfig::baseline(), net).unwrap();
    Row {
        name: name.to_string(),
        acc: surrogate::imagenet_accuracy(net, 0),
        lat: rep.latency_ms,
        energy: rep.energy_mj,
    }
}

fn search_row(
    name: &str,
    space_id: NasSpaceId,
    t_ms: f64,
    fixed_hw: bool,
    controller: &str,
    mut cm_eval: Option<&mut CostModelEval>,
    seed: u64,
) -> Option<Row> {
    // The joint space is ~40% larger than the fixed-hw one; like the
    // paper (5000-sample searches, best run reported) we give every
    // search row two controller restarts and keep the best.
    let mut b: Option<nahas::search::joint::Sample> = None;
    for r in 0..2u64 {
        let space = NasSpace::new(space_id);
        let has = HasSpace::new();
        let (cards, layout) = JointLayout::cards(&space, &has);
        let free = if fixed_hw { cards[..layout.nas_len].to_vec() } else { cards };
        let mut ctl: Box<dyn Controller> = match controller {
            "reinforce" => Box::new(ReinforceController::new(&free)),
            _ => Box::new(PpoController::new(&free)),
        };
        let cfg = SearchCfg::new(2500, RewardCfg::latency(t_ms), seed + 97 * r);
        let baseline = fixed_hw.then(|| has.baseline_decisions());
        let out = match cm_eval.as_deref_mut() {
            Some(ev) => joint_search(ev, ctl.as_mut(), &layout, baseline.as_deref(), None, &cfg),
            None => {
                let mut ev = SurrogateSim::new(space, seed);
                joint_search(&mut ev, ctl.as_mut(), &layout, baseline.as_deref(), None, &cfg)
            }
        };
        if let Some(cand) = out.best_feasible {
            if b.as_ref().map(|x| cand.result.acc > x.result.acc).unwrap_or(true) {
                b = Some(cand);
            }
        }
    }
    let b = b?;
    let has = HasSpace::new();
    // Re-simulate (cost-model rows report simulator ground truth, like
    // the paper's final table).
    let sp = NasSpace::new(space_id);
    let rep = simulate_network(&has.decode(&b.has_d), &sp.decode(&b.nas_d)).ok()?;
    Some(Row {
        name: name.to_string(),
        acc: b.result.acc * 100.0,
        lat: rep.latency_ms,
        energy: rep.energy_mj,
    })
}

fn print_regime(title: &str, rows: &[Row], out_rows: &mut Vec<Vec<String>>) {
    let best_lat = rows.iter().map(|r| r.lat).fold(f64::MAX, f64::min);
    let best_e = rows.iter().map(|r| r.energy).fold(f64::MAX, f64::min);
    let mut table =
        Table::new(&["Model", "Top-1 Acc.", "Latency ms (Ratio-to-best)", "Energy mJ (Ratio-to-best)"]);
    let mut sorted: Vec<&Row> = rows.iter().collect();
    sorted.sort_by(|a, b| a.acc.partial_cmp(&b.acc).unwrap());
    for r in sorted {
        table.row(vec![
            r.name.clone(),
            format!("{:.1}%", r.acc),
            format!("{:.2} ({:.2}x)", r.lat, r.lat / best_lat),
            format!("{:.2} ({:.2}x)", r.energy, r.energy / best_e),
        ]);
        out_rows.push(vec![
            title.to_string(),
            r.name.clone(),
            format!("{:.2}", r.acc),
            format!("{:.4}", r.lat),
            format!("{:.4}", r.energy),
        ]);
    }
    println!("\n--- {title} ---");
    table.print();
}

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    // Train the cost model once (for the oneshot rows).
    let mut rt = Runtime::load(Runtime::default_dir())?;
    let space = NasSpace::new(NasSpaceId::MobileNetV2);
    let mut rng = nahas::util::Rng::new(33);
    let (data, norm) = generate_dataset(&space, 3000, &mut rng);
    let mut cm = CostModel::init(&mut rt, norm, 0)?;
    cm.train(&mut rt, &data, 800, &mut rng)?;
    println!("cost model trained for the oneshot rows ({} samples)", data.len());

    let mut out_rows = Vec::new();

    // ---- small regime: 0.3 ms ------------------------------------------
    let mut small = vec![
        baseline_row("EfficientNet-B0 wo SE/Swish", &baselines::efficientnet(0, false)),
        baseline_row("MobileNetV2", &baselines::mobilenet_v2(1.0)),
        baseline_row("MnasNet-B1", &baselines::mnasnet_b1()),
        baseline_row("ProxylessNAS", &baselines::proxyless_mobile()),
        baseline_row("Manual-EdgeTPU-small", &baselines::manual_edgetpu(false)),
    ];
    if let Some(r) =
        search_row("IBN-only fixed accelerator", NasSpaceId::MobileNetV2, 0.3, true, "ppo", None, 51)
    {
        small.push(r);
    }
    if let Some(r) =
        search_row("IBN-only NAHAS multi-trial", NasSpaceId::MobileNetV2, 0.3, false, "ppo", None, 52)
    {
        small.push(r);
    }
    {
        let mut ev = CostModelEval::new(&mut rt, cm, NasSpace::new(NasSpaceId::MobileNetV2), 53);
        if let Some(r) = search_row(
            "IBN-only NAHAS oneshot (cost model)",
            NasSpaceId::MobileNetV2,
            0.3,
            false,
            "reinforce",
            Some(&mut ev),
            53,
        ) {
            small.push(r);
        }
        cm = ev.cm;
    }
    print_regime("small (target 0.3 ms / 0.7 mJ)", &small, &mut out_rows);

    // ---- medium regime: 0.5 ms -----------------------------------------
    let mut medium = vec![
        baseline_row("EfficientNet-B1 wo SE/Swish", &baselines::efficientnet(1, false)),
        baseline_row("MnasNet-D1", &baselines::mnasnet_d1()),
    ];
    for (name, sid, fixed) in [
        ("Fixed accelerator multi-trial w fused-IBN", NasSpaceId::Evolved, true),
        ("IBN-only NAHAS multi-trial", NasSpaceId::EfficientNet, false),
        ("NAHAS multi-trial w fused-IBN", NasSpaceId::Evolved, false),
    ] {
        if let Some(r) = search_row(name, sid, 0.5, fixed, "ppo", None, 61) {
            medium.push(r);
        }
    }
    {
        let mut ev = CostModelEval::new(&mut rt, cm, NasSpace::new(NasSpaceId::EfficientNet), 62);
        if let Some(r) = search_row(
            "IBN-only NAHAS oneshot (cost model)",
            NasSpaceId::EfficientNet,
            0.5,
            false,
            "reinforce",
            Some(&mut ev),
            62,
        ) {
            medium.push(r);
        }
        cm = ev.cm;
    }
    let _ = cm;
    print_regime("medium (target 0.5 ms / 1.0 mJ)", &medium, &mut out_rows);

    // ---- large regime: 0.7 ms ------------------------------------------
    let mut large = vec![
        baseline_row("EfficientNet-B3 wo SE/Swish", &baselines::efficientnet(3, false)),
        baseline_row("Manual-EdgeTPU-medium", &baselines::manual_edgetpu(true)),
        baseline_row("MobilenetV3 w SE", &baselines::mobilenet_v3_se()),
    ];
    for (name, sid, fixed) in [
        ("Fixed accelerator multi-trial w fused-IBN", NasSpaceId::Evolved, true),
        ("NAHAS multi-trial w fused-IBN", NasSpaceId::Evolved, false),
    ] {
        if let Some(r) = search_row(name, sid, 0.7, fixed, "ppo", None, 71) {
            large.push(r);
        }
    }
    print_regime("large (target 0.7 ms / 1.5 mJ)", &large, &mut out_rows);

    metrics::write_csv(
        "results/table3_sota.csv",
        &["regime", "model", "top1", "latency_ms", "energy_mj"],
        &out_rows,
    )?;
    println!("\ntook {:.1}s; results/table3_sota.csv written", t0.elapsed().as_secs_f64());
    Ok(())
}
