//! Ablation — learned cost model vs direct simulator as the search's
//! latency oracle (paper §3.5.2: the simulator query "becomes the new
//! bottleneck for NAHAS oneshot search", motivating the MLP).
//!
//! Compares (a) oracle quality: search outcome when rewards come from
//! MLP predictions vs ground truth, and (b) oracle throughput:
//! queries/s of each path.

use nahas::bench;
use nahas::bench::Table;
use nahas::costmodel::{featurize, generate_dataset, CostModel, FEATURE_DIM};
use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::runtime::Runtime;
use nahas::search::evaluator::{CostModelEval, Evaluator};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::{joint_search, RewardCfg, SearchCfg, SurrogateSim};
use nahas::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rt = Runtime::load(Runtime::default_dir())?;
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let mut rng = Rng::new(44);
    let (data, norm) = generate_dataset(&space, 4000, &mut rng);
    let mut cm = CostModel::init(&mut rt, norm, 0)?;
    cm.train(&mut rt, &data, 1000, &mut rng)?;

    // --- oracle throughput ------------------------------------------------
    let nas_d = space.random(&mut rng);
    let hw_d = has.baseline_decisions();
    let net = space.decode(&nas_d);
    let cfg_hw = has.decode(&hw_d);
    bench::bench("oracle: direct simulator", 10, 200, || {
        nahas::accel::simulate_network(&cfg_hw, &net).unwrap()
    });
    let mut feat = vec![0.0f32; FEATURE_DIM];
    featurize(&space, &nas_d, &hw_d, &mut feat);
    bench::bench("oracle: cost model (b1, incl PJRT)", 5, 50, || {
        cm.predict_one(&mut rt, &feat).unwrap()
    });
    let feats: Vec<Vec<f32>> = (0..256).map(|_| feat.clone()).collect();
    let r = bench::bench("oracle: cost model (b256 batch)", 3, 20, || {
        cm.predict(&mut rt, &feats).unwrap()
    });
    println!(
        "batched cost model: {:.0} predictions/s\n",
        256.0 / (r.mean_ns / 1e9)
    );

    // --- search-quality comparison ----------------------------------------
    let mut table =
        Table::new(&["Oracle", "Best feasible top-1(%)", "True latency(ms)", "Within target?"]);
    let t_ms = 0.5;
    for which in ["simulator", "costmodel"] {
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let (cards, layout) = JointLayout::cards(&space, &has);
        let mut ctl = PpoController::new(&cards);
        let cfg = SearchCfg::new(1500, RewardCfg::latency(t_ms), 9);
        let out = if which == "simulator" {
            let mut ev = SurrogateSim::new(space, 9);
            joint_search(&mut ev, &mut ctl, &layout, None, None, &cfg)
        } else {
            let mut ev = CostModelEval::new(&mut rt, cm, NasSpace::new(NasSpaceId::EfficientNet), 9);
            let out = joint_search(&mut ev, &mut ctl, &layout, None, None, &cfg);
            cm = ev.cm;
            out
        };
        if let Some(b) = out.best_feasible {
            // Ground-truth re-simulation of the winner.
            let sp = NasSpace::new(NasSpaceId::EfficientNet);
            let truth = nahas::accel::simulate_network(&has.decode(&b.has_d), &sp.decode(&b.nas_d));
            let (lat, ok) = match truth {
                Ok(rep) => (rep.latency_ms, rep.latency_ms <= t_ms * 1.1),
                Err(_) => (f64::NAN, false),
            };
            table.row(vec![
                which.into(),
                format!("{:.2}", b.result.acc * 100.0),
                format!("{lat:.3}"),
                format!("{ok}"),
            ]);
        } else {
            table.row(vec![which.into(), "-".into(), "-".into(), "false".into()]);
        }
    }
    println!("Search with each oracle (1500 samples, target {t_ms} ms, winner re-simulated):");
    table.print();
    Ok(())
}
