//! Fig. 2 — "Different accelerator configurations have different Pareto
//! frontiers consisting of different NAS models. Joint search
//! effectively extends the Pareto frontier by joining multiple
//! frontiers."
//!
//! Regenerates the schematic with real data, driven by the sweep
//! orchestrator: one platform-aware-NAS scenario per fixed accelerator
//! configuration (random controller, shared controller seed — so
//! every scenario samples the *same* model sequence and the frontiers
//! differ only by hardware), all running concurrently over ONE shared
//! `EvalBroker`. The union frontier (`pareto::union_frontier`, merged
//! by the sweep) dominates every individual one.
//! Writes results/fig2_frontier_union.csv.

use nahas::bench::Table;
use nahas::has::HasSpace;
use nahas::metrics;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::pareto::hypervolume;
use nahas::search::{run_sweep, ControllerKind, EvalBroker, ParallelSim, RewardCfg, Scenario};

fn main() {
    let has = HasSpace::new();
    // Four contrasting accelerator configs: baseline, compute-heavy,
    // memory-heavy, bandwidth-starved.
    let configs: Vec<(&str, Vec<usize>)> = vec![
        ("baseline (4x4, 2MB)", has.baseline_decisions()),
        ("compute-heavy (8x8, 1MB)", vec![4, 4, 3, 2, 1, 2, 4]),
        ("memory-heavy (2x2, 4MB)", vec![1, 1, 2, 2, 4, 3, 3]),
        ("io-starved (4x4, 5GB/s)", vec![2, 2, 2, 2, 2, 2, 0]),
    ];

    let scenarios: Vec<Scenario> = configs
        .iter()
        .map(|(name, hw)| {
            Scenario::new(*name, NasSpaceId::EfficientNet, RewardCfg::latency(2.0), 2)
                .samples(800)
                .batch(32)
                .controller(ControllerKind::Random)
                .fixed_hw(hw.clone())
        })
        .collect();
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let backend = ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), 2, workers);
    let broker = EvalBroker::new(Box::new(backend));
    let sweep = run_sweep(&broker, &scenarios);

    let mut rows = Vec::new();
    let mut table = Table::new(&["Accelerator", "Frontier size", "Hypervolume"]);
    let mut hv_best_single = 0.0f64;
    for o in &sweep.outcomes {
        let hv = hypervolume(&o.frontier, 70.0, 2.0);
        hv_best_single = hv_best_single.max(hv);
        table.row(vec![
            o.scenario.name.clone(),
            format!("{}", o.frontier.len()),
            format!("{hv:.3}"),
        ]);
        for p in &o.frontier {
            rows.push(vec![
                o.scenario.name.clone(),
                format!("{:.3}", p.acc),
                format!("{:.4}", p.cost),
            ]);
        }
    }

    let joint = &sweep.union[0].1;
    let hv_joint = hypervolume(joint, 70.0, 2.0);
    table.row(vec![
        "UNION (joint search reach)".into(),
        format!("{}", joint.len()),
        format!("{hv_joint:.3}"),
    ]);
    for p in joint {
        rows.push(vec!["union".into(), format!("{:.3}", p.acc), format!("{:.4}", p.cost)]);
    }

    println!("Fig. 2 — per-accelerator Pareto frontiers vs their union:");
    table.print();
    let st = &sweep.eval_stats;
    println!(
        "sweep: {} concurrent scenarios in {:.2}s, {} requests -> {} evals",
        sweep.outcomes.len(),
        sweep.elapsed_s,
        st.requests,
        st.evals
    );
    println!(
        "\nunion hypervolume {hv_joint:.3} >= best single {hv_best_single:.3}: {}",
        hv_joint >= hv_best_single
    );
    assert!(hv_joint >= hv_best_single, "union frontier must dominate");
    metrics::write_csv(
        "results/fig2_frontier_union.csv",
        &["config", "top1", "latency_ms"],
        &rows,
    )
    .unwrap();
    println!("results/fig2_frontier_union.csv written");
}
