//! Fig. 2 — "Different accelerator configurations have different Pareto
//! frontiers consisting of different NAS models. Joint search
//! effectively extends the Pareto frontier by joining multiple
//! frontiers."
//!
//! Regenerates the schematic with real data: a NAS sweep per fixed
//! accelerator configuration gives one frontier each; their union
//! (computed by `pareto::union_frontier`) dominates every individual
//! one. Writes results/fig2_frontier_union.csv.

use nahas::bench::Table;
use nahas::has::HasSpace;
use nahas::metrics;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::pareto::{frontier, hypervolume, union_frontier, Point};
use nahas::search::{Evaluator, SurrogateSim};
use nahas::util::Rng;

fn main() {
    let has = HasSpace::new();
    // Four contrasting accelerator configs: baseline, compute-heavy,
    // memory-heavy, bandwidth-starved.
    let configs: Vec<(&str, Vec<usize>)> = vec![
        ("baseline (4x4, 2MB)", has.baseline_decisions()),
        ("compute-heavy (8x8, 1MB)", vec![4, 4, 3, 2, 1, 2, 4]),
        ("memory-heavy (2x2, 4MB)", vec![1, 1, 2, 2, 4, 3, 3]),
        ("io-starved (4x4, 5GB/s)", vec![2, 2, 2, 2, 2, 2, 0]),
    ];

    let mut per_hw: Vec<Vec<Point>> = Vec::new();
    let mut rows = Vec::new();
    let mut table = Table::new(&["Accelerator", "Frontier size", "Hypervolume"]);
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let mut rng = Rng::new(2);
    // One shared model sample set so frontiers differ only by hardware.
    let samples: Vec<Vec<usize>> = (0..800).map(|_| space.random(&mut rng)).collect();

    for (name, hw) in &configs {
        let mut ev = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 2);
        let pts: Vec<Point> = samples
            .iter()
            .filter_map(|nas_d| {
                let r = ev.evaluate(nas_d, hw);
                r.valid.then(|| Point::new(r.acc * 100.0, r.latency_ms, name.to_string()))
            })
            .collect();
        let f = frontier(&pts);
        let hv = hypervolume(&pts, 70.0, 2.0);
        table.row(vec![name.to_string(), format!("{}", f.len()), format!("{hv:.3}")]);
        for p in &f {
            rows.push(vec![name.to_string(), format!("{:.3}", p.acc), format!("{:.4}", p.cost)]);
        }
        per_hw.push(pts);
    }

    let frontiers: Vec<Vec<Point>> = per_hw.iter().map(|p| frontier(p)).collect();
    let joint = union_frontier(&frontiers);
    let hv_joint = hypervolume(&joint, 70.0, 2.0);
    let hv_best_single = per_hw
        .iter()
        .map(|p| hypervolume(p, 70.0, 2.0))
        .fold(0.0f64, f64::max);
    table.row(vec![
        "UNION (joint search reach)".into(),
        format!("{}", joint.len()),
        format!("{hv_joint:.3}"),
    ]);
    for p in &joint {
        rows.push(vec!["union".into(), format!("{:.3}", p.acc), format!("{:.4}", p.cost)]);
    }

    println!("Fig. 2 — per-accelerator Pareto frontiers vs their union:");
    table.print();
    println!(
        "\nunion hypervolume {hv_joint:.3} >= best single {hv_best_single:.3}: {}",
        hv_joint >= hv_best_single
    );
    assert!(hv_joint >= hv_best_single, "union frontier must dominate");
    metrics::write_csv(
        "results/fig2_frontier_union.csv",
        &["config", "top1", "latency_ms"],
        &rows,
    )
    .unwrap();
    println!("results/fig2_frontier_union.csv written");
}
