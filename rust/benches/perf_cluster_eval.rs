//! §Perf — sharded cluster evaluation throughput: one fixed batch
//! driven through [`ShardedEvaluator`] pools of 1/2/3 in-process
//! `nahas serve` instances vs the serial evaluator and the single-host
//! service tier, plus the warm-cache replay and the per-host routing
//! split (rendezvous hashing should spread the key space roughly
//! evenly).

use std::time::Instant;

use nahas::cluster::ShardedEvaluator;
use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::{Evaluator, SurrogateSim};
use nahas::service::{Server, ServiceEvaluator};
use nahas::util::Rng;

const BATCH: usize = 384;
const CONNS_PER_HOST: usize = 4;

fn s2() -> NasSpace {
    NasSpace::new(NasSpaceId::EfficientNet)
}

fn fixed_batch() -> Vec<(Vec<usize>, Vec<usize>)> {
    let space = s2();
    let has = HasSpace::new();
    let mut rng = Rng::new(3);
    (0..BATCH).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect()
}

fn time_batch(ev: &mut dyn Evaluator, batch: &[(Vec<usize>, Vec<usize>)]) -> (f64, usize) {
    let t0 = Instant::now();
    let results = ev.evaluate_batch(batch);
    let dt = t0.elapsed().as_secs_f64();
    (batch.len() as f64 / dt, results.iter().filter(|r| r.valid).count())
}

fn main() {
    println!("cluster evaluation sweep: {BATCH} samples, {CONNS_PER_HOST} conns/host\n");
    let batch = fixed_batch();

    let mut serial = SurrogateSim::new(s2(), 3);
    let (serial_tput, serial_valid) = time_batch(&mut serial, &batch);
    println!("  SurrogateSim serial      {serial_tput:>8.0} samples/s  (1.00x)");

    let single = Server::spawn("127.0.0.1:0").expect("spawn server");
    let mut remote = ServiceEvaluator::connect(
        &single.addr.to_string(),
        NasSpaceId::EfficientNet,
        3,
        CONNS_PER_HOST,
    )
    .expect("connect service evaluator");
    let (tput, valid) = time_batch(&mut remote, &batch);
    assert_eq!(valid, serial_valid, "service results diverged");
    println!(
        "  ServiceEvaluator 1 host  {tput:>8.0} samples/s  ({:.2}x)",
        tput / serial_tput
    );
    single.stop();

    for n_hosts in [1usize, 2, 3] {
        let servers: Vec<Server> =
            (0..n_hosts).map(|_| Server::spawn("127.0.0.1:0").expect("spawn server")).collect();
        let hosts: Vec<String> = servers.iter().map(|s| s.addr.to_string()).collect();
        let mut cluster =
            ShardedEvaluator::connect(&hosts, NasSpaceId::EfficientNet, 3, CONNS_PER_HOST)
                .expect("connect cluster");
        let (tput, valid) = time_batch(&mut cluster, &batch);
        assert_eq!(valid, serial_valid, "cluster results diverged from serial");
        let split: Vec<String> = cluster
            .host_snapshots()
            .iter()
            .map(|s| format!("{:.0}%", 100.0 * s.evals as f64 / BATCH as f64))
            .collect();
        println!(
            "  ShardedEvaluator x{n_hosts}     {tput:>8.0} samples/s  ({:.2}x)  split {}",
            tput / serial_tput,
            split.join("/")
        );
        if n_hosts == 3 {
            // Warm-cache replay: pure memo hits, zero service traffic.
            let evals: usize = cluster.host_snapshots().iter().map(|s| s.evals).sum();
            let (hit_tput, _) = time_batch(&mut cluster, &batch);
            let evals2: usize = cluster.host_snapshots().iter().map(|s| s.evals).sum();
            assert_eq!(evals, evals2, "replay must not touch the hosts");
            println!(
                "  memo-cache replay        {hit_tput:>8.0} samples/s  ({:.2}x)",
                hit_tput / serial_tput
            );
        }
        for s in servers {
            s.stop();
        }
    }
}
