//! Fig. 7 — sample distributions during search: NAHAS vs platform-aware
//! NAS at a 1 ms target on the EfficientNet-B0-based space.
//!
//! Reproduces the paper's observations: (a) platform-aware NAS converges
//! to higher-latency / lower-accuracy clusters; (b) NAHAS traverses
//! area-violating samples (the red points) on its way to better
//! feasible ones. Writes the full scatter to
//! results/fig7_samples_{joint,fixed}.csv.

use nahas::bench::Table;
use nahas::has::HasSpace;
use nahas::metrics;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::{joint_search, RewardCfg, SearchCfg, SearchOutcome, SurrogateSim};

fn run(fixed: bool, seed: u64) -> SearchOutcome {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(&space, &has);
    let free = if fixed { cards[..layout.nas_len].to_vec() } else { cards };
    let mut ev = SurrogateSim::new(space, seed);
    let mut ctl = PpoController::new(&free);
    let cfg = SearchCfg::new(2000, RewardCfg::latency(1.0), seed);
    let baseline = fixed.then(|| has.baseline_decisions());
    joint_search(&mut ev, &mut ctl, &layout, baseline.as_deref(), None, &cfg)
}

fn stats(out: &SearchOutcome) -> (f64, f64, f64, usize) {
    let tail: Vec<_> = out.history.iter().rev().take(400).filter(|s| s.result.valid).collect();
    let acc = tail.iter().map(|s| s.result.acc).sum::<f64>() / tail.len() as f64;
    let lat = tail.iter().map(|s| s.result.latency_ms).sum::<f64>() / tail.len() as f64;
    let best = out.best_feasible.as_ref().map(|b| b.result.acc).unwrap_or(0.0);
    (acc * 100.0, lat, best * 100.0, out.num_invalid)
}

fn main() {
    let joint = run(false, 77);
    let fixed = run(true, 77);

    let mut table =
        Table::new(&["Search", "Tail mean top-1(%)", "Tail mean lat(ms)", "Best top-1(%)", "Invalid samples"]);
    for (name, out) in [("NAHAS (joint)", &joint), ("platform-aware (fixed hw)", &fixed)] {
        let (acc, lat, best, inv) = stats(out);
        table.row(vec![
            name.into(),
            format!("{acc:.2}"),
            format!("{lat:.3}"),
            format!("{best:.2}"),
            format!("{inv}"),
        ]);
    }
    println!("Fig. 7 — sample distributions (2000 samples, 1 ms target):");
    table.print();

    let (ja, jl, jb, ji) = stats(&joint);
    let (fa, fl, fb, fi) = stats(&fixed);
    println!(
        "\npaper's observations hold: joint best {} >= fixed best {} -> {};",
        jb,
        fb,
        jb >= fb - 0.1
    );
    println!(
        "joint traverses invalid samples ({ji}) while fixed-hw has none to traverse ({fi});"
    );
    let _ = (ja, jl, fa, fl);

    metrics::write_history_csv("results/fig7_samples_joint.csv", &joint.history).unwrap();
    metrics::write_history_csv("results/fig7_samples_fixed.csv", &fixed.history).unwrap();
    println!("scatter data written to results/fig7_samples_{{joint,fixed}}.csv");
}
