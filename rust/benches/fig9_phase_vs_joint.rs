//! Fig. 9 — joint search vs phase-based (HAS-then-NAS) search.
//!
//! Phase search at the same sample budget is much worse than joint
//! multi-trial; doubling its budget helps; the initial architecture
//! choice creates large variance (the paper's three findings). Three
//! initial architectures (MobileNetV2-like minimal, EfficientNet-B1-ish
//! mid, EfficientNet-B2-ish max decisions in the S2 space) x 3 seeds.
//! Writes results/fig9_phase_vs_joint.csv.

use nahas::bench::Table;
use nahas::has::HasSpace;
use nahas::metrics;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::joint::JointLayout;
use nahas::search::phase::phase_search;
use nahas::search::ppo::PpoController;
use nahas::search::{joint_search, EvalBroker, RewardCfg, SearchCfg, SurrogateSim};

fn main() {
    let samples = 1200;
    let target = RewardCfg::latency(0.6);
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let nd = space.num_decisions();
    // Initial architectures for phase-1 HAS (paper: MobileNetV2, B1, B2).
    let initials: Vec<(&str, Vec<usize>)> = vec![
        ("min (MobileNetV2-ish)", vec![0; nd]),
        ("mid (B1-ish)", (0..nd).map(|i| if i % 2 == 0 { 1 } else { 0 }).collect()),
        ("max (B2-ish)", space.specs().iter().map(|s| s.cardinality - 1).collect()),
    ];

    let mut table = Table::new(&["Method", "Initial arch", "Seed", "Best feasible top-1(%)"]);
    let mut rows = Vec::new();
    let mut joint_accs = Vec::new();
    let mut phase1_accs = Vec::new();
    let mut phase2_accs = Vec::new();

    for seed in [1u64, 2, 3] {
        let has = HasSpace::new();
        let (cards, layout) = JointLayout::cards(&space, &has);
        let mut ev = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed);
        let mut ctl = PpoController::new(&cards);
        let cfg = SearchCfg::new(samples, target, seed);
        let out = joint_search(&mut ev, &mut ctl, &layout, None, None, &cfg);
        let acc = out.best_feasible.map(|b| b.result.acc * 100.0).unwrap_or(0.0);
        table.row(vec!["joint (1x)".into(), "-".into(), format!("{seed}"), format!("{acc:.2}")]);
        rows.push(vec!["joint-1x".into(), "-".into(), format!("{seed}"), format!("{acc:.3}")]);
        joint_accs.push(acc);

        for (iname, init) in &initials {
            for (mult, bucket) in [(1usize, &mut phase1_accs), (2usize, &mut phase2_accs)] {
                let sim = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed);
                let broker = EvalBroker::new(Box::new(sim));
                let cfg = SearchCfg::new(samples * mult, target, seed);
                let out = phase_search(&broker, &space, init, &cfg);
                let acc =
                    out.nas_phase.best_feasible.map(|b| b.result.acc * 100.0).unwrap_or(0.0);
                table.row(vec![
                    format!("phase ({mult}x)"),
                    iname.to_string(),
                    format!("{seed}"),
                    format!("{acc:.2}"),
                ]);
                rows.push(vec![
                    format!("phase-{mult}x"),
                    iname.to_string(),
                    format!("{seed}"),
                    format!("{acc:.3}"),
                ]);
                bucket.push(acc);
            }
        }
    }

    println!("Fig. 9 — joint vs phase-based search ({samples} samples at 1x):");
    table.print();

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let std = |v: &[f64]| {
        let m = mean(v);
        (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
    };
    println!("\njoint 1x:  mean {:.2}% (std {:.2})", mean(&joint_accs), std(&joint_accs));
    println!("phase 1x:  mean {:.2}% (std {:.2})", mean(&phase1_accs), std(&phase1_accs));
    println!("phase 2x:  mean {:.2}% (std {:.2})", mean(&phase2_accs), std(&phase2_accs));
    println!(
        "paper shape: joint > phase-2x > phase-1x -> {} {}",
        mean(&joint_accs) >= mean(&phase2_accs) - 0.05,
        mean(&phase2_accs) >= mean(&phase1_accs) - 0.05
    );
    metrics::write_csv(
        "results/fig9_phase_vs_joint.csv",
        &["method", "initial", "seed", "top1"],
        &rows,
    )
    .unwrap();
}
