//! §Perf — L3 hot-path micro-benchmarks.
//!
//! The cycle-level simulator is the inner loop of every search
//! (thousands of (model, hw) evaluations per run), so its throughput
//! gates end-to-end search speed. Targets (DESIGN.md §Perf): >= 100k
//! layer-evals/s; search >= 1000 samples/s; featurizer and decoder off
//! the critical path. Results recorded in EXPERIMENTS.md §Perf.

use nahas::accel::{simulate_network, simulate_network_detailed, AcceleratorConfig};
use nahas::bench;
use nahas::costmodel::{featurize, FEATURE_DIM};
use nahas::has::HasSpace;
use nahas::nas::{baselines, NasSpace, NasSpaceId};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::{joint_search, RewardCfg, SearchCfg, SurrogateSim};
use nahas::util::Rng;

fn main() {
    let cfg = AcceleratorConfig::baseline();

    // Simulator throughput on representative networks.
    let nets = [
        ("MobileNetV2 (54 layers)", baselines::mobilenet_v2(1.0)),
        ("EfficientNet-B3 (~80 layers)", baselines::efficientnet(3, false)),
        ("Manual-EdgeTPU-M", baselines::manual_edgetpu(true)),
    ];
    for (name, net) in &nets {
        let layers = net.layers.len();
        let r = bench::bench(&format!("simulate {name}"), 50, 2000, || {
            simulate_network(&cfg, net).unwrap()
        });
        println!(
            "    -> {:.0} net-evals/s, {:.2}M layer-evals/s",
            1e9 / r.mean_ns,
            layers as f64 * 1e9 / r.mean_ns / 1e6
        );
    }

    // Detailed (per-layer vector) variant: allocation cost visibility.
    let net = baselines::mobilenet_v2(1.0);
    let mut per = Vec::new();
    bench::bench("simulate_network_detailed MobileNetV2", 50, 2000, || {
        simulate_network_detailed(&cfg, &net, &mut per).unwrap()
    });

    // Space decode + featurize.
    let space = NasSpace::new(NasSpaceId::Evolved);
    let has = HasSpace::new();
    let mut rng = Rng::new(1);
    let nas_d = space.random(&mut rng);
    let has_d = has.baseline_decisions();
    bench::bench("decode evolved-space sample -> IR", 100, 5000, || space.decode(&nas_d));
    let mut feat = vec![0.0f32; FEATURE_DIM];
    bench::bench("featurize (394-dim) incl decode", 100, 5000, || {
        featurize(&space, &nas_d, &has_d, &mut feat)
    });

    // End-to-end search throughput (the composite hot loop).
    let r = bench::bench("joint_search 500 samples (PPO+sim+surrogate)", 1, 5, || {
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let (cards, layout) = JointLayout::cards(&space, &has);
        let mut ev = SurrogateSim::new(space, 3);
        let mut ctl = PpoController::new(&cards);
        let cfg = SearchCfg::new(500, RewardCfg::latency(0.5), 3);
        joint_search(&mut ev, &mut ctl, &layout, None, None, &cfg)
    });
    println!("    -> {:.0} search samples/s", 500.0 * 1e9 / r.mean_ns);
}
