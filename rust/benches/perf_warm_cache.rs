//! §Perf — cross-run warm start: the same sweep run cold (fresh
//! `--cache-dir`) and then warm (reopening the spilled cache file).
//! The warm pass must perform **zero** backend evaluations and be
//! markedly faster end to end; the bench also reports the store's
//! load/append costs, which bound the overhead persistence adds to a
//! cold run.

use std::time::Instant;

use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::store::eval_fingerprint;
use nahas::search::{
    run_sweep, scenario_grid, CacheStore, CostObjective, EvalBroker, ParallelSim, SweepDriver,
    Task,
};

const SAMPLES: usize = 200;
const SEED: u64 = 7;

fn broker(store: Option<CacheStore>) -> EvalBroker {
    let backend = Box::new(ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), SEED, 4));
    match store {
        Some(s) => EvalBroker::with_store(backend, s),
        None => EvalBroker::new(backend),
    }
}

fn main() {
    let dir = std::env::temp_dir().join(format!("nahas-warm-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("evals.cache");
    let fp = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, SEED);
    let scenarios = scenario_grid(
        &[0.35, 0.5, 0.7],
        &[CostObjective::Latency],
        &[SweepDriver::Joint],
        NasSpaceId::EfficientNet,
        SAMPLES,
        20,
        SEED,
    );
    println!(
        "warm-start sweep: {} scenarios x {SAMPLES} samples, cache file {}\n",
        scenarios.len(),
        path.display()
    );

    // Cold pass: pays the full simulator bill, spills every entry.
    let store = CacheStore::open(&path, &fp).expect("open cache store");
    let cold_broker = broker(Some(store));
    let t0 = Instant::now();
    let cold = run_sweep(&cold_broker, &scenarios);
    let cold_s = t0.elapsed().as_secs_f64();
    let cold_backend = cold_broker.backend_stats().requests;
    drop(cold_broker); // Flush the spill file.
    println!(
        "  cold: {cold_s:>6.2}s  {} evals, {} backend requests, {} persisted hits",
        cold.eval_stats.evals, cold_backend, cold.eval_stats.persisted_hits
    );

    // Warm pass: fresh process state, same file.
    let t0 = Instant::now();
    let store = CacheStore::open(&path, &fp).expect("reopen cache store");
    let load_s = t0.elapsed().as_secs_f64();
    let loaded = store.loaded_len();
    let warm_broker = broker(Some(store));
    let t0 = Instant::now();
    let warm = run_sweep(&warm_broker, &scenarios);
    let warm_s = t0.elapsed().as_secs_f64();
    let warm_backend = warm_broker.backend_stats().requests;
    println!(
        "  warm: {warm_s:>6.2}s  {} evals, {} backend requests, {} persisted hits \
         ({loaded} entries loaded in {:.1}ms)",
        warm.eval_stats.evals,
        warm_backend,
        warm.eval_stats.persisted_hits,
        load_s * 1e3
    );

    assert_eq!(warm_backend, 0, "fully-warm sweep must not touch the backend");
    assert!(warm.eval_stats.persisted_hits > 0);
    // Frontier equivalence: warm replay is the same sweep.
    for ((_, a), (_, b)) in cold.union.iter().zip(&warm.union) {
        assert_eq!(a.len(), b.len(), "warm union frontier diverged");
    }
    println!("\n  speedup: {:.1}x (cold/warm wall clock)", cold_s / warm_s.max(1e-9));
    let _ = std::fs::remove_dir_all(&dir);
}
