//! Fig. 8 — Inference latency vs ImageNet accuracy.
//!
//! NAHAS points at the paper's five latency targets (0.3/0.5/0.8/1.1/
//! 1.3 ms; IBN-only space for the tight target, evolved space for the
//! relaxed ones — §4.3) against every platform-aware / manual baseline,
//! all costed on the same simulator. Paper headline: ~1% higher top-1
//! at every target, or ~20% lower latency at matched accuracy.
//!
//! Driven by the sweep orchestrator: each space's targets (x two
//! controller seeds — the paper reports its best search outcome) run
//! as concurrent scenarios over ONE shared `EvalBroker` on a parallel
//! backend, so the searches share the worker pool and the cross-search
//! memo cache instead of queueing serially.
//! Writes results/fig8_latency_sweep.csv.

use nahas::accel::{simulate_network, AcceleratorConfig};
use nahas::bench::Table;
use nahas::metrics;
use nahas::nas::{baselines, NasSpace, NasSpaceId};
use nahas::search::{run_sweep, EvalBroker, ParallelSim, RewardCfg, Scenario};
use nahas::trainer::surrogate;

fn main() {
    let t0 = std::time::Instant::now();
    let mut table = Table::new(&["Model", "Top-1(%)", "Latency(ms)"]);
    let mut rows = Vec::new();

    let base_hw = AcceleratorConfig::baseline();
    for (name, net) in baselines::all_baselines() {
        let rep = simulate_network(&base_hw, &net).unwrap();
        let acc = surrogate::imagenet_accuracy(&net, 0);
        table.row(vec![name.into(), format!("{acc:.1}"), format!("{:.3}", rep.latency_ms)]);
        rows.push(vec![name.into(), format!("{acc:.3}"), format!("{:.4}", rep.latency_ms)]);
    }

    // Paper §4.3: IBN-only for the tightest target, the evolved
    // (fused-IBN + compound-scale) space once latency relaxes. One
    // broker (and one surrogate-fidelity instance) per space; all of a
    // space's scenarios run concurrently over it.
    let groups: [(NasSpaceId, &[(&str, f64)]); 2] = [
        (NasSpaceId::MobileNetV2, &[("NAHAS-XS", 0.3)]),
        (
            NasSpaceId::Evolved,
            &[("NAHAS-S", 0.5), ("NAHAS-M", 0.8), ("NAHAS-L", 1.1), ("NAHAS-XL", 1.3)],
        ),
    ];
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut nahas_accs = Vec::new();
    for (sid, points) in groups {
        let mut scenarios = Vec::new();
        for (name, target) in points {
            // Best of two controller seeds per target (paper budget:
            // 2000-5000 samples per search).
            for s in 0..2u64 {
                let tag = format!("{name}@{target}ms#s{s}");
                let reward = RewardCfg::latency(*target);
                scenarios.push(Scenario::new(tag, sid, reward, 800 + 37 * s).samples(2500));
            }
        }
        let backend = ParallelSim::new(NasSpace::new(sid), 800, workers);
        let broker = EvalBroker::new(Box::new(backend));
        let sweep = run_sweep(&broker, &scenarios);
        let st = &sweep.eval_stats;
        println!(
            "{sid:?} sweep: {} scenarios, {} requests -> {} evals \
             ({} cache hits, {} cross-scenario)",
            scenarios.len(),
            st.requests,
            st.evals,
            st.cache_hits,
            st.cross_session_hits
        );
        for (name, target) in points {
            // Best feasible across the two seeds of this target.
            let best = sweep
                .outcomes
                .iter()
                .filter(|o| o.scenario.name.starts_with(name))
                .filter_map(|o| o.search.best_feasible.clone())
                .max_by(|a, b| a.result.acc.partial_cmp(&b.result.acc).unwrap());
            if let Some(b) = best {
                let acc = b.result.acc * 100.0;
                table.row(vec![
                    format!("{name} (target {target} ms)"),
                    format!("{acc:.1}"),
                    format!("{:.3}", b.result.latency_ms),
                ]);
                rows.push(vec![
                    name.to_string(),
                    format!("{acc:.3}"),
                    format!("{:.4}", b.result.latency_ms),
                ]);
                nahas_accs.push((*target, acc, b.result.latency_ms));
            }
        }
    }

    println!("\nFig. 8 — latency vs accuracy (2500 samples per search, surrogate fidelity):");
    table.print();

    // Headline: accuracy advantage over the best baseline at each target.
    println!("\nNAHAS vs best baseline under each latency target:");
    for (t, acc, lat) in &nahas_accs {
        let best_base = baselines::all_baselines()
            .into_iter()
            .filter_map(|(n, net)| {
                let rep = simulate_network(&base_hw, &net).ok()?;
                (rep.latency_ms <= *t)
                    .then(|| (n, surrogate::imagenet_accuracy(&net, 0)))
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        match best_base {
            Some((n, ba)) => println!(
                "  target {t} ms: NAHAS {acc:.1}% @ {lat:.3} ms vs {n} {ba:.1}% -> +{:.1}%",
                acc - ba
            ),
            None => println!("  target {t} ms: no baseline fits"),
        }
    }

    metrics::write_csv("results/fig8_latency_sweep.csv", &["model", "top1", "latency_ms"], &rows)
        .unwrap();
    println!("took {:.1}s; results/fig8_latency_sweep.csv written", t0.elapsed().as_secs_f64());
}
