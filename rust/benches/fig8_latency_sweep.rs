//! Fig. 8 — Inference latency vs ImageNet accuracy.
//!
//! NAHAS points at the paper's five latency targets (0.3/0.5/0.8/1.1/
//! 1.3 ms; IBN-only space for the tight targets, evolved space for the
//! relaxed ones — §4.3) against every platform-aware / manual baseline,
//! all costed on the same simulator. Paper headline: ~1% higher top-1
//! at every target, or ~20% lower latency at matched accuracy.
//! Writes results/fig8_latency_sweep.csv.

use nahas::accel::{simulate_network, AcceleratorConfig};
use nahas::bench::Table;
use nahas::has::HasSpace;
use nahas::metrics;
use nahas::nas::{baselines, NasSpace, NasSpaceId};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::{joint_search, RewardCfg, SearchCfg, SurrogateSim};
use nahas::trainer::surrogate;

fn main() {
    let t0 = std::time::Instant::now();
    let mut table = Table::new(&["Model", "Top-1(%)", "Latency(ms)"]);
    let mut rows = Vec::new();

    let base_hw = AcceleratorConfig::baseline();
    for (name, net) in baselines::all_baselines() {
        let rep = simulate_network(&base_hw, &net).unwrap();
        let acc = surrogate::imagenet_accuracy(&net, 0);
        table.row(vec![name.into(), format!("{acc:.1}"), format!("{:.3}", rep.latency_ms)]);
        rows.push(vec![name.into(), format!("{acc:.3}"), format!("{:.4}", rep.latency_ms)]);
    }

    let names = ["NAHAS-XS", "NAHAS-S", "NAHAS-M", "NAHAS-L", "NAHAS-XL"];
    let targets = [0.3, 0.5, 0.8, 1.1, 1.3];
    let mut nahas_accs = Vec::new();
    for (i, (&t, name)) in targets.iter().zip(names).enumerate() {
        // Paper §4.3: IBN-only for the tightest targets, the evolved
        // (fused-IBN + compound-scale) space once latency relaxes.
        let sid = if t <= 0.3 { NasSpaceId::MobileNetV2 } else { NasSpaceId::Evolved };
        // Paper budget: 2000-5000 samples per search; best of two
        // controller seeds (the paper reports its best search outcome).
        let mut best: Option<nahas::search::joint::Sample> = None;
        for s in 0..2u64 {
            let space = NasSpace::new(sid);
            let has = HasSpace::new();
            let (cards, layout) = JointLayout::cards(&space, &has);
            let seed = 800 + i as u64 + 37 * s;
            let mut ev = SurrogateSim::new(space, 800 + i as u64);
            let mut ctl = PpoController::new(&cards);
            let cfg = SearchCfg::new(2500, RewardCfg::latency(t), seed);
            let out = joint_search(&mut ev, &mut ctl, &layout, None, None, &cfg);
            if let Some(b) = out.best_feasible {
                if best.as_ref().map(|x| b.result.acc > x.result.acc).unwrap_or(true) {
                    best = Some(b);
                }
            }
        }
        if let Some(b) = best {
            let acc = b.result.acc * 100.0;
            table.row(vec![
                format!("{name} (target {t} ms)"),
                format!("{acc:.1}"),
                format!("{:.3}", b.result.latency_ms),
            ]);
            rows.push(vec![
                name.into(),
                format!("{acc:.3}"),
                format!("{:.4}", b.result.latency_ms),
            ]);
            nahas_accs.push((t, acc, b.result.latency_ms));
        }
    }

    println!("Fig. 8 — latency vs accuracy (2000 samples per NAHAS point, surrogate fidelity):");
    table.print();

    // Headline: accuracy advantage over the best baseline at each target.
    println!("\nNAHAS vs best baseline under each latency target:");
    for (t, acc, lat) in &nahas_accs {
        let best_base = baselines::all_baselines()
            .into_iter()
            .filter_map(|(n, net)| {
                let rep = simulate_network(&base_hw, &net).ok()?;
                (rep.latency_ms <= *t)
                    .then(|| (n, surrogate::imagenet_accuracy(&net, 0)))
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        match best_base {
            Some((n, ba)) => println!(
                "  target {t} ms: NAHAS {acc:.1}% @ {lat:.3} ms vs {n} {ba:.1}% -> +{:.1}%",
                acc - ba
            ),
            None => println!("  target {t} ms: no baseline fits"),
        }
    }

    metrics::write_csv("results/fig8_latency_sweep.csv", &["model", "top1", "latency_ms"], &rows)
        .unwrap();
    println!("took {:.1}s; results/fig8_latency_sweep.csv written", t0.elapsed().as_secs_f64());
}
