//! Table 4 — Cityscapes segmentation transfer (paper §4.5).
//!
//! Every backbone is rebuilt as a dense-prediction network (640-crop
//! input + FCN decoder, ~10x the classification latency — see
//! `search::evaluator::segmentation_variant`) and costed by the same
//! simulator; mIOU comes from the segmentation surrogate (DESIGN.md
//! §Substitutions — the paper's 1000-epoch Cityscapes training is not
//! reproducible here). NAHAS rows re-run the joint search with the
//! segmentation objective. Writes results/table4_segmentation.csv.

use nahas::accel::{simulate_network, AcceleratorConfig};
use nahas::bench::Table;
use nahas::has::HasSpace;
use nahas::metrics;
use nahas::nas::{baselines, NasSpace, NasSpaceId};
use nahas::search::evaluator::segmentation_variant;
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::{joint_search, RewardCfg, SearchCfg, SurrogateSim};
use nahas::trainer::surrogate;

struct Row {
    name: String,
    miou: f64,
    lat: f64,
    energy: f64,
}

fn main() {
    let t0 = std::time::Instant::now();
    let base_hw = AcceleratorConfig::baseline();
    let mut rows = Vec::new();

    for (name, net) in [
        ("EfficientNet-B0 wo SE/Swish", baselines::efficientnet(0, false)),
        ("EfficientNet-B1 wo SE/Swish", baselines::efficientnet(1, false)),
        ("EfficientNet-B2 wo SE/Swish", baselines::efficientnet(2, false)),
        ("Manual-EdgeTPU-S", baselines::manual_edgetpu(false)),
        ("Manual-EdgeTPU-M", baselines::manual_edgetpu(true)),
    ] {
        let seg = segmentation_variant(&net);
        let rep = simulate_network(&base_hw, &seg).unwrap();
        rows.push(Row {
            name: name.to_string(),
            miou: surrogate::segmentation_miou(&seg, 0),
            lat: rep.latency_ms,
            energy: rep.energy_mj,
        });
    }

    // NAHAS rows: joint search with the segmentation objective.
    for (name, sid, seed) in [
        ("IBN-only NAHAS multi-trial", NasSpaceId::EfficientNet, 91u64),
        ("NAHAS multi-trial w fused-IBN", NasSpaceId::Evolved, 92),
    ] {
        let space = NasSpace::new(sid);
        let has = HasSpace::new();
        let (cards, layout) = JointLayout::cards(&space, &has);
        let mut ev = SurrogateSim::new(space, seed).segmentation();
        let mut ctl = PpoController::new(&cards);
        let cfg = SearchCfg::new(1500, RewardCfg::latency(3.5), seed);
        let out = joint_search(&mut ev, &mut ctl, &layout, None, None, &cfg);
        if let Some(b) = out.best_feasible {
            let sp = NasSpace::new(sid);
            let seg = segmentation_variant(&sp.decode(&b.nas_d));
            let rep = simulate_network(&has.decode(&b.has_d), &seg).unwrap();
            rows.push(Row {
                name: name.to_string(),
                miou: b.result.acc * 100.0,
                lat: rep.latency_ms,
                energy: rep.energy_mj,
            });
        }
    }

    let best_lat = rows.iter().map(|r| r.lat).fold(f64::MAX, f64::min);
    let best_e = rows.iter().map(|r| r.energy).fold(f64::MAX, f64::min);
    let mut table = Table::new(&[
        "Model",
        "mIOU Acc.",
        "Latency ms (Ratio-to-best)",
        "Energy mJ (Ratio-to-best)",
    ]);
    let mut csv = Vec::new();
    for r in &rows {
        table.row(vec![
            r.name.clone(),
            format!("{:.1}%", r.miou),
            format!("{:.2} ({:.2}x)", r.lat, r.lat / best_lat),
            format!("{:.2} ({:.2}x)", r.energy, r.energy / best_e),
        ]);
        csv.push(vec![
            r.name.clone(),
            format!("{:.2}", r.miou),
            format!("{:.3}", r.lat),
            format!("{:.3}", r.energy),
        ]);
    }
    println!("Table 4 — Cityscapes segmentation (simulated latency/energy, surrogate mIOU):");
    table.print();
    println!(
        "\npaper shape checks: Manual-EdgeTPU-M most energy-hungry: {}; NAHAS rows on the \
         latency/energy frontier: see table",
        rows.iter().max_by(|a, b| a.energy.partial_cmp(&b.energy).unwrap()).unwrap().name
    );
    metrics::write_csv(
        "results/table4_segmentation.csv",
        &["model", "miou", "latency_ms", "energy_mj"],
        &csv,
    )
    .unwrap();
    println!("took {:.1}s; results/table4_segmentation.csv written", t0.elapsed().as_secs_f64());
}
