//! §Perf — registry-driven multi-task sweep, cold vs. warm: the
//! `multitask-cls-seg` substrate compiled from the registry, run cold
//! (fresh `--cache-dir`) and then warm (reopening the spilled
//! task-set-fingerprinted cache file). The warm pass must perform
//! **zero** backend evaluations; per-task frontiers and the union
//! frontier must replay identically. This is the cold-vs-warm row of
//! `docs/BENCH_TRAJECTORY.md` for the scenario-substrate PR.

use std::time::Instant;

use nahas::nas::NasSpaceId;
use nahas::search::store::{eval_cache_file_tasks, eval_fingerprint_tasks};
use nahas::search::{
    builtin_registry, compile_substrates, run_sweep, CacheStore, EvalBroker, MultiTaskEval,
    Scenario, SubstrateParams,
};

const SAMPLES: usize = 200;
const SEED: u64 = 7;

fn broker(scenarios: &[Scenario], store: CacheStore) -> EvalBroker {
    let tasks = scenarios[0].tasks.as_ref().expect("multi-task scenarios");
    let backend =
        Box::new(MultiTaskEval::surrogate(tasks, NasSpaceId::EfficientNet, SEED, 4));
    EvalBroker::with_store(backend, store)
}

fn main() {
    let dir = std::env::temp_dir().join(format!("nahas-mtwarm-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let registry = builtin_registry();
    let params = SubstrateParams::new(NasSpaceId::EfficientNet, SAMPLES, 20, SEED)
        .targets(vec![0.4, 0.5, 0.6]);
    let scenarios =
        compile_substrates(&registry, &["multitask-cls-seg".to_string()], &params).unwrap();
    let kinds = scenarios[0].tasks_key();
    let path = eval_cache_file_tasks(&dir, NasSpaceId::EfficientNet, &kinds, SEED);
    let fp = eval_fingerprint_tasks(NasSpaceId::EfficientNet, &kinds, SEED);
    println!(
        "multi-task warm start: {} scenarios x {SAMPLES} samples x {} tasks, cache file {}\n",
        scenarios.len(),
        kinds.len(),
        path.display()
    );

    // Cold pass: pays the full simulator bill, spills every entry.
    let store = CacheStore::open(&path, &fp).expect("open cache store");
    let cold_broker = broker(&scenarios, store);
    let t0 = Instant::now();
    let cold = run_sweep(&cold_broker, &scenarios);
    let cold_s = t0.elapsed().as_secs_f64();
    let cold_backend = cold_broker.backend_stats().requests;
    drop(cold_broker); // Flush the spill file.
    println!(
        "  cold: {cold_s:>6.2}s  {} evals, {} backend requests, {} persisted hits, \
         {} cross-scenario hits",
        cold.eval_stats.evals,
        cold_backend,
        cold.eval_stats.persisted_hits,
        cold.eval_stats.cross_session_hits
    );

    // Warm pass: fresh process state, same file.
    let t0 = Instant::now();
    let store = CacheStore::open(&path, &fp).expect("reopen cache store");
    let load_s = t0.elapsed().as_secs_f64();
    let loaded = store.loaded_len();
    let warm_broker = broker(&scenarios, store);
    let t0 = Instant::now();
    let warm = run_sweep(&warm_broker, &scenarios);
    let warm_s = t0.elapsed().as_secs_f64();
    let warm_backend = warm_broker.backend_stats().requests;
    println!(
        "  warm: {warm_s:>6.2}s  {} evals, {} backend requests, {} persisted hits \
         ({loaded} entries loaded in {:.1}ms)",
        warm.eval_stats.evals,
        warm_backend,
        warm.eval_stats.persisted_hits,
        load_s * 1e3
    );

    assert_eq!(warm_backend, 0, "fully-warm multi-task sweep must not touch the backend");
    assert!(warm.eval_stats.persisted_hits > 0);
    assert!(cold.eval_stats.cross_session_hits > 0, "same-seed scenarios must share work");
    // Per-task frontier equivalence: warm replay is the same sweep.
    assert_eq!(cold.task_frontiers, warm.task_frontiers, "warm per-task frontiers diverged");
    for ((_, a), (_, b)) in cold.union.iter().zip(&warm.union) {
        assert_eq!(a.len(), b.len(), "warm union frontier diverged");
    }
    for (key, front) in &warm.task_frontiers {
        println!("  per-task frontier {key}: {} points", front.len());
    }
    println!("\n  speedup: {:.1}x (cold/warm wall clock)", cold_s / warm_s.max(1e-9));
    let _ = std::fs::remove_dir_all(&dir);
}
