//! §Perf — simulator hot-path A/B: per-eval allocation vs batch-level
//! scratch reuse.
//!
//! `SurrogateSim::evaluate_pure` historically rebuilt the decoded
//! `NetworkIr` (layer `Vec` + name `String`, plus the segmentation
//! variant's second network) from scratch for every sample, and the
//! timing model recomputed its per-config constants for every layer.
//! The hot path now decodes into a caller-owned [`SimScratch`]
//! (`evaluate_pure_in`) and hoists the per-config constants once per
//! network (`CostCtx`). This bench pins the contract and measures the
//! win:
//!
//! * A — the old shape: `evaluate_pure`, fresh allocations per eval;
//! * B — the batch shape: `evaluate_pure_in` with one reused scratch;
//! * the two must produce **bit-identical** `EvalResult`s on the same
//!   random sample set (asserted, not eyeballed), because the broker
//!   memo cache and every equivalence test key on exact bits;
//! * the before/after wall-clock row goes in
//!   `docs/BENCH_TRAJECTORY.md` §perf_sim_hotpath.

use nahas::bench;
use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::{EvalResult, SimScratch, SurrogateSim};
use nahas::util::Rng;

fn bits(r: &EvalResult) -> (bool, u64, u64, u64, u64) {
    (
        r.valid,
        r.acc.to_bits(),
        r.latency_ms.to_bits(),
        r.energy_mj.to_bits(),
        r.area_mm2.to_bits(),
    )
}

fn main() {
    let sim = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let mut rng = Rng::new(7);
    let samples: Vec<(Vec<usize>, Vec<usize>)> =
        (0..256).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect();

    // Contract first: scratch reuse must not change a single bit.
    let mut scratch = SimScratch::default();
    for (nas_d, has_d) in &samples {
        let a = sim.evaluate_pure(nas_d, has_d);
        let b = sim.evaluate_pure_in(nas_d, has_d, &mut scratch);
        assert_eq!(bits(&a), bits(&b), "scratch reuse changed a result for {nas_d:?}");
    }
    println!("bit-identity: {} samples, alloc-per-eval == scratch-reuse", samples.len());

    // A: the pre-optimization shape (allocate per eval).
    let a = bench::bench("sim hot path A: evaluate_pure (alloc per eval)", 5, 40, || {
        let mut acc = 0u64;
        for (nas_d, has_d) in &samples {
            acc ^= sim.evaluate_pure(nas_d, has_d).latency_ms.to_bits();
        }
        acc
    });

    // B: the batch shape (one scratch across the sample set) — what
    // `SurrogateSim::evaluate_batch` and `ParallelSim` workers run.
    let b = bench::bench("sim hot path B: evaluate_pure_in (scratch reuse)", 5, 40, || {
        let mut scratch = SimScratch::default();
        let mut acc = 0u64;
        for (nas_d, has_d) in &samples {
            acc ^= sim.evaluate_pure_in(nas_d, has_d, &mut scratch).latency_ms.to_bits();
        }
        acc
    });

    let per_eval_a = a.mean_ns / samples.len() as f64;
    let per_eval_b = b.mean_ns / samples.len() as f64;
    println!(
        "    -> A {:.2} us/eval, B {:.2} us/eval, speedup {:.2}x \
         ({:.0} evals/s warm path)",
        per_eval_a / 1e3,
        per_eval_b / 1e3,
        per_eval_a / per_eval_b,
        1e9 / per_eval_b
    );

    // Segmentation doubles the decode work (backbone + seg variant),
    // so the scratch win there bounds the multi-task sweeps.
    let seg = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3).segmentation();
    let mut scratch = SimScratch::default();
    for (nas_d, has_d) in samples.iter().take(64) {
        let a = seg.evaluate_pure(nas_d, has_d);
        let b = seg.evaluate_pure_in(nas_d, has_d, &mut scratch);
        assert_eq!(bits(&a), bits(&b), "seg scratch reuse changed a result");
    }
    let sb = bench::bench("sim hot path B (segmentation task)", 5, 20, || {
        let mut scratch = SimScratch::default();
        let mut acc = 0u64;
        for (nas_d, has_d) in &samples {
            acc ^= seg.evaluate_pure_in(nas_d, has_d, &mut scratch).latency_ms.to_bits();
        }
        acc
    });
    println!(
        "    -> segmentation {:.2} us/eval with scratch reuse",
        sb.mean_ns / samples.len() as f64 / 1e3
    );
}
