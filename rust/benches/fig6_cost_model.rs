//! Fig. 6 + Table 2 — cost-model accuracy.
//!
//! Trains the AOT MLP (394-dim features, 3x256 trunk on the fused L1
//! pallas kernel, Adam lr 1e-3, batch 128, loss = MSE(area) + 10 x
//! MSE(latency)) on simulator-labelled joint samples, then reports the
//! holdout predicted-vs-simulated quality and the paper's
//! 5-latency-target retrieval check (§4.1: "average error between the
//! latency target and the estimated latency of the best model ...
//! 0.4%"). Also times the b1/b256 inference paths (the oneshot inner
//! loop). Writes results/fig6_cost_model.csv.

use nahas::bench;
use nahas::costmodel::{self, featurize, CostModel, FEATURE_DIM};
use nahas::metrics;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::runtime::Runtime;
use nahas::util::Rng;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    println!("Table 2 config: 394-dim input, 3x256 ReLU MLP, dropout 0.1, Adam 1e-3,");
    println!("batch 128, loss = MSE(area) + 10*MSE(latency)\n");

    let space = NasSpace::new(NasSpaceId::Evolved);
    let mut rng = Rng::new(6);
    // Paper trains on 500k samples / 600k steps; scaled to this box.
    let (data, norm) = costmodel::generate_dataset(&space, 12000, &mut rng);
    println!("generated {} simulator-labelled samples", data.len());

    let mut rt = Runtime::load(Runtime::default_dir())?;
    let mut cm = CostModel::init(&mut rt, norm, 0)?;
    let (test, train) = data.split_at(512);
    let losses = cm.train(&mut rt, train, 2500, &mut rng)?;
    println!(
        "trained 2500 steps: loss {:.4} -> {:.4}",
        losses[0],
        losses.last().unwrap()
    );

    let feats: Vec<Vec<f32>> = test.iter().map(|s| s.features.clone()).collect();
    let preds = cm.predict(&mut rt, &feats)?;
    let refs: Vec<&costmodel::CostSample> = test.iter().collect();
    let (rel, corr) = costmodel::host::accuracy_metrics(&preds, &refs);
    println!("\nFig. 6 holdout: mean relative latency error {:.1}%, corr {:.3}", rel * 100.0, corr);

    let mut rows = Vec::new();
    for (p, t) in preds.iter().zip(&refs) {
        rows.push(vec![format!("{:.5}", t.latency_ms), format!("{:.5}", p.0)]);
    }
    metrics::write_csv(
        "results/fig6_cost_model.csv",
        &["simulated_latency_ms", "predicted_latency_ms"],
        &rows,
    )?;

    // §4.1 check: search best-model-by-cost-model for 5 latency targets,
    // verify against the simulator.
    println!("\nlatency-target retrieval (paper: avg error 0.4%):");
    let mut errs = Vec::new();
    for t in [0.3, 0.5, 0.8, 1.1, 1.3] {
        // Cheap retrieval: best predicted-latency-under-target from a
        // random pool, then re-simulated.
        let mut best: Option<(f64, &costmodel::CostSample)> = None;
        for (p, s) in preds.iter().zip(&refs) {
            if p.0 <= t && best.map(|(bp, _)| p.0 > bp).unwrap_or(true) {
                best = Some((p.0, s));
            }
        }
        if let Some((pred_lat, s)) = best {
            let err = (pred_lat - s.latency_ms).abs() / t;
            errs.push(err);
            println!(
                "  target {t:.1} ms: predicted {:.3} ms, simulated {:.3} ms ({:.1}% of target)",
                pred_lat,
                s.latency_ms,
                err * 100.0
            );
        }
    }
    let avg = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
    println!("  average |predicted - simulated| / target = {:.1}%", avg * 100.0);

    // Inference-path micro-bench (the oneshot inner loop).
    let mut feat = vec![0.0f32; FEATURE_DIM];
    let has = nahas::has::HasSpace::new();
    featurize(&space, &space.random(&mut rng), &has.baseline_decisions(), &mut feat);
    bench::bench("costmodel predict_one (b1 artifact)", 3, 30, || {
        cm.predict_one(&mut rt, &feat).unwrap()
    });
    let batch: Vec<Vec<f32>> = (0..256).map(|_| feat.clone()).collect();
    bench::bench("costmodel predict x256 (b256 artifact)", 2, 10, || {
        cm.predict(&mut rt, &batch).unwrap()
    });
    println!("total {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
