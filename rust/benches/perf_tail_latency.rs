//! §Perf — dispatch-chunk tail latency: 8 overlapping sessions hammer
//! one broker over a deliberately slow backend (0.5 ms per key), once
//! with drain-the-whole-queue dispatch (the pre-PR-6 path, forced via
//! a huge `--dispatch-chunk`) and once with the default capacity-sized
//! chunks.
//!
//! The measurement is per-batch *wait*: how long one session's
//! `evaluate_batch` call takes wall-clock. Under drain-all, a session
//! whose keys sit at the queue front still rides out the whole
//! mega-dispatch — every batch that piled up behind the backend goes
//! out as one call — so the p99 wait grows with the number of
//! contending sessions. Chunked dispatch bounds each backend call at
//! `capacity()` keys and completes queue-front sessions first, so the
//! tail collapses while the median stays put. Sessions use disjoint
//! key namespaces: no cache hit can hide a dispatch.
//!
//! Chunking is pure scheduling: the bench asserts bit-identical
//! results, identical backend eval counts, and a strictly lower p99
//! for the chunked run. Record the printed trajectory row in
//! `docs/BENCH_TRAJECTORY.md`.

use std::time::{Duration, Instant};

use nahas::search::{EvalBroker, EvalResult, Evaluator};

const SESSIONS: usize = 8;
const BATCHES: usize = 15;
const BATCH: usize = 8;
const PER_KEY: Duration = Duration::from_micros(500);

/// The pure function the backend computes, for bit-identity checks.
fn det_result(nas_d: &[usize], has_d: &[usize]) -> EvalResult {
    let s = nas_d.iter().chain(has_d).sum::<usize>() as f64;
    EvalResult {
        acc: 0.5 + s * 1e-9,
        latency_ms: 1.0 + s,
        energy_mj: 0.25 * s,
        area_mm2: 42.0,
        valid: true,
    }
}

/// Deterministic slow backend: 0.5 ms of "simulation" per key, one
/// sleep per dispatch — so a mega-dispatch holds the backend (and
/// every queue-front waiter) for its whole length.
struct SleepBackend;

impl Evaluator for SleepBackend {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        det_result(nas_d, has_d)
    }

    fn evaluate_batch_tagged(
        &mut self,
        batch: &[(Vec<usize>, Vec<usize>)],
    ) -> Vec<(EvalResult, bool)> {
        std::thread::sleep(PER_KEY * batch.len() as u32);
        batch.iter().map(|(n, h)| (det_result(n, h), true)).collect()
    }

    fn capacity(&self) -> usize {
        8
    }
}

/// Session `t`, batch `b`, slot `j` -> a key no other (t, b, j) makes.
fn key(t: usize, b: usize, j: usize) -> (Vec<usize>, Vec<usize>) {
    let id = t * 10_000 + b * 100 + j;
    (vec![id], vec![id % 5])
}

/// Run the contention pattern; per-batch waits (ms), per-session
/// results, and the broker for its ledgers.
fn run(chunk: Option<usize>) -> (Vec<f64>, Vec<Vec<EvalResult>>, EvalBroker) {
    let mut broker = EvalBroker::new(Box::new(SleepBackend));
    if let Some(c) = chunk {
        broker = broker.with_dispatch_chunk(c);
    }
    let per_session: Vec<(Vec<f64>, Vec<EvalResult>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..SESSIONS)
            .map(|t| {
                let mut session = broker.session();
                s.spawn(move || {
                    let mut waits = Vec::with_capacity(BATCHES);
                    let mut results = Vec::with_capacity(BATCHES * BATCH);
                    for b in 0..BATCHES {
                        let batch: Vec<_> = (0..BATCH).map(|j| key(t, b, j)).collect();
                        let t0 = Instant::now();
                        let r = session.evaluate_batch(&batch);
                        waits.push(t0.elapsed().as_secs_f64() * 1e3);
                        results.extend(r);
                    }
                    (waits, results)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("session panicked")).collect()
    });
    let mut waits = Vec::new();
    let mut results = Vec::new();
    for (w, r) in per_session {
        waits.extend(w);
        results.push(r);
    }
    (waits, results, broker)
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx]
}

fn main() {
    println!(
        "tail latency: {SESSIONS} sessions x {BATCHES} batches x {BATCH} keys, \
         {:?}/key backend\n",
        PER_KEY
    );

    let (mut drain_w, drain_r, drain_broker) = run(Some(usize::MAX));
    let dov = drain_broker.overlap_stats();
    drain_w.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (d50, d99) = (percentile(&drain_w, 50.0), percentile(&drain_w, 99.0));
    println!(
        "  drain-all: p50 {d50:>7.2} ms  p99 {d99:>7.2} ms  \
         ({} dispatches, peak queue depth {})",
        dov.dispatches, dov.peak_queue_depth
    );

    let (mut chunk_w, chunk_r, chunk_broker) = run(None);
    let cov = chunk_broker.overlap_stats();
    chunk_w.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (c50, c99) = (percentile(&chunk_w, 50.0), percentile(&chunk_w, 99.0));
    println!(
        "  chunk {}:   p50 {c50:>7.2} ms  p99 {c99:>7.2} ms  \
         ({} dispatches, {} chunked, peak queue depth {})",
        cov.chunk_limit, cov.dispatches, cov.chunked_dispatches, cov.peak_queue_depth
    );

    // Chunking is pure scheduling: same results, same backend work.
    assert_eq!(
        drain_broker.stats().evals,
        chunk_broker.stats().evals,
        "both runs must evaluate every unique key exactly once"
    );
    assert_eq!(drain_broker.stats().evals, SESSIONS * BATCHES * BATCH);
    for (a, b) in drain_r.iter().zip(&chunk_r) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.acc.to_bits(), y.acc.to_bits(), "results diverged under chunking");
            assert_eq!(x.latency_ms.to_bits(), y.latency_ms.to_bits());
        }
    }
    // The point of the PR: bounded dispatches cut the tail.
    assert!(
        c99 < d99,
        "chunked p99 ({c99:.2} ms) must beat drain-all p99 ({d99:.2} ms)"
    );

    let gain = (d99 - c99) / d99 * 100.0;
    println!("\n  p99 improvement: {gain:.0}% (drain-all {d99:.2} ms -> chunked {c99:.2} ms)");
    println!("\n  trajectory row (docs/BENCH_TRAJECTORY.md):");
    println!(
        "  | perf_tail_latency | drain-all p50/p99: {d50:.2}/{d99:.2} ms \
         | chunk {}: p50/p99 {c50:.2}/{c99:.2} ms | p99 -{gain:.0}% | {} chunked / {} dispatches |",
        cov.chunk_limit, cov.chunked_dispatches, cov.dispatches
    );
}
