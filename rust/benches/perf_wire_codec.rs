//! §Perf — one sweep, two wire codecs: the same fixed batch driven
//! through the service tier over the JSON line protocol (`--wire
//! json`) and the length-prefixed binary frame protocol (`--wire
//! binary`, the default). The codec only changes how the same numbers
//! travel — the server answers both from one result cache and ships
//! raw f64 bits either way — so the bench asserts bit-identical
//! results against the serial simulator, then prints bytes-on-wire
//! and wall-clock for both. Record the printed trajectory row in
//! `docs/BENCH_TRAJECTORY.md`.

use std::time::Instant;

use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::{EvalResult, Evaluator, SurrogateSim};
use nahas::service::{Server, ServiceEvaluator, Wire};
use nahas::util::Rng;

const BATCH: usize = 384;
const CONNS: usize = 4;

fn fixed_batch() -> Vec<(Vec<usize>, Vec<usize>)> {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let mut rng = Rng::new(3);
    (0..BATCH).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect()
}

/// Drive the batch through a fresh server over one wire preference;
/// returns results, wall-clock, and (tx, rx) bytes on the wire. A
/// fresh server per run keeps the comparison fair — a shared one
/// would answer the second codec from a warm result cache.
fn run_wire(wire: Wire, batch: &[(Vec<usize>, Vec<usize>)]) -> (Vec<EvalResult>, f64, u64, u64) {
    let server = Server::spawn("127.0.0.1:0").expect("spawn server");
    let mut ev = ServiceEvaluator::connect_wire(
        &server.addr.to_string(),
        NasSpaceId::EfficientNet,
        3,
        CONNS,
        wire,
    )
    .expect("connect service evaluator");
    let t0 = Instant::now();
    let results = ev.evaluate_batch(batch);
    let dt = t0.elapsed().as_secs_f64();
    let (tx, rx) = ev.wire_bytes();
    server.stop();
    (results, dt, tx, rx)
}

fn bits_equal(a: &EvalResult, b: &EvalResult) -> bool {
    a.valid == b.valid
        && a.acc.to_bits() == b.acc.to_bits()
        && a.latency_ms.to_bits() == b.latency_ms.to_bits()
        && a.energy_mj.to_bits() == b.energy_mj.to_bits()
        && a.area_mm2.to_bits() == b.area_mm2.to_bits()
}

fn main() {
    println!("wire codec sweep: {BATCH} samples, {CONNS} connections, service tier\n");
    let batch = fixed_batch();
    let mut serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
    let want = serial.evaluate_batch(&batch);

    let (json_r, json_s, jtx, jrx) = run_wire(Wire::Json, &batch);
    let (bin_r, bin_s, btx, brx) = run_wire(Wire::Binary, &batch);
    for (i, ((w, j), b)) in want.iter().zip(&json_r).zip(&bin_r).enumerate() {
        assert!(bits_equal(w, j), "sample {i}: JSON wire diverged from the serial simulator");
        assert!(bits_equal(w, b), "sample {i}: binary wire diverged from the serial simulator");
    }

    let (json_bytes, bin_bytes) = (jtx + jrx, btx + brx);
    println!("  json wire    {json_s:>6.3}s  {json_bytes:>9} bytes (tx {jtx} / rx {jrx})");
    println!("  binary wire  {bin_s:>6.3}s  {bin_bytes:>9} bytes (tx {btx} / rx {brx})");
    let shrink = json_bytes as f64 / bin_bytes.max(1) as f64;
    println!("\n  bytes shrink: {shrink:.2}x; results bit-identical across codecs");
    assert!(
        bin_bytes < json_bytes,
        "binary wire must put fewer bytes on the wire than JSON \
         ({bin_bytes} vs {json_bytes})"
    );

    println!("\n  trajectory row (docs/BENCH_TRAJECTORY.md):");
    println!(
        "  | perf_wire_codec | json: {json_s:.3}s, {json_bytes} B | binary: {bin_s:.3}s, \
         {bin_bytes} B | {shrink:.2}x fewer bytes | bit-identical |"
    );
}
