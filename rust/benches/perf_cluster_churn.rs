//! §Perf — elastic membership churn: what a live join and leave cost,
//! and what the warm cache handoff buys the joining host.
//!
//! One warmed 2-host pool; a third host then joins twice — once cold
//! (no warm source) and once with the warm handoff streaming its key
//! range first — and a fresh evaluator replays the same batch against
//! each 3-host pool. The warm join should push the joining host's
//! first-contact simulations to (near) zero and speed up the replay;
//! the leave path is timed for its drain + re-rank cost.

use std::time::{Duration, Instant};

use nahas::cluster::{query_host_stats, ShardedEvaluator};
use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::{joint_key, EvalResult, Evaluator};
use nahas::service::Server;
use nahas::util::Rng;

const BATCH: usize = 384;
const CONNS_PER_HOST: usize = 4;
const SEED: u64 = 3;

fn fixed_batch() -> Vec<(Vec<usize>, Vec<usize>)> {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let mut rng = Rng::new(SEED);
    (0..BATCH).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect()
}

/// Warm a fresh 2-host pool with the batch and return (servers,
/// warm entries) — the starting state both join variants share.
fn warmed_pool(
    batch: &[(Vec<usize>, Vec<usize>)],
) -> (Vec<Server>, Vec<String>, Vec<(Vec<usize>, EvalResult)>) {
    let servers: Vec<Server> =
        (0..2).map(|_| Server::spawn("127.0.0.1:0").expect("spawn server")).collect();
    let hosts: Vec<String> = servers.iter().map(|s| s.addr.to_string()).collect();
    let mut cluster =
        ShardedEvaluator::connect(&hosts, NasSpaceId::EfficientNet, SEED, CONNS_PER_HOST)
            .expect("connect cluster");
    let results = cluster.evaluate_batch(batch);
    let mut entries: Vec<(Vec<usize>, EvalResult)> = Vec::new();
    for ((n, h), r) in batch.iter().zip(&results) {
        let k = joint_key(n, h);
        if !entries.iter().any(|(e, _)| *e == k) {
            entries.push((k, *r));
        }
    }
    (servers, hosts, entries)
}

fn main() {
    println!("membership churn: {BATCH} samples, {CONNS_PER_HOST} conns/host\n");
    let batch = fixed_batch();
    let probe = Duration::from_secs(2);

    let mut replay_tput = [0.0f64; 2];
    for (warm, label) in [(false, "cold join"), (true, "warm join")] {
        let (servers, hosts, entries) = warmed_pool(&batch);
        let mut cluster =
            ShardedEvaluator::connect(&hosts, NasSpaceId::EfficientNet, SEED, CONNS_PER_HOST)
                .expect("connect cluster");
        if warm {
            cluster.warm_source().set(move || entries.clone());
        }
        let joiner = Server::spawn("127.0.0.1:0").expect("spawn joiner");
        let t0 = Instant::now();
        let event = cluster.join_host(&joiner.addr.to_string(), 1.0).expect("join");
        let join_ms = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "  {label:9}  {join_ms:>7.1} ms   {} entries handed off",
            event.handed_off
        );

        // A fresh evaluator (restarted search, same long-lived pool)
        // replays the batch against the grown pool: the joining host's
        // share is either all cold simulation or all cache.
        let grown: Vec<String> = {
            let mut g = hosts.clone();
            g.push(joiner.addr.to_string());
            g
        };
        let mut fresh =
            ShardedEvaluator::connect(&grown, NasSpaceId::EfficientNet, SEED, CONNS_PER_HOST)
                .expect("connect grown cluster");
        let t0 = Instant::now();
        let results = fresh.evaluate_batch(&batch);
        let dt = t0.elapsed().as_secs_f64();
        replay_tput[warm as usize] = BATCH as f64 / dt;
        let valid = results.iter().filter(|r| r.valid).count();
        let js = query_host_stats(&joiner.addr.to_string(), probe).expect("stats probe");
        println!(
            "    replay    {:>8.0} samples/s  joiner: {} sim evals, {} cache hits, \
             {} installed  ({valid} valid)",
            BATCH as f64 / dt,
            js.sim_evals,
            js.cache_hits,
            js.installed
        );
        if warm {
            assert!(js.installed > 0, "warm join handed nothing off");
            assert!(
                js.cache_hits > 0,
                "warm join served nothing from the handed-off cache"
            );
        }

        // Leave: drain (structural — between batches) + re-rank.
        let t0 = Instant::now();
        cluster.leave_host(&joiner.addr.to_string()).expect("leave");
        let leave_ms = t0.elapsed().as_secs_f64() * 1e3;
        println!("    leave     {leave_ms:>7.2} ms");

        joiner.stop();
        for s in servers {
            s.stop();
        }
    }
    println!(
        "\n  warm/cold replay speedup: {:.2}x",
        replay_tput[1] / replay_tput[0]
    );
}
