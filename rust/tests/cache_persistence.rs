//! Cross-run persistence equivalence: a warm re-run from a populated
//! `--cache-dir` must be **bit-identical** to its cold run for the
//! same seed on every backend tier, perform zero backend evaluations
//! when fully warm, and report the savings as
//! `EvalStats::persisted_hits`. A stale-fingerprint, corrupted or
//! truncated cache file must degrade to a clean cold start — never
//! fail the run, never silently replay stale data.

use std::fs;
use std::path::PathBuf;

use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::store::{eval_cache_file, eval_fingerprint};
use nahas::search::{
    run_scenario, run_sweep, CacheStore, CostObjective, EvalBroker, Evaluator, ParallelSim,
    RewardCfg, Scenario, ScenarioOutcome, SurrogateSim, SweepDriver, Task,
};

const SAMPLES: usize = 64;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("nahas-persist-{}-{tag}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// Two joint scenarios (latency + energy objective) and one
/// phase-driver scenario, all on one controller seed — the same shape
/// `tests/sweep_equivalence.rs` pins, small enough to run cold twice
/// per backend.
fn scenarios(seed: u64) -> Vec<Scenario> {
    vec![
        Scenario::new("lat0.4ms", NasSpaceId::EfficientNet, RewardCfg::latency(0.4), seed)
            .samples(SAMPLES)
            .batch(16),
        Scenario::new("energy1mJ", NasSpaceId::EfficientNet, RewardCfg::energy(1.0), seed)
            .samples(SAMPLES)
            .batch(16),
        Scenario::new("lat0.4ms-phase", NasSpaceId::EfficientNet, RewardCfg::latency(0.4), seed)
            .samples(SAMPLES)
            .driver(SweepDriver::Phase),
    ]
}

fn backend(kind: &str, seed: u64) -> Box<dyn Evaluator + Send> {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    match kind {
        "local" => Box::new(SurrogateSim::new(space, seed)),
        "parallel" => Box::new(ParallelSim::new(space, seed, 4)),
        other => panic!("unknown backend kind {other}"),
    }
}

fn assert_scenario_identical(want: &ScenarioOutcome, got: &ScenarioOutcome, ctx: &str) {
    assert_eq!(want.search.history.len(), got.search.history.len(), "{ctx}: history length");
    for (w, g) in want.search.history.iter().zip(&got.search.history) {
        assert_eq!(w.nas_d, g.nas_d, "{ctx}: sample {} nas decisions", w.index);
        assert_eq!(w.has_d, g.has_d, "{ctx}: sample {} has decisions", w.index);
        assert_eq!(w.reward.to_bits(), g.reward.to_bits(), "{ctx}: sample {}", w.index);
        assert_eq!(w.result.acc.to_bits(), g.result.acc.to_bits(), "{ctx}");
        assert_eq!(w.result.latency_ms.to_bits(), g.result.latency_ms.to_bits(), "{ctx}");
        assert_eq!(w.result.energy_mj.to_bits(), g.result.energy_mj.to_bits(), "{ctx}");
        assert_eq!(w.result.area_mm2.to_bits(), g.result.area_mm2.to_bits(), "{ctx}");
    }
    assert_eq!(want.search.num_invalid, got.search.num_invalid, "{ctx}: invalid count");
    assert_eq!(want.selected_hw, got.selected_hw, "{ctx}: selected hw");
    assert_eq!(want.frontier, got.frontier, "{ctx}: frontier");
}

#[test]
fn warm_rerun_is_bit_identical_with_zero_backend_evals() {
    for kind in ["local", "parallel"] {
        for seed in [1u64, 7, 42] {
            let ctx = format!("backend {kind}, seed {seed}");
            let dir = tmp_dir(&format!("warm-{kind}-{seed}"));
            let path =
                eval_cache_file(&dir, NasSpaceId::EfficientNet, Task::Classification, seed);
            let fp = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, seed);
            let scs = scenarios(seed);

            // Cold run: pays the backend bill, spills every entry.
            let store = CacheStore::open(&path, &fp).unwrap();
            let cold_broker = EvalBroker::with_store(backend(kind, seed), store);
            assert_eq!(cold_broker.persisted_loaded(), 0, "{ctx}");
            let cold = run_sweep(&cold_broker, &scs);
            assert_eq!(cold.eval_stats.persisted_hits, 0, "{ctx}");
            let cold_evals = cold_broker.stats().evals;
            assert!(cold_broker.backend_stats().requests > 0, "{ctx}");
            assert_eq!(cold_broker.backend_stats().requests, cold_evals, "{ctx}");
            drop(cold_broker); // Flush-on-drop.

            // Warm re-run: fresh backend and broker, same cache file.
            let store = CacheStore::open(&path, &fp).unwrap();
            assert!(store.discarded().is_none(), "{ctx}: warm open must not discard");
            assert_eq!(store.loaded_len(), cold_evals, "{ctx}: one entry per cold eval");
            let warm_broker = EvalBroker::with_store(backend(kind, seed), store);
            assert_eq!(warm_broker.persisted_loaded(), cold_evals, "{ctx}");
            let warm = run_sweep(&warm_broker, &scs);

            // Bit-identical trajectories and frontiers, scenario by
            // scenario, plus the merged union frontiers.
            for (w, g) in cold.outcomes.iter().zip(&warm.outcomes) {
                assert_scenario_identical(w, g, &format!("{ctx}, {}", w.scenario.name));
            }
            assert_eq!(cold.union, warm.union, "{ctx}: union frontier");

            // Fully warm: zero backend evaluations, all requests served
            // as persisted hits (merged across the sweep's sessions and
            // agreeing with the broker's global view).
            assert_eq!(warm_broker.backend_stats().requests, 0, "{ctx}: backend touched");
            assert_eq!(warm.eval_stats.evals, 0, "{ctx}: warm run evaluated");
            assert!(warm.eval_stats.persisted_hits > 0, "{ctx}: no persisted hits");
            assert_eq!(
                warm.eval_stats.persisted_hits,
                warm_broker.stats().persisted_hits,
                "{ctx}: session deltas must sum to the broker's persisted counter"
            );
            drop(warm_broker);
            let _ = fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn corrupt_or_truncated_cache_degrades_to_clean_cold_start() {
    let seed = 7u64;
    let dir = tmp_dir("damage");
    let path = dir.join("evals.cache");
    let fp = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, seed);
    let sc = scenarios(seed).remove(0);

    // Reference: the scenario with no store at all.
    let want = run_scenario(&EvalBroker::new(backend("local", seed)), &sc);

    // Populate a pristine cache file once.
    {
        let store = CacheStore::open(&path, &fp).unwrap();
        let broker = EvalBroker::with_store(backend("local", seed), store);
        run_scenario(&broker, &sc);
    }
    let pristine = fs::read(&path).unwrap();
    assert!(pristine.starts_with(b"nahas-cache v2 "), "cold run must spill the v2 format");

    // Cut mid-segment (a crash mid-append), append garbage after the
    // last segment (bad magic), and flip a payload byte (checksum
    // mismatch): the eval cache reads strictly, so each must discard
    // the whole file rather than salvage around the damage.
    let truncated = pristine[..pristine.len() - 3].to_vec();
    let bad_magic = {
        let mut b = pristine.clone();
        b.extend_from_slice(&[0x00, 0x01, 0x02]);
        b
    };
    let flipped = {
        let mut b = pristine.clone();
        let i = b.len() - 1;
        b[i] ^= 0x40;
        b
    };
    let damages: Vec<(&str, Vec<u8>)> =
        vec![("truncated", truncated), ("bad magic", bad_magic), ("checksum flip", flipped)];
    for (name, bytes) in damages {
        fs::write(&path, &bytes).unwrap();
        let store = CacheStore::open(&path, &fp).unwrap();
        assert!(store.discarded().is_some(), "{name}: damage must be detected");
        assert_eq!(store.loaded_len(), 0, "{name}: nothing salvaged");
        let broker = EvalBroker::with_store(backend("local", seed), store);
        let got = run_scenario(&broker, &sc);
        assert_scenario_identical(&want, &got, name);
        let stats = broker.stats();
        assert_eq!(stats.persisted_hits, 0, "{name}: cold start cannot have warm hits");
        assert!(broker.backend_stats().requests > 0, "{name}");
        drop(broker);
        // The restarted file is healthy again: a follow-up warm run
        // loads what the cold-start run re-spilled.
        let store = CacheStore::open(&path, &fp).unwrap();
        assert!(store.discarded().is_none(), "{name}: restart left a bad file");
        assert!(store.loaded_len() > 0, "{name}: cold start did not re-spill");
    }
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn v1_text_cache_loads_under_v2_reader_bit_identically() {
    use nahas::search::store::STORE_FORMAT;
    use nahas::search::CacheValue;

    let seed = 7u64;
    let dir = tmp_dir("v1-migrate");
    let path = dir.join("evals.cache");
    let fp = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, seed);
    let sc = scenarios(seed).remove(0);

    // Reference cold run and a populated v2 file to harvest entries.
    let want = run_scenario(&EvalBroker::new(backend("local", seed)), &sc);
    {
        let store = CacheStore::open(&path, &fp).unwrap();
        let broker = EvalBroker::with_store(backend("local", seed), store);
        run_scenario(&broker, &sc);
    }
    let mut store = CacheStore::open(&path, &fp).unwrap();
    assert!(store.discarded().is_none());
    let entries = store.take_loaded();
    assert!(!entries.is_empty());
    drop(store);

    // Rewrite the same entries as a v1 text file — the format earlier
    // releases spilled.
    let mut text = format!("{STORE_FORMAT} {fp}\n");
    for (k, v) in &entries {
        let key: Vec<String> = k.iter().map(|d| d.to_string()).collect();
        text.push_str(&format!("{}|{}\n", key.join(","), v.encode()));
    }
    fs::write(&path, text).unwrap();

    // The v2 reader loads the v1 file bit-identically: a warm run off
    // it replays the whole scenario with zero backend evaluations.
    let store = CacheStore::open(&path, &fp).unwrap();
    assert!(store.discarded().is_none(), "v1 file must stay loadable under the v2 reader");
    assert_eq!(store.loaded_len(), entries.len(), "every v1 entry must load");
    let broker = EvalBroker::with_store(backend("local", seed), store);
    let got = run_scenario(&broker, &sc);
    assert_scenario_identical(&want, &got, "v1 migration");
    assert_eq!(broker.backend_stats().requests, 0, "v1-warmed run touched the backend");
    drop(broker);
    // And opening it migrated the file to the v2 binary format.
    let bytes = fs::read(&path).unwrap();
    assert!(bytes.starts_with(b"nahas-cache v2 "), "v1 file was not migrated to v2");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn killed_sweep_resumes_from_checkpoint_without_reevaluating() {
    use nahas::search::{run_sweep_resumable, SweepCheckpoint};

    let seed = 42u64;
    let dir = tmp_dir("sweep-resume");
    let fp = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, seed);
    let scs = scenarios(seed);

    // Reference: the whole sweep, no checkpointing.
    let want = run_sweep(&EvalBroker::new(backend("local", seed)), &scs);

    // "Killed" run: only the first two scenarios complete before the
    // process dies (simulated by sweeping a prefix of the list).
    {
        let mut ckpt = SweepCheckpoint::open(&dir, &fp).unwrap();
        assert_eq!(ckpt.loaded_len(), 0);
        let broker = EvalBroker::new(backend("local", seed));
        run_sweep_resumable(&broker, &scs[..2], Some(&mut ckpt), 2);
        assert_eq!(ckpt.recorded(), 2);
    }

    // Restart: the completed scenarios replay from the checkpoint —
    // only the unfinished one costs backend work.
    let mut ckpt = SweepCheckpoint::open(&dir, &fp).unwrap();
    assert!(ckpt.discarded().is_none(), "clean checkpoint must reload");
    assert_eq!(ckpt.loaded_len(), 2);
    let broker = EvalBroker::new(backend("local", seed));
    let got = run_sweep_resumable(&broker, &scs, Some(&mut ckpt), scs.len());
    assert_eq!(ckpt.resumed(), 2, "both completed scenarios must resume");
    assert_eq!(ckpt.recorded(), 1, "only the unfinished scenario is recorded");
    assert!(broker.stats().requests > 0, "the unfinished scenario still needs evaluating");
    for (w, g) in want.outcomes.iter().zip(&got.outcomes) {
        assert_scenario_identical(w, g, &format!("resume, {}", w.scenario.name));
    }
    assert_eq!(want.union, got.union, "resume: union frontier");
    drop(broker);

    // Second restart: everything is checkpointed — zero re-evaluations.
    let mut ckpt = SweepCheckpoint::open(&dir, &fp).unwrap();
    assert_eq!(ckpt.loaded_len(), 3);
    let broker = EvalBroker::new(backend("local", seed));
    let again = run_sweep_resumable(&broker, &scs, Some(&mut ckpt), scs.len());
    assert_eq!(ckpt.resumed(), 3);
    assert_eq!(broker.stats().requests, 0, "fully-checkpointed sweep re-evaluated");
    for (w, g) in want.outcomes.iter().zip(&again.outcomes) {
        assert_scenario_identical(w, g, &format!("full resume, {}", w.scenario.name));
    }
    assert_eq!(want.union, again.union, "full resume: union frontier");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn stale_fingerprint_falls_back_to_cold_start() {
    let seed = 42u64;
    let dir = tmp_dir("stale-fp");
    let path = dir.join("evals.cache");
    let sc = Scenario::new("lat0.5ms", NasSpaceId::EfficientNet, RewardCfg::latency(0.5), seed)
        .samples(SAMPLES)
        .batch(16);
    let want = run_scenario(&EvalBroker::new(backend("local", seed)), &sc);

    // Spill under one fingerprint, reopen under another — the shape of
    // a simulator upgrade (SIM_FINGERPRINT bump) between runs.
    {
        let store: CacheStore = CacheStore::open(&path, "eval/old-simulator").unwrap();
        let broker = EvalBroker::with_store(backend("local", seed), store);
        run_scenario(&broker, &sc);
    }
    let fp = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, seed);
    let store = CacheStore::open(&path, &fp).unwrap();
    assert!(
        store.discarded().unwrap().contains("fingerprint mismatch"),
        "stale fingerprint must be rejected, got {:?}",
        store.discarded()
    );
    let broker = EvalBroker::with_store(backend("local", seed), store);
    let got = run_scenario(&broker, &sc);
    assert_scenario_identical(&want, &got, "stale fingerprint");
    assert_eq!(broker.stats().persisted_hits, 0);
    assert!(broker.backend_stats().requests > 0);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn oneshot_oracle_behind_broker_persists_and_warm_starts() {
    // ISSUE 7 acceptance: the oneshot driver performs zero evaluations
    // outside the broker seam — the oracle's traffic IS the broker
    // session's counters — and a warm re-run answers off disk.
    use nahas::has::HasSpace;
    use nahas::search::oneshot::{BrokerOracle, LatencyOracle};
    use nahas::util::Rng;

    let seed = 7u64;
    let dir = tmp_dir("oneshot-oracle");
    let path = eval_cache_file(&dir, NasSpaceId::Proxy, Task::Classification, seed);
    let fp = eval_fingerprint(NasSpaceId::Proxy, Task::Classification, seed);
    let space = NasSpace::new(NasSpaceId::Proxy);
    let has = HasSpace::new();
    let mut rng = Rng::new(seed);
    let pairs: Vec<(Vec<usize>, Vec<usize>)> =
        (0..24).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect();

    // Cold run.
    let store = CacheStore::open(&path, &fp).unwrap();
    let broker =
        EvalBroker::with_store(Box::new(SurrogateSim::new(space.clone(), seed)), store);
    let mut oracle = BrokerOracle::new(&broker);
    let cold: Vec<Option<(f64, f64)>> = pairs.iter().map(|(n, h)| oracle.cost(n, h)).collect();
    let (requests, evals) = oracle.traffic();
    assert_eq!(requests, pairs.len());
    let g = broker.stats();
    assert_eq!(g.requests, requests, "oracle queries outside the broker seam");
    assert_eq!(g.evals, evals);
    assert!(broker.backend_stats().requests > 0);
    drop(oracle);
    drop(broker); // Flush-on-drop.

    // Warm run: fresh broker over the same cache file — bit-identical
    // answers, zero backend work, all persisted hits.
    let store = CacheStore::open(&path, &fp).unwrap();
    assert!(store.discarded().is_none(), "warm open must not discard");
    let broker =
        EvalBroker::with_store(Box::new(SurrogateSim::new(space.clone(), seed)), store);
    let mut oracle = BrokerOracle::new(&broker);
    let warm: Vec<Option<(f64, f64)>> = pairs.iter().map(|(n, h)| oracle.cost(n, h)).collect();
    for (i, (c, w)) in cold.iter().zip(&warm).enumerate() {
        match (c, w) {
            (None, None) => {}
            (Some((cl, ca)), Some((wl, wa))) => {
                assert_eq!(cl.to_bits(), wl.to_bits(), "pair {i}: latency");
                assert_eq!(ca.to_bits(), wa.to_bits(), "pair {i}: area");
            }
            _ => panic!("pair {i}: validity changed across warm start: {c:?} vs {w:?}"),
        }
    }
    let g = broker.stats();
    assert_eq!(g.requests, pairs.len());
    assert!(g.persisted_hits > 0, "warm oracle run had no persisted hits");
    assert_eq!(broker.backend_stats().requests, 0, "warm oracle run touched the backend");
    drop(oracle);
    drop(broker);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn multi_task_cache_never_warm_starts_a_single_task_run() {
    // ISSUE 7 satellite: the scenario's task SET is part of the
    // eval-cache identity — same directory, same space, same seed, but
    // a multi-task sweep and a single-task sweep land in different
    // files under different fingerprints.
    use nahas::search::store::{eval_cache_file_tasks, eval_fingerprint_tasks};
    use nahas::search::{builtin_registry, compile_substrates, MultiTaskEval, SubstrateParams};

    let seed = 7u64;
    let dir = tmp_dir("task-set");
    let space = NasSpaceId::EfficientNet;
    let single = [Task::Classification];
    let multi = [Task::Classification, Task::Segmentation];
    let single_path = eval_cache_file_tasks(&dir, space, &single, seed);
    let multi_path = eval_cache_file_tasks(&dir, space, &multi, seed);
    assert_ne!(single_path, multi_path, "task sets must map to distinct cache files");
    // The one-task form of the task-set API is the classic fingerprint,
    // so pre-existing single-task cache files stay valid.
    assert_eq!(
        eval_fingerprint_tasks(space, &single, seed),
        eval_fingerprint(space, Task::Classification, seed)
    );
    assert_eq!(single_path, eval_cache_file(&dir, space, Task::Classification, seed));

    // Populate the multi-task cache from a registry-compiled sweep.
    let registry = builtin_registry();
    let params = SubstrateParams::new(space, 32, 16, seed).targets(vec![0.5]);
    let scs =
        compile_substrates(&registry, &["multitask-cls-seg".to_string()], &params).unwrap();
    let tasks = scs[0].tasks.as_ref().unwrap().clone();
    {
        let store =
            CacheStore::open(&multi_path, &eval_fingerprint_tasks(space, &multi, seed)).unwrap();
        let backend = Box::new(MultiTaskEval::surrogate(&tasks, space, seed, 1));
        let broker = EvalBroker::with_store(backend, store);
        let out = run_sweep(&broker, &scs);
        assert!(out.eval_stats.evals > 0);
    }
    // A single-task run in the same directory opens a different file:
    // nothing to warm-start from.
    let store =
        CacheStore::open(&single_path, &eval_fingerprint_tasks(space, &single, seed)).unwrap();
    assert_eq!(store.loaded_len(), 0, "single-task run warm-started from a multi-task cache");
    // And force-feeding the multi-task FILE to a single-task run is a
    // fingerprint mismatch — discarded, clean cold start.
    let stale =
        CacheStore::open(&multi_path, &eval_fingerprint_tasks(space, &single, seed)).unwrap();
    assert!(
        stale.discarded().unwrap().contains("fingerprint mismatch"),
        "multi-task cache contents must not replay into a single-task run: {:?}",
        stale.discarded()
    );
    drop(stale);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn multi_task_warm_rerun_is_bit_identical_with_zero_backend_evals() {
    use nahas::search::store::{eval_cache_file_tasks, eval_fingerprint_tasks};
    use nahas::search::{builtin_registry, compile_substrates, MultiTaskEval, SubstrateParams};

    let seed = 1u64;
    let dir = tmp_dir("multitask-warm");
    let space = NasSpaceId::EfficientNet;
    let registry = builtin_registry();
    let params = SubstrateParams::new(space, SAMPLES, 16, seed).targets(vec![0.5, 0.6]);
    let scs =
        compile_substrates(&registry, &["multitask-cls-seg".to_string()], &params).unwrap();
    let kinds = scs[0].tasks_key();
    let tasks = scs[0].tasks.as_ref().unwrap().clone();
    let path = eval_cache_file_tasks(&dir, space, &kinds, seed);
    let fp = eval_fingerprint_tasks(space, &kinds, seed);

    let store = CacheStore::open(&path, &fp).unwrap();
    let cold_broker =
        EvalBroker::with_store(Box::new(MultiTaskEval::surrogate(&tasks, space, seed, 1)), store);
    let cold = run_sweep(&cold_broker, &scs);
    assert!(cold_broker.backend_stats().requests > 0);
    drop(cold_broker);

    let store = CacheStore::open(&path, &fp).unwrap();
    assert!(store.discarded().is_none(), "warm open must not discard");
    let warm_broker =
        EvalBroker::with_store(Box::new(MultiTaskEval::surrogate(&tasks, space, seed, 1)), store);
    let warm = run_sweep(&warm_broker, &scs);
    for (w, g) in cold.outcomes.iter().zip(&warm.outcomes) {
        assert_scenario_identical(w, g, &format!("multi-task warm, {}", w.scenario.name));
    }
    assert_eq!(cold.task_frontiers, warm.task_frontiers, "per-task frontiers");
    assert_eq!(cold.union, warm.union, "union frontier");
    assert_eq!(warm_broker.backend_stats().requests, 0, "warm multi-task touched backend");
    assert!(warm.eval_stats.persisted_hits > 0, "no persisted warm-start hits");
    drop(warm_broker);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn sweep_objectives_still_union_per_objective_when_warm() {
    // Warm-start must not disturb the sweep's merge step: the union
    // frontier per objective of a warm sweep equals the cold one even
    // though every result came off disk.
    let seed = 1u64;
    let dir = tmp_dir("union");
    let path = dir.join("evals.cache");
    let fp = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, seed);
    let scs = scenarios(seed);
    let store = CacheStore::open(&path, &fp).unwrap();
    let cold = run_sweep(&EvalBroker::with_store(backend("local", seed), store), &scs);
    let store = CacheStore::open(&path, &fp).unwrap();
    let warm = run_sweep(&EvalBroker::with_store(backend("local", seed), store), &scs);
    assert_eq!(cold.union.len(), warm.union.len());
    let objectives: Vec<CostObjective> = cold.union.iter().map(|(o, _)| *o).collect();
    assert!(objectives.contains(&CostObjective::Latency));
    assert!(objectives.contains(&CostObjective::Energy));
    assert_eq!(cold.union, warm.union);
    let _ = fs::remove_dir_all(&dir);
}
