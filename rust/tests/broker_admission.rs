//! Concurrent admission control: overlapping session batches over one
//! shared [`EvalBroker`] must never duplicate an in-flight evaluation
//! (a key claimed by one session is *waited on*, not re-dispatched, by
//! every other session that wants it mid-flight), per-session stats
//! deltas must sum exactly to the broker's globals, and every result
//! must stay bit-identical to the serial path for the same seed —
//! whatever the interleaving, the admission limit, or the amount of
//! dispatch coalescing.
//!
//! The deterministic-overlap tests use a *gated* stub backend: its
//! first dispatch blocks until the test opens a gate, so the test can
//! provably park one session mid-dispatch, pile further sessions onto
//! the broker (observed via [`EvalBroker::overlap_stats`]), and only
//! then let the world move. No sleeps-as-synchronization.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::{
    joint_key, CacheStore, EvalBroker, EvalResult, EvalStats, Evaluator, ParallelSim,
    SurrogateSim,
};
use nahas::util::Rng;

/// The pure function every stub backend computes, so any test can
/// check bit-identity of a result from the key alone.
fn det_result(nas_d: &[usize], has_d: &[usize]) -> EvalResult {
    let s = nas_d.iter().chain(has_d).sum::<usize>() as f64;
    EvalResult {
        acc: 0.5 + s * 1e-3,
        latency_ms: 1.0 + s,
        energy_mj: 0.25 * s,
        area_mm2: 42.0,
        valid: true,
    }
}

/// Synthetic sample `i`: distinct joint key per `i`.
fn sample(i: usize) -> (Vec<usize>, Vec<usize>) {
    (vec![i], vec![i % 3])
}

/// Shared observer for stub backends: how often each joint key was
/// actually evaluated by the backend (the duplicate-eval detector).
#[derive(Default)]
struct BackendProbe {
    seen: Mutex<HashMap<Vec<usize>, usize>>,
}

impl BackendProbe {
    fn record(&self, nas_d: &[usize], has_d: &[usize]) {
        *self.seen.lock().unwrap().entry(joint_key(nas_d, has_d)).or_insert(0) += 1;
    }

    fn assert_each_key_evaluated_once(&self, expect_keys: usize, ctx: &str) {
        let seen = self.seen.lock().unwrap();
        assert_eq!(seen.len(), expect_keys, "{ctx}: unique keys reaching the backend");
        for (key, count) in seen.iter() {
            assert_eq!(*count, 1, "{ctx}: key {key:?} dispatched {count} times");
        }
    }
}

/// Stub backend whose FIRST dispatch blocks on a gate (and optionally
/// fails as an uncacheable transport error); later dispatches pass
/// straight through. Advertises a wide capacity so admission is bound
/// by the broker's limit, not the backend hint.
struct GatedBackend {
    probe: Arc<BackendProbe>,
    gate: Arc<(Mutex<bool>, Condvar)>,
    first_call: bool,
    fail_first_call: bool,
    capacity: usize,
}

impl GatedBackend {
    fn new(probe: Arc<BackendProbe>, gate: Arc<(Mutex<bool>, Condvar)>, fail: bool) -> Self {
        GatedBackend { probe, gate, first_call: true, fail_first_call: fail, capacity: 8 }
    }
}

fn open_gate(gate: &Arc<(Mutex<bool>, Condvar)>) {
    let (open, cvar) = &**gate;
    *open.lock().unwrap() = true;
    cvar.notify_all();
}

impl Evaluator for GatedBackend {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        self.probe.record(nas_d, has_d);
        det_result(nas_d, has_d)
    }

    fn evaluate_batch_tagged(
        &mut self,
        batch: &[(Vec<usize>, Vec<usize>)],
    ) -> Vec<(EvalResult, bool)> {
        let first = self.first_call;
        self.first_call = false;
        if first {
            let (open, cvar) = &*self.gate;
            let mut open = open.lock().unwrap();
            while !*open {
                open = cvar.wait(open).unwrap();
            }
        }
        batch
            .iter()
            .map(|(n, h)| {
                self.probe.record(n, h);
                if first && self.fail_first_call {
                    (EvalResult::invalid(), false)
                } else {
                    (det_result(n, h), true)
                }
            })
            .collect()
    }

    fn capacity(&self) -> usize {
        self.capacity
    }
}

/// Stub backend that just takes a while per key — enough contention
/// for the stress test without timing-sensitive assertions.
struct SlowBackend {
    probe: Arc<BackendProbe>,
}

impl Evaluator for SlowBackend {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        self.probe.record(nas_d, has_d);
        det_result(nas_d, has_d)
    }

    fn evaluate_batch_tagged(
        &mut self,
        batch: &[(Vec<usize>, Vec<usize>)],
    ) -> Vec<(EvalResult, bool)> {
        std::thread::sleep(Duration::from_micros(200 * batch.len() as u64));
        batch
            .iter()
            .map(|(n, h)| {
                self.probe.record(n, h);
                (det_result(n, h), true)
            })
            .collect()
    }

    fn capacity(&self) -> usize {
        4
    }
}

/// Poll a broker-observable condition instead of sleeping blind; the
/// deadline turns a would-be deadlock into a loud failure.
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(20);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for: {what}");
        std::thread::sleep(Duration::from_millis(1));
    }
}

fn assert_deltas_sum_to_broker(deltas: &[EvalStats], broker: &EvalBroker, ctx: &str) {
    let merged = deltas.iter().fold(EvalStats::default(), |acc, d| acc.merged(d));
    let global = broker.stats();
    assert_eq!(merged.requests, global.requests, "{ctx}: requests");
    assert_eq!(merged.evals, global.evals, "{ctx}: evals");
    assert_eq!(merged.cache_hits, global.cache_hits, "{ctx}: cache hits");
    assert_eq!(merged.invalid, global.invalid, "{ctx}: invalid");
    assert_eq!(merged.cross_session_hits, global.cross_session_hits, "{ctx}: cross hits");
    assert_eq!(merged.persisted_hits, global.persisted_hits, "{ctx}: persisted hits");
    assert_eq!(merged.inflight_hits, global.inflight_hits, "{ctx}: inflight hits");
}

/// A session that requests a key mid-flight waits on the in-progress
/// evaluation instead of dispatching it again, and batches admitted
/// while the backend is busy coalesce into the next dispatch. Fully
/// deterministic: the first dispatch is parked on a gate until the
/// test has *observed* (via overlap stats) that three session batches
/// are admitted concurrently.
#[test]
fn overlapping_batches_dedup_inflight_keys_and_coalesce() {
    let probe = Arc::new(BackendProbe::default());
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let backend = GatedBackend::new(probe.clone(), gate.clone(), false);
    let broker = EvalBroker::new(Box::new(backend)).with_inflight_limit(3);

    let batch_a: Vec<_> = (0..4).map(sample).collect(); // claims k0..k3
    let batch_b = vec![sample(0), sample(4), sample(5)]; // waits k0, claims k4 k5
    let batch_c = vec![sample(1), sample(6)]; // waits k1, claims k6

    let (ra, rb, rc, stats) = std::thread::scope(|s| {
        let mut sa = broker.session();
        let ba = &batch_a;
        let ha = s.spawn(move || {
            let r = sa.evaluate_batch(ba);
            (r, sa.stats())
        });
        // A is provably mid-dispatch (backend checked out, parked on
        // the gate) once the first dispatch is counted.
        wait_until("session A mid-dispatch", || broker.overlap_stats().dispatches >= 1);

        let mut sb = broker.session();
        let bb = &batch_b;
        let hb = s.spawn(move || {
            let r = sb.evaluate_batch(bb);
            (r, sb.stats())
        });
        let mut sc = broker.session();
        let bc = &batch_c;
        let hc = s.spawn(move || {
            let r = sc.evaluate_batch(bc);
            (r, sc.stats())
        });
        // B and C must be admitted *while* A is still in flight: only
        // then can their k0/k1 requests be mid-flight waits.
        wait_until("three admitted batches", || broker.overlap_stats().peak_admitted >= 3);
        open_gate(&gate);

        let (ra, da) = ha.join().expect("session A panicked");
        let (rb, db) = hb.join().expect("session B panicked");
        let (rc, dc) = hc.join().expect("session C panicked");
        (ra, rb, rc, vec![da, db, dc])
    });

    // No in-flight key was ever dispatched twice: 7 unique keys, one
    // backend evaluation each.
    probe.assert_each_key_evaluated_once(7, "gated overlap");
    let g = broker.stats();
    assert_eq!(g.requests, 9);
    assert_eq!(g.evals, 7, "k0 and k1 must not be re-dispatched for B/C");
    assert_eq!(g.cross_session_hits, 2, "B's k0 and C's k1");
    assert_eq!(g.inflight_hits, 2, "both cross hits were served mid-flight");
    assert_deltas_sum_to_broker(&stats, &broker, "gated overlap");

    // Overlap actually happened, and the second dispatch coalesced
    // B's and C's claims into one backend call.
    let ov = broker.overlap_stats();
    assert_eq!(ov.inflight_limit, 3);
    assert_eq!(ov.peak_admitted, 3);
    assert_eq!(ov.dispatches, 2, "k0..k3, then coalesced k4 k5 k6");
    assert_eq!(ov.coalesced_dispatches, 1);

    // Bit-identical to the pure function whatever session computed or
    // waited on a key.
    for (batch, results) in [(&batch_a, &ra), (&batch_b, &rb), (&batch_c, &rc)] {
        for ((n, h), r) in batch.iter().zip(results) {
            let want = det_result(n, h);
            assert_eq!(r.acc.to_bits(), want.acc.to_bits());
            assert_eq!(r.latency_ms.to_bits(), want.latency_ms.to_bits());
        }
    }
}

/// An uncacheable transport failure wakes every mid-flight waiter with
/// the invalid result, but poisons neither the in-flight table nor the
/// persistent store: the next request for the key retries the backend,
/// and only genuine results ever reach disk.
#[test]
fn transport_failure_wakes_waiters_without_poisoning_table_or_store() {
    let path = std::env::temp_dir()
        .join(format!("nahas-admission-spill-{}.cache", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let probe = Arc::new(BackendProbe::default());
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let backend = GatedBackend::new(probe.clone(), gate.clone(), true);
    let store = CacheStore::open(&path, "eval/admission-test").unwrap();
    let broker = EvalBroker::with_store(Box::new(backend), store);

    let batch_a = vec![sample(0)]; // fails (uncacheable) on dispatch 1
    let batch_b = vec![sample(0), sample(9)]; // waits on k0 mid-flight, claims k9

    let (ra, rb) = std::thread::scope(|s| {
        let mut sa = broker.session();
        let ba = &batch_a;
        let ha = s.spawn(move || sa.evaluate_batch(ba));
        wait_until("session A mid-dispatch", || broker.overlap_stats().dispatches >= 1);
        let mut sb = broker.session();
        let bb = &batch_b;
        let hb = s.spawn(move || sb.evaluate_batch(bb));
        wait_until("session B admitted", || broker.overlap_stats().peak_admitted >= 2);
        open_gate(&gate);
        (ha.join().expect("session A panicked"), hb.join().expect("session B panicked"))
    });

    assert!(!ra[0].valid, "A sees the transport failure");
    assert!(!rb[0].valid, "the waiter wakes with the same failed outcome, no retry yet");
    assert!(rb[1].valid, "B's own claim evaluated normally");
    assert_eq!(broker.stats().evals, 2, "k0 (failed) and k9");
    assert_eq!(broker.stats().inflight_hits, 1, "B's k0 was a mid-flight wait");

    // The failure is not memoized and its in-flight entry is gone: a
    // later session retries the backend and succeeds.
    let mut sc = broker.session();
    let rc = sc.evaluate_batch(&batch_a);
    assert!(rc[0].valid, "retry reaches the backend after the gate");
    assert_eq!(broker.stats().evals, 3);
    assert_eq!(*probe.seen.lock().unwrap().get(&joint_key(&[0], &[0])).unwrap(), 2);

    // And once memoized, no further backend traffic for the key.
    let mut sd = broker.session();
    assert!(sd.evaluate_batch(&batch_a)[0].valid);
    assert_eq!(broker.stats().evals, 3, "memoized success is served from cache");

    // The spill file holds only the two genuine results (k9 and the
    // k0 retry) — the transport failure never reached disk.
    drop((sc, sd, broker));
    let mut reopened: CacheStore = CacheStore::open(&path, "eval/admission-test").unwrap();
    let mut keys: Vec<Vec<usize>> =
        reopened.take_loaded().into_iter().map(|(k, _)| k).collect();
    keys.sort();
    assert_eq!(keys, vec![joint_key(&[0], &[0]), joint_key(&[9], &[0])]);
    let _ = std::fs::remove_file(&path);
}

/// `--broker-inflight 1` restores strictly serial admission: however
/// many sessions pile on, at most one batch is ever in flight.
#[test]
fn inflight_limit_one_serializes_session_batches() {
    let probe = Arc::new(BackendProbe::default());
    let broker =
        EvalBroker::new(Box::new(SlowBackend { probe: probe.clone() })).with_inflight_limit(1);
    let batch: Vec<_> = (0..24).map(sample).collect();
    let stats: Vec<EvalStats> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let mut session = broker.session();
                let batch = &batch;
                s.spawn(move || {
                    let r = session.evaluate_batch(batch);
                    for ((n, h), got) in batch.iter().zip(&r) {
                        assert_eq!(got.acc.to_bits(), det_result(n, h).acc.to_bits());
                    }
                    session.stats()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("session panicked")).collect()
    });
    probe.assert_each_key_evaluated_once(24, "serial limit");
    let ov = broker.overlap_stats();
    assert_eq!(ov.inflight_limit, 1);
    assert_eq!(ov.peak_admitted, 1, "limit 1 must never admit overlapping batches");
    assert_eq!(broker.stats().evals, 24);
    assert_deltas_sum_to_broker(&stats, &broker, "serial limit");
}

/// Stress: 8 sessions hammer one broker with rotated slices of a
/// shared 60-key universe (every session requests every key exactly
/// once, in a different batch order), over a slow backend with full
/// admission overlap. Each unique key must reach the backend exactly
/// once, the counters must balance at every layer, and every result
/// must equal the pure function.
#[test]
fn stress_shared_keys_never_duplicate_backend_evals() {
    const KEYS: usize = 60;
    const SESSIONS: usize = 8;
    let universe: Vec<_> = (0..KEYS).map(sample).collect();
    let probe = Arc::new(BackendProbe::default());
    let broker = EvalBroker::new(Box::new(SlowBackend { probe: probe.clone() }));

    let stats: Vec<EvalStats> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..SESSIONS)
            .map(|t| {
                let mut session = broker.session();
                let universe = &universe;
                s.spawn(move || {
                    // Three batches of 20, starting at a per-session
                    // offset: a rotation of the universe, so sessions
                    // contend on every key but never repeat their own.
                    for b in 0..3 {
                        let batch: Vec<_> = (0..KEYS / 3)
                            .map(|j| universe[(t * 7 + b * (KEYS / 3) + j) % KEYS].clone())
                            .collect();
                        let r = session.evaluate_batch(&batch);
                        for ((n, h), got) in batch.iter().zip(&r) {
                            let want = det_result(n, h);
                            assert_eq!(got.acc.to_bits(), want.acc.to_bits());
                            assert_eq!(got.latency_ms.to_bits(), want.latency_ms.to_bits());
                        }
                    }
                    session.stats()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("session panicked")).collect()
    });

    probe.assert_each_key_evaluated_once(KEYS, "stress");
    let g = broker.stats();
    assert_eq!(g.requests, KEYS * SESSIONS);
    assert_eq!(g.evals, KEYS, "each unique key evaluated exactly once");
    assert_eq!(
        g.cross_session_hits,
        KEYS * (SESSIONS - 1),
        "every non-paying request is a cross-session hit"
    );
    assert!(g.inflight_hits <= g.cross_session_hits);
    assert_eq!(g.invalid, 0);
    assert_deltas_sum_to_broker(&stats, &broker, "stress");
}

/// Overlap over the real evaluation stack: concurrent sessions with
/// overlapping random batches on the parallel backend (admission limit
/// = its worker count) stay bit-identical to the serial
/// [`SurrogateSim`] for the same seed, and the backend still sees only
/// the broker's deduped misses.
#[test]
fn overlapped_parallel_backend_matches_serial_simulator_bit_for_bit() {
    let space = || NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let mut rng = Rng::new(17);
    let pool: Vec<(Vec<usize>, Vec<usize>)> =
        (0..48).map(|_| (space().random(&mut rng), has.random(&mut rng))).collect();

    let broker = EvalBroker::new(Box::new(ParallelSim::new(space(), 3, 4)));
    assert_eq!(broker.overlap_stats().inflight_limit, 4, "defaults to worker capacity");
    let outputs: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let mut session = broker.session();
                let pool = &pool;
                s.spawn(move || {
                    // Overlapping 24-sample windows of the pool.
                    let batch: Vec<_> = pool[t * 8..t * 8 + 24].to_vec();
                    let r = session.evaluate_batch(&batch);
                    (batch, r)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("session panicked")).collect()
    });

    let serial = SurrogateSim::new(space(), 3);
    for (batch, results) in &outputs {
        for ((n, h), got) in batch.iter().zip(results) {
            let want = serial.evaluate_pure(n, h);
            assert_eq!(got.valid, want.valid);
            assert_eq!(got.acc.to_bits(), want.acc.to_bits());
            assert_eq!(got.latency_ms.to_bits(), want.latency_ms.to_bits());
            assert_eq!(got.energy_mj.to_bits(), want.energy_mj.to_bits());
            assert_eq!(got.area_mm2.to_bits(), want.area_mm2.to_bits());
        }
    }
    // The backend saw exactly the broker's deduped misses.
    assert_eq!(broker.backend_stats().requests, broker.stats().evals);
}
