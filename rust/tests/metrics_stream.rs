//! The live metrics stream's three contracts:
//!
//! * **rows reconcile** — every emitted JSONL row parses, cumulative
//!   counters are monotone across rows, the per-session deltas sum to
//!   the broker-wide counters in the *same* row, and the final row
//!   agrees with `EvalBroker::stats()` once the run is quiescent;
//! * **observation is live** — a [`MetricsStreamer`] attached to a
//!   real concurrent sweep writes at least one row while it runs plus
//!   the final row at stop, without deadlocking against dispatches;
//! * **observation is free** — a sweep with the streamer attached
//!   produces bit-identical frontiers to the same sweep without it
//!   (the snapshot seam never perturbs the search).

use std::sync::Arc;
use std::time::Duration;

use nahas::metrics::{MetricsSink, MetricsStreamer};
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::{
    run_sweep, run_sweep_observed, scenario_grid, CostObjective, EvalBroker, Evaluator,
    ParallelSim, Scenario, SurrogateSim, SweepDriver, SweepOutcome, SweepProgress,
};
use nahas::util::json::Json;

fn scenarios(seed: u64) -> Vec<Scenario> {
    scenario_grid(
        &[0.35, 0.5],
        &[CostObjective::Latency, CostObjective::Energy],
        &[SweepDriver::Joint],
        NasSpaceId::EfficientNet,
        64,
        16,
        seed,
    )
}

fn local_broker(seed: u64) -> EvalBroker {
    EvalBroker::new(Box::new(SurrogateSim::new(
        NasSpace::new(NasSpaceId::EfficientNet),
        seed,
    )))
}

fn parallel_broker(seed: u64) -> EvalBroker {
    EvalBroker::new(Box::new(ParallelSim::new(
        NasSpace::new(NasSpaceId::EfficientNet),
        seed,
        4,
    )))
}

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("nahas_metrics_stream_{name}_{}", std::process::id()))
}

fn usize_field(row: &Json, key: &str) -> usize {
    row.get(key)
        .and_then(Json::as_usize)
        .unwrap_or_else(|| panic!("row missing numeric field {key:?}: {row}"))
}

#[test]
fn rows_parse_reconcile_and_match_final_stats() {
    let broker = local_broker(3);
    let dir = tmp_path("reconcile");
    let path = dir.join("rows.jsonl");
    let mut sink = MetricsSink::create(&path).unwrap();

    // Drive two sessions by hand, snapshotting between batches — a
    // deterministic stand-in for the interval thread.
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = nahas::has::HasSpace::new();
    let mut rng = nahas::util::Rng::new(11);
    let mut a = broker.session();
    let mut b = broker.session();
    let mut t = 0.0f64;
    for round in 0..4 {
        let batch: Vec<(Vec<usize>, Vec<usize>)> =
            (0..8).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect();
        if round % 2 == 0 {
            a.evaluate_batch(&batch);
        } else {
            b.evaluate_batch(&batch);
        }
        // Re-issue one earlier key from the other session so the
        // cross-session counters are exercised too.
        if round == 3 {
            a.evaluate_batch(&batch);
        }
        t += 1.0;
        sink.emit(t, &broker.snapshot(), Some((round, 4))).unwrap();
    }

    let text = std::fs::read_to_string(&path).unwrap();
    let rows: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(rows.len(), 4);

    // Cumulative counters are monotone; gauges parse; session deltas
    // sum to the broker-wide counters row by row.
    let mut last = (0usize, 0usize);
    for row in &rows {
        let (req, ev) = (usize_field(row, "requests"), usize_field(row, "evals"));
        assert!(req >= last.0 && ev >= last.1, "counters went backwards: {row}");
        last = (req, ev);
        assert_eq!(usize_field(row, "cache_hits"), req - ev);
        let sessions = row.get("sessions").and_then(Json::as_arr).unwrap();
        let sum =
            |key: &str| sessions.iter().map(|s| usize_field(s, key)).sum::<usize>();
        assert_eq!(sum("requests"), req, "session requests don't sum: {row}");
        assert_eq!(sum("evals"), ev, "session evals don't sum: {row}");
        assert_eq!(sum("cross_session_hits"), usize_field(row, "cross_session_hits"));
        assert_eq!(sum("dispatched_chunks"), usize_field(row, "dispatches"));
    }

    // Quiescent: the last row equals the blocking stats() view.
    let stats = broker.stats();
    let fin = rows.last().unwrap();
    assert_eq!(usize_field(fin, "requests"), stats.requests);
    assert_eq!(usize_field(fin, "evals"), stats.evals);
    assert_eq!(usize_field(fin, "invalid"), stats.invalid);
    assert_eq!(usize_field(fin, "cross_session_hits"), stats.cross_session_hits);
    assert_eq!(usize_field(fin, "queue_depth"), 0);
    assert_eq!(usize_field(fin, "admitted"), 0);
    assert_eq!(usize_field(fin, "scenarios_done"), 3);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streamer_observes_a_live_sweep() {
    let broker = parallel_broker(5);
    let dir = tmp_path("live");
    let path = dir.join("rows.jsonl");
    let progress = Arc::new(SweepProgress::new());
    let streamer = MetricsStreamer::spawn(
        broker.clone(),
        MetricsSink::create(&path).unwrap(),
        Duration::from_millis(60),
        Some(progress.clone()),
    );
    let scs = scenarios(7);
    let out = run_sweep_observed(&broker, &scs, None, scs.len(), Some(&progress));
    let (written, rows) = streamer.stop().unwrap();
    assert_eq!(written, path);
    assert!(rows >= 1, "expected at least the final row");
    assert_eq!(progress.completed(), scs.len());

    let text = std::fs::read_to_string(&path).unwrap();
    let parsed: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(parsed.len(), rows);
    // The final row was emitted after the sweep returned, so it must
    // agree with the merged outcome's totals exactly.
    let fin = parsed.last().unwrap();
    assert_eq!(usize_field(fin, "requests"), out.eval_stats.requests);
    assert_eq!(usize_field(fin, "evals"), out.eval_stats.evals);
    assert_eq!(usize_field(fin, "scenarios_done"), scs.len());
    assert_eq!(usize_field(fin, "scenarios_total"), scs.len());

    std::fs::remove_dir_all(&dir).ok();
}

fn frontier_bits(out: &SweepOutcome) -> Vec<(String, u64, u64, String)> {
    out.union
        .iter()
        .flat_map(|(obj, front)| {
            front.iter().map(move |p| {
                (format!("{obj:?}"), p.acc.to_bits(), p.cost.to_bits(), p.tag.clone())
            })
        })
        .collect()
}

#[test]
fn observation_never_changes_search_results() {
    let scs = scenarios(42);
    let plain = run_sweep(&local_broker(9), &scs);

    let broker = local_broker(9);
    let dir = tmp_path("bitident");
    let progress = Arc::new(SweepProgress::new());
    let streamer = MetricsStreamer::spawn(
        broker.clone(),
        MetricsSink::create(dir.join("rows.jsonl")).unwrap(),
        Duration::from_millis(50),
        Some(progress.clone()),
    );
    let observed = run_sweep_observed(&broker, &scs, None, scs.len(), Some(&progress));
    streamer.stop().unwrap();

    assert_eq!(frontier_bits(&plain), frontier_bits(&observed));
    assert_eq!(plain.eval_stats.requests, observed.eval_stats.requests);
    std::fs::remove_dir_all(&dir).ok();
}
