//! Chunked streaming dispatch: a long shared queue must flow through
//! the backend in `capacity()`-sized chunks — never as one
//! queue-draining mega-batch — so a session whose keys land in an
//! early chunk unblocks as soon as that chunk completes, while later
//! chunks are still queued (or still gated) behind it. The chunking is
//! pure scheduling: results stay bit-identical to the serial
//! simulator, and the per-session `dispatched_chunks` deltas sum to
//! the broker's global dispatch count like every other counter.
//!
//! The ordering test uses a *counting gate* backend: dispatch `k`
//! blocks until the test has released at least `k + 1` calls, so the
//! test can deterministically hold chunk 2 closed while proving the
//! chunk-1 session already returned. No sleeps-as-synchronization.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::{
    joint_key, EvalBroker, EvalResult, EvalStats, Evaluator, ParallelSim, SurrogateSim,
};
use nahas::util::Rng;

/// The pure function every stub backend computes, so any test can
/// check bit-identity of a result from the key alone.
fn det_result(nas_d: &[usize], has_d: &[usize]) -> EvalResult {
    let s = nas_d.iter().chain(has_d).sum::<usize>() as f64;
    EvalResult {
        acc: 0.5 + s * 1e-3,
        latency_ms: 1.0 + s,
        energy_mj: 0.25 * s,
        area_mm2: 42.0,
        valid: true,
    }
}

/// Synthetic sample `i`: distinct joint key per `i`.
fn sample(i: usize) -> (Vec<usize>, Vec<usize>) {
    (vec![i], vec![i % 3])
}

/// Poll a broker-observable condition instead of sleeping blind; the
/// deadline turns a would-be deadlock into a loud failure.
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(20);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for: {what}");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Shared per-dispatch log: the joint keys of every backend call, in
/// call order — the chunk-size and chunk-content witness.
type CallLog = Arc<Mutex<Vec<Vec<Vec<usize>>>>>;

fn record_call(calls: &CallLog, batch: &[(Vec<usize>, Vec<usize>)]) {
    calls.lock().unwrap().push(batch.iter().map(|(n, h)| joint_key(n, h)).collect());
}

fn assert_calls_within_capacity(calls: &CallLog, cap: usize, ctx: &str) {
    for (i, call) in calls.lock().unwrap().iter().enumerate() {
        assert!(
            call.len() <= cap,
            "{ctx}: dispatch {i} carried {} keys, over the {cap}-key chunk limit",
            call.len()
        );
    }
}

/// Stub backend whose dispatch `k` blocks until the test has released
/// `k + 1` calls. Records every call's key list before blocking, so
/// the test can watch chunks arrive while they are still gated.
struct CountingGateBackend {
    calls: CallLog,
    gate: Arc<(Mutex<usize>, Condvar)>,
    call_no: usize,
    capacity: usize,
}

fn release_calls(gate: &Arc<(Mutex<usize>, Condvar)>, n: usize) {
    let (released, cvar) = &**gate;
    *released.lock().unwrap() = n;
    cvar.notify_all();
}

impl Evaluator for CountingGateBackend {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        det_result(nas_d, has_d)
    }

    fn evaluate_batch_tagged(
        &mut self,
        batch: &[(Vec<usize>, Vec<usize>)],
    ) -> Vec<(EvalResult, bool)> {
        let k = self.call_no;
        self.call_no += 1;
        record_call(&self.calls, batch);
        let (released, cvar) = &*self.gate;
        let mut released = released.lock().unwrap();
        while *released <= k {
            released = cvar.wait(released).unwrap();
        }
        drop(released);
        batch.iter().map(|(n, h)| (det_result(n, h), true)).collect()
    }

    fn capacity(&self) -> usize {
        self.capacity
    }
}

/// Recording backend with a small per-key delay — contention for the
/// stats test without timing-sensitive assertions.
struct SlowRecordingBackend {
    calls: CallLog,
}

impl Evaluator for SlowRecordingBackend {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        det_result(nas_d, has_d)
    }

    fn evaluate_batch_tagged(
        &mut self,
        batch: &[(Vec<usize>, Vec<usize>)],
    ) -> Vec<(EvalResult, bool)> {
        record_call(&self.calls, batch);
        std::thread::sleep(Duration::from_micros(100 * batch.len() as u64));
        batch.iter().map(|(n, h)| (det_result(n, h), true)).collect()
    }

    fn capacity(&self) -> usize {
        4
    }
}

/// The heart of streaming dispatch, proven deterministically:
///
/// 1. no dispatch ever exceeds `capacity()` keys (the default chunk);
/// 2. the queue is chunked FIFO — chunk 1 is exactly the first
///    session's keys, chunk 2 exactly the second's;
/// 3. the session whose keys went in chunk 1 RETURNS while chunk 2 is
///    still gated — under drain-all dispatch it would have had to wait
///    for the whole queue.
#[test]
fn chunk_one_session_unblocks_while_chunk_two_still_gated() {
    let calls: CallLog = Arc::new(Mutex::new(Vec::new()));
    let gate = Arc::new((Mutex::new(0usize), Condvar::new()));
    let backend =
        CountingGateBackend { calls: calls.clone(), gate: gate.clone(), call_no: 0, capacity: 2 };
    let broker = EvalBroker::new(Box::new(backend)).with_inflight_limit(3);

    let batch_c = vec![sample(9)]; // occupies the backend (call 0, gated)
    let batch_a = vec![sample(0), sample(1)]; // queued first -> chunk 1
    let batch_b = vec![sample(2), sample(3)]; // queued second -> chunk 2
    let a_done = AtomicBool::new(false);
    let b_done = AtomicBool::new(false);

    let stats = std::thread::scope(|s| {
        let mut sc = broker.session();
        let bc = &batch_c;
        let hc = s.spawn(move || {
            let r = sc.evaluate_batch(bc);
            (r, sc.stats())
        });
        // C is provably mid-dispatch (backend checked out, blocked on
        // the gate) once call 0 is counted.
        wait_until("session C mid-dispatch", || broker.overlap_stats().dispatches >= 1);

        // Admit A, then B, in that order: admission claims a session's
        // keys into the FIFO queue atomically, so once the overlap
        // stats show the admission, the keys are queued.
        let mut sa = broker.session();
        let (ba, ad) = (&batch_a, &a_done);
        let ha = s.spawn(move || {
            let r = sa.evaluate_batch(ba);
            ad.store(true, Ordering::SeqCst);
            (r, sa.stats())
        });
        wait_until("session A admitted", || broker.overlap_stats().peak_admitted >= 2);
        let mut sb = broker.session();
        let (bb, bd) = (&batch_b, &b_done);
        let hb = s.spawn(move || {
            let r = sb.evaluate_batch(bb);
            bd.store(true, Ordering::SeqCst);
            (r, sb.stats())
        });
        wait_until("session B admitted", || broker.overlap_stats().peak_admitted >= 3);

        // Release call 0: C finishes, and the 4-deep queue must go out
        // as TWO capacity-sized chunks, chunk 1 = A's keys.
        release_calls(&gate, 1);
        wait_until("chunk 1 dispatched", || calls.lock().unwrap().len() >= 2);
        assert_eq!(
            calls.lock().unwrap()[1],
            vec![joint_key(&[0], &[0]), joint_key(&[1], &[1])],
            "chunk 1 must be exactly A's keys, FIFO from the queue front"
        );

        // Release call 1 only: A must come back while chunk 2 ([k2,k3])
        // is still gated — the streaming property.
        release_calls(&gate, 2);
        wait_until("session A returned", || a_done.load(Ordering::SeqCst));
        assert!(
            !b_done.load(Ordering::SeqCst),
            "B cannot have returned: its chunk-2 keys are still gated"
        );

        release_calls(&gate, 3);
        let (rc, dc) = hc.join().expect("session C panicked");
        let (ra, da) = ha.join().expect("session A panicked");
        let (rb, db) = hb.join().expect("session B panicked");
        for (batch, results) in [(&batch_c, &rc), (&batch_a, &ra), (&batch_b, &rb)] {
            for ((n, h), r) in batch.iter().zip(results) {
                assert_eq!(r.acc.to_bits(), det_result(n, h).acc.to_bits());
            }
        }
        vec![dc, da, db]
    });

    // Chunk shapes: [k9], then [k0,k1], then [k2,k3] — never more than
    // capacity() keys per dispatch.
    assert_calls_within_capacity(&calls, 2, "gated streaming");
    assert_eq!(calls.lock().unwrap().len(), 3);
    assert_eq!(
        calls.lock().unwrap()[2],
        vec![joint_key(&[2], &[2]), joint_key(&[3], &[0])],
        "chunk 2 must be exactly B's keys"
    );

    // Streaming accounting: only the depth-4 dispatch left keys behind.
    let ov = broker.overlap_stats();
    assert_eq!(ov.chunk_limit, 2, "default chunk = backend capacity");
    assert_eq!(ov.dispatches, 3);
    assert_eq!(ov.chunked_dispatches, 1, "only chunk 1 left keys queued");
    assert_eq!(ov.peak_queue_depth, 4, "A's and B's claims queued together");

    // Per-session chunk counts sum to the broker's dispatch total.
    let driven: usize = stats.iter().map(|d| d.dispatched_chunks).sum();
    assert_eq!(driven, ov.dispatches, "every dispatch driven by exactly one session");
    assert_eq!(broker.stats().dispatched_chunks, ov.dispatches);
}

/// Chunked dispatch is pure scheduling: concurrent sessions with
/// overlapping random batches stay bit-identical to the serial
/// [`SurrogateSim`] for the same seed — at the default chunk AND at
/// the degenerate one-key-per-dispatch extreme — across seeds.
#[test]
fn chunked_dispatch_matches_serial_simulator_bit_for_bit_across_seeds() {
    for seed in [1u64, 7, 42] {
        for chunk in [None, Some(1)] {
            let space = || NasSpace::new(NasSpaceId::EfficientNet);
            let has = HasSpace::new();
            let mut rng = Rng::new(seed);
            let pool: Vec<(Vec<usize>, Vec<usize>)> =
                (0..40).map(|_| (space().random(&mut rng), has.random(&mut rng))).collect();

            let mut broker = EvalBroker::new(Box::new(ParallelSim::new(space(), seed, 4)));
            if let Some(c) = chunk {
                broker = broker.with_dispatch_chunk(c);
            }
            let outputs: Vec<_> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..4)
                    .map(|t| {
                        let mut session = broker.session();
                        let pool = &pool;
                        s.spawn(move || {
                            // Overlapping 16-sample windows of the pool.
                            let batch: Vec<_> = pool[t * 8..t * 8 + 16].to_vec();
                            let r = session.evaluate_batch(&batch);
                            (batch, r)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("session panicked")).collect()
            });

            let serial = SurrogateSim::new(space(), seed);
            for (batch, results) in &outputs {
                for ((n, h), got) in batch.iter().zip(results) {
                    let want = serial.evaluate_pure(n, h);
                    assert_eq!(got.valid, want.valid, "seed {seed} chunk {chunk:?}");
                    assert_eq!(got.acc.to_bits(), want.acc.to_bits());
                    assert_eq!(got.latency_ms.to_bits(), want.latency_ms.to_bits());
                    assert_eq!(got.energy_mj.to_bits(), want.energy_mj.to_bits());
                    assert_eq!(got.area_mm2.to_bits(), want.area_mm2.to_bits());
                }
            }
            // Chunking must not duplicate backend work either.
            assert_eq!(broker.backend_stats().requests, broker.stats().evals);
        }
    }
}

/// Under heavy chunking (chunk 2 on a capacity-4 backend, shared keys,
/// full overlap) the whole stats ledger still balances: per-session
/// deltas — including `dispatched_chunks` — sum exactly to the broker
/// globals, and no dispatch ever exceeds the configured chunk.
#[test]
fn session_stat_deltas_sum_to_broker_globals_under_chunking() {
    const KEYS: usize = 30;
    const SESSIONS: usize = 4;
    let universe: Vec<_> = (0..KEYS).map(sample).collect();
    let calls: CallLog = Arc::new(Mutex::new(Vec::new()));
    let broker = EvalBroker::new(Box::new(SlowRecordingBackend { calls: calls.clone() }))
        .with_dispatch_chunk(2);

    let stats: Vec<EvalStats> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..SESSIONS)
            .map(|t| {
                let mut session = broker.session();
                let universe = &universe;
                s.spawn(move || {
                    // Rotated halves of the universe: sessions contend
                    // on every key but never repeat their own.
                    for b in 0..2 {
                        let batch: Vec<_> = (0..KEYS / 2)
                            .map(|j| universe[(t * 7 + b * (KEYS / 2) + j) % KEYS].clone())
                            .collect();
                        let r = session.evaluate_batch(&batch);
                        for ((n, h), got) in batch.iter().zip(&r) {
                            assert_eq!(got.acc.to_bits(), det_result(n, h).acc.to_bits());
                        }
                    }
                    session.stats()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("session panicked")).collect()
    });

    assert_calls_within_capacity(&calls, 2, "chunk-2 stress");
    let merged = stats.iter().fold(EvalStats::default(), |acc, d| acc.merged(d));
    let g = broker.stats();
    assert_eq!(merged.requests, g.requests, "requests");
    assert_eq!(merged.evals, g.evals, "evals");
    assert_eq!(merged.cache_hits, g.cache_hits, "cache hits");
    assert_eq!(merged.cross_session_hits, g.cross_session_hits, "cross hits");
    assert_eq!(merged.inflight_hits, g.inflight_hits, "inflight hits");
    assert_eq!(merged.dispatched_chunks, g.dispatched_chunks, "dispatched chunks");
    assert_eq!(g.requests, KEYS * SESSIONS);
    assert_eq!(g.evals, KEYS, "each unique key evaluated exactly once");
    let ov = broker.overlap_stats();
    assert_eq!(g.dispatched_chunks, ov.dispatches, "chunk ledger vs overlap ledger");
    assert!(
        ov.dispatches >= KEYS / 2,
        "30 unique keys at 2 per chunk need at least 15 dispatches, saw {}",
        ov.dispatches
    );
}
