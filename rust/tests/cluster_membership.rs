//! Elastic membership: warm cache handoff purity and the admin plan
//! channel.
//!
//! A joining host must receive *exactly* its key range (the entries
//! the post-join ring assigns to it, nothing else), install it
//! all-or-nothing, and answer its first shard traffic from that cache
//! with **zero** simulations — while a mangled handoff stream installs
//! nothing and leaves the host cold but consistent. The plan-file
//! channel behind `nahas cluster join|leave --membership-dir` applies
//! commands between batches with bit-identical results throughout.

use std::time::Duration;

use nahas::cluster::{
    membership, query_host_stats, HashRing, HostServeStats, MembershipCmd, ShardedEvaluator,
};
use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::store::{encode_handoff, serve_fingerprint};
use nahas::search::{joint_key, EvalResult, Evaluator, SurrogateSim};
use nahas::service::{Client, Server, Wire};
use nahas::util::Rng;

const PROBE: Duration = Duration::from_secs(2);

fn stats(addr: &str) -> HostServeStats {
    query_host_stats(addr, PROBE).expect("stats probe failed")
}

fn assert_bits_equal(w: &EvalResult, g: &EvalResult, what: &str) {
    assert_eq!(w.valid, g.valid, "{what}");
    assert_eq!(w.acc.to_bits(), g.acc.to_bits(), "{what}");
    assert_eq!(w.latency_ms.to_bits(), g.latency_ms.to_bits(), "{what}");
    assert_eq!(w.energy_mj.to_bits(), g.energy_mj.to_bits(), "{what}");
    assert_eq!(w.area_mm2.to_bits(), g.area_mm2.to_bits(), "{what}");
}

#[test]
fn warm_handoff_transfers_exactly_the_joining_hosts_range_and_serves_it_cold_free() {
    let seed = 17u64;
    let a = Server::spawn("127.0.0.1:0").unwrap();
    let b = Server::spawn("127.0.0.1:0").unwrap();
    let c = Server::spawn("127.0.0.1:0").unwrap();
    let ab = vec![a.addr.to_string(), b.addr.to_string()];
    let mut cluster =
        ShardedEvaluator::connect(&ab, NasSpaceId::EfficientNet, seed, 2).unwrap();

    // Warm up over {a, b}: every unique key lands in its owner's serve
    // cache and in the warm inventory we hand the evaluator below.
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let mut rng = Rng::new(seed);
    let batch: Vec<(Vec<usize>, Vec<usize>)> =
        (0..48).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect();
    let warm_res = cluster.evaluate_batch(&batch);

    // The warm source, wired exactly as the CLI wires the broker's
    // warm inventory: joint key -> result, one entry per unique key.
    let mut entries: Vec<(Vec<usize>, EvalResult)> = Vec::new();
    for ((n, h), r) in batch.iter().zip(&warm_res) {
        let k = joint_key(n, h);
        if !entries.iter().any(|(e, _)| *e == k) {
            entries.push((k, *r));
        }
    }
    let warm = cluster.warm_source();
    {
        let entries = entries.clone();
        warm.set(move || entries.clone());
    }

    // Join c: its slice streams into its serve cache before it takes
    // any shard traffic.
    let event = cluster.join_host(&c.addr.to_string(), 1.0).unwrap();
    assert_eq!(event.detail, "", "join was not clean");

    // The transferred slice is exactly c's key range on the post-join
    // ring: the valid warm entries whose owner is the new index 2 —
    // nothing more (no foreign keys), nothing less.
    let abc = vec![ab[0].clone(), ab[1].clone(), c.addr.to_string()];
    let ring = HashRing::new(&abc);
    let owned_by_c: Vec<&(Vec<usize>, EvalResult)> =
        entries.iter().filter(|(k, _)| ring.owner(k) == Some(2)).collect();
    let transferred = owned_by_c.iter().filter(|(_, r)| r.valid).count();
    let cold = owned_by_c.len() - transferred;
    assert!(transferred > 0, "seed produced no warm keys for the joining host");
    assert_eq!(event.handed_off, transferred, "handoff != the joining host's key range");
    let cs = stats(&c.addr.to_string());
    assert_eq!(cs.installed, transferred as u64);
    assert_eq!(cs.cache_size, transferred as u64);
    assert_eq!(cs.sim_evals, 0, "a handoff must not simulate anything");

    // Replay the same batch on a *fresh* evaluator over {a, b, c} (a
    // restarted search against the long-lived pool): bit-identical
    // results, and c serves its whole transferred range from the
    // installed cache — zero simulations for it, cold only for the
    // invalid keys the handoff deliberately skipped.
    let a_sim = stats(&a.addr.to_string()).sim_evals;
    let b_sim = stats(&b.addr.to_string()).sim_evals;
    let mut fresh =
        ShardedEvaluator::connect(&abc, NasSpaceId::EfficientNet, seed, 2).unwrap();
    let replay = fresh.evaluate_batch(&batch);
    for (i, (w, g)) in warm_res.iter().zip(&replay).enumerate() {
        assert_bits_equal(w, g, &format!("replay sample {i} diverged"));
    }
    let cs = stats(&c.addr.to_string());
    assert_eq!(cs.sim_evals, cold as u64, "c simulated inside its transferred range");
    assert_eq!(cs.cache_hits, transferred as u64, "c did not serve its range from cache");
    let c_snap = fresh
        .host_snapshots()
        .into_iter()
        .find(|s| s.addr == c.addr.to_string())
        .unwrap();
    assert_eq!(c_snap.evals, owned_by_c.len(), "c did not take exactly its shard share");
    // A join moves keys only *to* the new host, so a and b replay
    // their unchanged ranges purely from their own serve caches.
    assert_eq!(stats(&a.addr.to_string()).sim_evals, a_sim, "a re-simulated after the join");
    assert_eq!(stats(&b.addr.to_string()).sim_evals, b_sim, "b re-simulated after the join");

    a.stop();
    b.stop();
    c.stop();
}

#[test]
fn mangled_handoff_streams_install_nothing_and_leave_the_host_cold_but_consistent() {
    let s = Server::spawn("127.0.0.1:0").unwrap();
    let addr = s.addr.to_string();
    let entries: Vec<(Vec<usize>, String)> = (0..6)
        .map(|i| {
            (
                vec![0, 0, 3, i, i + 1, i + 2],
                format!("{{\"valid\": true, \"latency_ms\": {i}.25}}"),
            )
        })
        .collect();
    let pristine = encode_handoff(&entries);
    let mut client = Client::connect_wire(&addr, Some(PROBE), Wire::Binary).unwrap();
    assert!(client.is_binary(), "fresh server must negotiate the binary wire");

    // Truncated mid-segment: refused whole.
    let err = client
        .install_cache(&serve_fingerprint(), &pristine[..pristine.len() - 3])
        .unwrap_err();
    assert!(err.to_string().contains("refused"), "unexpected error: {err}");
    // One flipped bit: the segment checksum catches it, refused whole.
    let mut flipped = pristine.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x10;
    let err = client.install_cache(&serve_fingerprint(), &flipped).unwrap_err();
    assert!(err.to_string().contains("refused"), "unexpected error: {err}");
    // A stale fingerprint never installs, however clean the bytes.
    let err = client.install_cache("serve/v0/stale", &pristine).unwrap_err();
    assert!(err.to_string().contains("fingerprint mismatch"), "unexpected error: {err}");

    // Cold but consistent: absolutely nothing landed.
    let st = stats(&addr);
    assert_eq!((st.installed, st.cache_size), (0, 0), "a refused handoff half-installed");

    // The pristine stream still lands whole on the same connection.
    assert_eq!(client.install_cache(&serve_fingerprint(), &pristine).unwrap(), entries.len());
    let st = stats(&addr);
    assert_eq!(st.installed, entries.len() as u64);
    assert_eq!(st.cache_size, entries.len() as u64);
    assert_eq!(st.sim_evals, 0);
    s.stop();
}

#[test]
fn plan_file_drives_join_and_leave_between_batches() {
    let seed = 23u64;
    let a = Server::spawn("127.0.0.1:0").unwrap();
    let b = Server::spawn("127.0.0.1:0").unwrap();
    let c = Server::spawn("127.0.0.1:0").unwrap();
    let dir = std::env::temp_dir()
        .join(format!("nahas-membership-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // A command already in the plan predates the evaluator: it must be
    // skipped, not replayed.
    membership::append_cmd(
        &dir,
        &MembershipCmd::Join { addr: "10.255.0.1:1".into(), weight: 1.0 },
    )
    .unwrap();

    let ab = vec![a.addr.to_string(), b.addr.to_string()];
    let mut cluster = ShardedEvaluator::connect(&ab, NasSpaceId::EfficientNet, seed, 1)
        .unwrap()
        .with_membership_dir(dir.clone());
    let mut local = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed);
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let mut rng = Rng::new(seed);
    let mut batch = |n: usize| -> Vec<(Vec<usize>, Vec<usize>)> {
        (0..n).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect()
    };

    let b1 = batch(6);
    let r1 = cluster.evaluate_batch(&b1);
    assert_eq!(cluster.hosts(), 2, "a pre-existing plan line was replayed");

    // Queue a join the way `nahas cluster join` does; it applies
    // before the next batch, not in the middle of one.
    membership::append_cmd(
        &dir,
        &MembershipCmd::Join { addr: c.addr.to_string(), weight: 1.0 },
    )
    .unwrap();
    assert_eq!(cluster.hosts(), 2, "membership changed outside a batch boundary");
    let b2 = batch(6);
    let r2 = cluster.evaluate_batch(&b2);
    assert_eq!(cluster.hosts(), 3);

    membership::append_cmd(&dir, &MembershipCmd::Leave { addr: b.addr.to_string() }).unwrap();
    let b3 = batch(6);
    let r3 = cluster.evaluate_batch(&b3);
    assert_eq!(cluster.hosts(), 2);

    let (events, _) = cluster.membership_log().since(0);
    assert_eq!(events.len(), 2);
    assert_eq!(events[0].action, "join");
    assert_eq!(events[0].addr, c.addr.to_string());
    assert_eq!(events[1].action, "leave");
    assert_eq!(events[1].addr, b.addr.to_string());

    // Bit-identical to the local simulator through every transition.
    for (bt, rs) in [(&b1, &r1), (&b2, &r2), (&b3, &r3)] {
        for (i, ((n, h), g)) in bt.iter().zip(rs).enumerate() {
            assert_bits_equal(&local.evaluate(n, h), g, &format!("sample {i} diverged"));
        }
    }

    let _ = std::fs::remove_dir_all(&dir);
    a.stop();
    b.stop();
    c.stop();
}

#[test]
fn membership_error_paths_reject_without_touching_the_pool() {
    let a = Server::spawn("127.0.0.1:0").unwrap();
    let b = Server::spawn("127.0.0.1:0").unwrap();
    let ab = vec![a.addr.to_string(), b.addr.to_string()];
    let mut cluster =
        ShardedEvaluator::connect(&ab, NasSpaceId::EfficientNet, 1, 1).unwrap();

    let err = cluster.join_host(&a.addr.to_string(), 1.0).unwrap_err();
    assert!(err.to_string().contains("already in the pool"), "{err}");
    let err = cluster.leave_host("10.255.0.1:1").unwrap_err();
    assert!(err.to_string().contains("not in the pool"), "{err}");
    assert_eq!(cluster.hosts(), 2, "a rejected command changed the pool");

    cluster.leave_host(&a.addr.to_string()).unwrap();
    assert_eq!(cluster.hosts(), 1);
    let err = cluster.leave_host(&b.addr.to_string()).unwrap_err();
    assert!(err.to_string().contains("last host"), "{err}");
    assert_eq!(cluster.hosts(), 1);

    a.stop();
    b.stop();
}
