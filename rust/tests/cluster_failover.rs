//! Cluster failover: a search sharded over a host pool must survive a
//! dead host — at connect time or mid-flight — with **bit-identical**
//! results to the serial path (the dead host's key range re-routes to
//! the survivors; values never depend on where they were computed) and
//! an honest down-host count in `EvalStats`.

use std::io::ErrorKind;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use nahas::cluster::{query_host_stats, MembershipCmd, ShardedEvaluator};
use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::store::eval_fingerprint;
use nahas::search::{
    joint_search, CacheStore, EvalBroker, Evaluator, RewardCfg, SearchCfg, SearchOutcome,
    SurrogateSim, Task,
};
use nahas::service::Server;

const SAMPLES: usize = 96;

fn run(ev: &mut dyn Evaluator, seed: u64) -> SearchOutcome {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(&space, &has);
    let mut ctl = PpoController::new(&cards);
    let cfg = SearchCfg::new(SAMPLES, RewardCfg::latency(0.4), seed);
    joint_search(ev, &mut ctl, &layout, None, None, &cfg)
}

fn assert_same_trajectory(want: &SearchOutcome, got: &SearchOutcome) {
    assert_eq!(want.history.len(), got.history.len());
    for (w, g) in want.history.iter().zip(&got.history) {
        assert_eq!(w.nas_d, g.nas_d, "sample {}", w.index);
        assert_eq!(w.has_d, g.has_d, "sample {}", w.index);
        assert_eq!(w.reward.to_bits(), g.reward.to_bits(), "sample {}", w.index);
        assert_eq!(w.result.acc.to_bits(), g.result.acc.to_bits(), "sample {}", w.index);
        assert_eq!(
            w.result.latency_ms.to_bits(),
            g.result.latency_ms.to_bits(),
            "sample {}",
            w.index
        );
    }
    assert_eq!(want.num_invalid, got.num_invalid);
}

/// A host that accepts TCP connections and immediately drops them:
/// `connect` succeeds, every roundtrip fails. This is the worst kind
/// of dead host — it looks alive to the pool until queried.
fn black_hole() -> (String, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    listener.set_nonblocking(true).unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let handle = std::thread::spawn(move || {
        while !stop2.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => drop(stream),
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                Err(_) => break,
            }
        }
    });
    (addr, stop, handle)
}

#[test]
fn search_survives_black_hole_host_mid_flight() {
    let seed = 7u64;
    let mut serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed);
    let want = run(&mut serial, seed);

    let s1 = Server::spawn("127.0.0.1:0").unwrap();
    let s2 = Server::spawn("127.0.0.1:0").unwrap();
    let (bh_addr, bh_stop, bh_handle) = black_hole();
    let hosts = vec![s1.addr.to_string(), bh_addr.clone(), s2.addr.to_string()];
    let mut cluster = ShardedEvaluator::connect(&hosts, NasSpaceId::EfficientNet, seed, 2)
        .expect("black hole accepts connects, so the pool starts 3/3 up");
    assert_eq!(cluster.hosts_up(), 3);

    let got = run(&mut cluster, seed);
    assert_same_trajectory(&want, &got);

    // The first batch that routed a key to the black hole marked it
    // down; its range moved to the survivors and stayed there.
    let st = &got.eval_stats;
    assert_eq!(st.hosts_down, 1, "exactly the black hole is down: {st:?}");
    assert_eq!(st.requests, SAMPLES);
    assert_eq!(st.evals + st.cache_hits, st.requests);
    let bh = st.per_host.iter().find(|h| h.host == bh_addr).unwrap();
    assert!(bh.down, "black hole not marked down");
    assert_eq!(bh.evals, 0, "black hole cannot have answered anything");
    let survivor_evals: usize = st.per_host.iter().filter(|h| !h.down).map(|h| h.evals).sum();
    assert!(survivor_evals > 0);

    bh_stop.store(true, Ordering::Relaxed);
    bh_handle.join().unwrap();
    s1.stop();
    s2.stop();
}

#[test]
fn host_dead_at_connect_starts_down_and_is_skipped() {
    let seed = 3u64;
    let mut serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed);
    let want = run(&mut serial, seed);

    let live = Server::spawn("127.0.0.1:0").unwrap();
    // A port with nothing listening: bind, read, drop.
    let dead = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let hosts = vec![live.addr.to_string(), dead.clone()];
    let mut cluster =
        ShardedEvaluator::connect(&hosts, NasSpaceId::EfficientNet, seed, 2).unwrap();
    assert_eq!(cluster.hosts_up(), 1);

    let got = run(&mut cluster, seed);
    assert_same_trajectory(&want, &got);
    let st = &got.eval_stats;
    assert_eq!(st.hosts_down, 1);
    let d = st.per_host.iter().find(|h| h.host == dead).unwrap();
    assert!(d.down);
    assert_eq!((d.requests, d.evals), (0, 0), "down host must receive no routes");
    live.stop();
}

#[test]
fn entirely_dead_pool_refuses_to_connect() {
    let dead: Vec<String> = (0..2)
        .map(|_| {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        })
        .collect();
    assert!(ShardedEvaluator::connect(&dead, NasSpaceId::EfficientNet, 0, 1).is_err());
}

#[test]
fn transport_failures_never_reach_the_spilled_cache() {
    // A cluster run with a black-holed host, spilling through a
    // store-backed broker: failover keeps every *result* correct, and
    // the non-cacheable transport verdicts must keep every *entry*
    // that reaches disk correct too — reloading the spilled file must
    // yield only values bit-identical to the serial simulator.
    let seed = 5u64;
    let space_id = NasSpaceId::EfficientNet;
    let path = std::env::temp_dir()
        .join(format!("nahas-failover-spill-{}.cache", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let fp = eval_fingerprint(space_id, Task::Classification, seed);

    let s1 = Server::spawn("127.0.0.1:0").unwrap();
    let (bh_addr, bh_stop, bh_handle) = black_hole();
    let hosts = vec![s1.addr.to_string(), bh_addr];
    let cluster = ShardedEvaluator::connect(&hosts, space_id, seed, 2).unwrap();
    let store = CacheStore::open(&path, &fp).unwrap();
    let broker = EvalBroker::with_store(Box::new(cluster), store);
    let mut session = broker.session();
    let got = run(&mut session, seed);
    let mut serial = SurrogateSim::new(NasSpace::new(space_id), seed);
    assert_same_trajectory(&run(&mut serial, seed), &got);
    let evals = broker.stats().evals;
    assert!(evals > 0);
    drop(session);
    drop(broker); // Flush the spill file.

    let mut store: CacheStore = CacheStore::open(&path, &fp).unwrap();
    assert!(store.discarded().is_none());
    let loaded = store.take_loaded();
    // Failover resolved every miss, so every (cacheable) eval spilled.
    assert_eq!(loaded.len(), evals, "one spilled entry per broker eval");
    let nas_len = NasSpace::new(space_id).num_decisions();
    let reference = SurrogateSim::new(NasSpace::new(space_id), seed);
    for (key, r) in &loaded {
        let want = reference.evaluate_pure(&key[..nas_len], &key[nas_len..]);
        assert_eq!(want.valid, r.valid, "poisoned entry for key {key:?}");
        assert_eq!(want.acc.to_bits(), r.acc.to_bits());
        assert_eq!(want.latency_ms.to_bits(), r.latency_ms.to_bits());
        assert_eq!(want.energy_mj.to_bits(), r.energy_mj.to_bits());
        assert_eq!(want.area_mm2.to_bits(), r.area_mm2.to_bits());
    }

    bh_stop.store(true, Ordering::Relaxed);
    bh_handle.join().unwrap();
    s1.stop();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn all_hosts_down_spills_nothing() {
    // A pool that is only a black hole: every sample fails as a
    // non-cacheable transport invalid. The spilled cache file must
    // stay empty — persisting those invalids would starve every later
    // warm-started run of its retry.
    let seed = 9u64;
    let space_id = NasSpaceId::EfficientNet;
    let path = std::env::temp_dir()
        .join(format!("nahas-failover-poison-{}.cache", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let fp = eval_fingerprint(space_id, Task::Classification, seed);

    let (bh_addr, bh_stop, bh_handle) = black_hole();
    let cluster = ShardedEvaluator::connect(&[bh_addr], space_id, seed, 1)
        .expect("a black hole accepts connections");
    let store = CacheStore::open(&path, &fp).unwrap();
    let broker = EvalBroker::with_store(Box::new(cluster), store);
    let mut session = broker.session();
    let space = NasSpace::new(space_id);
    let has = HasSpace::new();
    let mut rng = nahas::util::Rng::new(seed);
    let batch: Vec<(Vec<usize>, Vec<usize>)> =
        (0..4).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect();
    let results = session.evaluate_batch(&batch);
    assert!(results.iter().all(|r| !r.valid), "no host could have answered");
    drop(session);
    drop(broker);

    let mut store: CacheStore = CacheStore::open(&path, &fp).unwrap();
    assert!(store.discarded().is_none());
    assert_eq!(store.take_loaded().len(), 0, "transport failures were spilled");

    bh_stop.store(true, Ordering::Relaxed);
    bh_handle.join().unwrap();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn membership_churn_mid_sweep_is_bit_identical_with_zero_duplicate_evals() {
    // Churn choreography: a third host joins mid-sweep and one of the
    // founding hosts leaves a little later. The trajectory must be
    // bit-identical to the same sweep on a static pool, with the same
    // broker eval count, and *zero* duplicate backend evaluations —
    // every unique key simulated exactly once across the whole
    // (changing) pool, counted server-side.
    let seed = 11u64;

    // Reference: the same sweep through a broker over a static pool.
    let (static_servers, static_hosts) = {
        let servers: Vec<Server> =
            (0..2).map(|_| Server::spawn("127.0.0.1:0").unwrap()).collect();
        let hosts: Vec<String> = servers.iter().map(|s| s.addr.to_string()).collect();
        (servers, hosts)
    };
    let static_cluster =
        ShardedEvaluator::connect(&static_hosts, NasSpaceId::EfficientNet, seed, 2).unwrap();
    // Drain-all dispatch on both brokers: one backend call per
    // controller batch, so the evaluator's batch clock (which the
    // membership schedule runs on) counts controller batches 0..=5.
    let static_broker = EvalBroker::new(Box::new(static_cluster)).with_dispatch_chunk(usize::MAX);
    let mut static_session = static_broker.session();
    let want = run(&mut static_session, seed);
    let static_evals = static_broker.stats().evals;
    drop(static_session);
    for s in static_servers {
        s.stop();
    }

    // Churn run: start on {a, b}; c joins before batch 2, b leaves
    // before batch 4 (96 samples / batch 16 = 6 batches, so both land
    // strictly mid-run).
    let a = Server::spawn("127.0.0.1:0").unwrap();
    let b = Server::spawn("127.0.0.1:0").unwrap();
    let c = Server::spawn("127.0.0.1:0").unwrap();
    let hosts = vec![a.addr.to_string(), b.addr.to_string()];
    let mut cluster =
        ShardedEvaluator::connect(&hosts, NasSpaceId::EfficientNet, seed, 2).unwrap();
    cluster
        .schedule_membership(2, MembershipCmd::Join { addr: c.addr.to_string(), weight: 1.0 });
    cluster.schedule_membership(4, MembershipCmd::Leave { addr: b.addr.to_string() });
    let log = cluster.membership_log();
    let broker = EvalBroker::new(Box::new(cluster)).with_dispatch_chunk(usize::MAX);
    let mut session = broker.session();
    let got = run(&mut session, seed);

    // Bit-identical: routing (and re-routing) decides where a key is
    // evaluated, never what it computes.
    assert_same_trajectory(&want, &got);
    assert_eq!(broker.stats().evals, static_evals, "churn changed the broker eval count");

    // Both transitions were applied, in order, at the expected pool
    // sizes; no warm source is wired here, so the join started cold.
    let (events, _) = log.since(0);
    assert_eq!(events.len(), 2, "expected exactly one join and one leave");
    assert_eq!((events[0].action, events[0].hosts), ("join", 3));
    assert_eq!(events[0].addr, c.addr.to_string());
    assert_eq!(events[0].handed_off, 0, "no warm source: the join must start cold");
    assert_eq!((events[1].action, events[1].hosts), ("leave", 2));
    assert_eq!(events[1].addr, b.addr.to_string());
    assert!(events[0].batch <= events[1].batch);

    // Zero duplicate backend evaluations: summed across all three
    // servers (b still runs after leaving the pool), the backend
    // simulated exactly one eval per broker eval and never served the
    // same key twice (an empty serve cache means any repeat would have
    // been a sim_eval duplicate, and there are none).
    let t = Duration::from_secs(2);
    let stats: Vec<_> = [&a, &b, &c]
        .iter()
        .map(|s| query_host_stats(&s.addr.to_string(), t).expect("stats probe"))
        .collect();
    let sim_evals: u64 = stats.iter().map(|s| s.sim_evals).sum();
    let cache_hits: u64 = stats.iter().map(|s| s.cache_hits).sum();
    assert_eq!(sim_evals, static_evals as u64, "backend evals != broker evals");
    assert_eq!(cache_hits, 0, "a server answered the same key twice");
    assert!(stats[2].sim_evals > 0, "the joining host never took shard traffic");

    drop(session);
    a.stop();
    b.stop();
    c.stop();
}

#[test]
fn single_host_cluster_equals_plain_service_path() {
    // Degenerate pool: one host. The cluster tier must still replay
    // the serial trajectory (routing is the identity).
    let seed = 42u64;
    let mut serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed);
    let want = run(&mut serial, seed);
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let hosts = vec![server.addr.to_string()];
    let mut cluster =
        ShardedEvaluator::connect(&hosts, NasSpaceId::EfficientNet, seed, 4).unwrap();
    let got = run(&mut cluster, seed);
    assert_same_trajectory(&want, &got);
    assert_eq!(got.eval_stats.per_host[0].requests, SAMPLES);
    server.stop();
}
