//! End-to-end search integration: every driver x space x objective
//! combination produces sane outcomes, and the paper's qualitative
//! claims hold at test-sized budgets.

use nahas::has::{validate, HasSpace};
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::evolution::EvolutionController;
use nahas::search::joint::JointLayout;
use nahas::search::phase::phase_search;
use nahas::search::ppo::PpoController;
use nahas::search::reinforce::ReinforceController;
use nahas::search::{
    joint_search, Controller, EvalBroker, RandomController, RewardCfg, SearchCfg, SurrogateSim,
};

fn run_search(
    id: NasSpaceId,
    reward: RewardCfg,
    controller: &str,
    samples: usize,
    seed: u64,
) -> nahas::search::SearchOutcome {
    let space = NasSpace::new(id);
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(&space, &has);
    let mut ctl: Box<dyn Controller> = match controller {
        "ppo" => Box::new(PpoController::new(&cards)),
        "reinforce" => Box::new(ReinforceController::new(&cards)),
        "evolution" => Box::new(EvolutionController::new(cards)),
        _ => Box::new(RandomController::new(cards)),
    };
    let mut ev = SurrogateSim::new(space, seed);
    let cfg = SearchCfg::new(samples, reward, seed);
    joint_search(&mut ev, ctl.as_mut(), &layout, None, None, &cfg)
}

#[test]
fn every_controller_finds_feasible_points_in_every_space() {
    for id in [NasSpaceId::MobileNetV2, NasSpaceId::EfficientNet, NasSpaceId::Evolved] {
        for controller in ["ppo", "reinforce", "evolution", "random"] {
            let out = run_search(id, RewardCfg::latency(0.8), controller, 300, 5);
            let best = out
                .best_feasible
                .unwrap_or_else(|| panic!("{controller} on {id:?}: no feasible sample"));
            assert!(best.result.latency_ms <= 0.8);
            assert!(best.result.acc > 0.5);
            // The winning hardware is statically valid.
            let has = HasSpace::new();
            assert!(validate(&has.decode(&best.has_d)).is_ok());
        }
    }
}

#[test]
fn energy_driven_search_meets_energy_target() {
    let out = run_search(NasSpaceId::Evolved, RewardCfg::energy(1.0), "ppo", 600, 6);
    let best = out.best_feasible.expect("feasible");
    assert!(best.result.energy_mj <= 1.0, "{:?}", best.result);
}

#[test]
fn tighter_target_forces_smaller_models() {
    let loose = run_search(NasSpaceId::EfficientNet, RewardCfg::latency(1.0), "ppo", 600, 7);
    let tight = run_search(NasSpaceId::EfficientNet, RewardCfg::latency(0.3), "ppo", 600, 7);
    let l = loose.best_feasible.unwrap();
    let t = tight.best_feasible.unwrap();
    assert!(t.result.latency_ms < l.result.latency_ms);
    assert!(t.result.acc <= l.result.acc + 0.001, "loose target must not lose accuracy");
}

#[test]
fn phase_search_end_to_end() {
    let space = NasSpace::new(NasSpaceId::Evolved);
    let sim = SurrogateSim::new(NasSpace::new(NasSpaceId::Evolved), 8);
    let broker = EvalBroker::new(Box::new(sim));
    // A realistic (B0-like) initial architecture: scale B1, k=3, exp=6,
    // IBN, filter 1.0 — phase 1 sizes the accelerator for THIS network.
    let mut initial = vec![0usize; space.num_decisions()];
    initial[0] = 1; // compound scale
    for b in 0..space.blocks.len() {
        initial[1 + b * 5 + 1] = 1; // expansion 6
        initial[1 + b * 5 + 3] = 2; // filter x1.0
    }
    let cfg = SearchCfg::new(800, RewardCfg::latency(1.0), 8);
    let out = phase_search(&broker, &space, &initial, &cfg);
    assert_eq!(out.selected_hw.len(), 7);
    assert!(out.has_phase.best.is_some());
    assert!(out.nas_phase.best_feasible.is_some());
}

#[test]
fn phase_search_with_degenerate_initial_arch_collapses() {
    // The paper's Fig. 9 finding — "the initial neural architecture
    // creates a large variance in search quality" — at its extreme: a
    // minimal initial arch makes phase 1 pick a tiny chip that phase 2
    // cannot then fit real models onto.
    let space = NasSpace::new(NasSpaceId::Evolved);
    let sim = SurrogateSim::new(NasSpace::new(NasSpaceId::Evolved), 8);
    let broker = EvalBroker::new(Box::new(sim));
    let initial = vec![0; space.num_decisions()];
    let cfg = SearchCfg::new(800, RewardCfg::latency(1.0), 8);
    let out = phase_search(&broker, &space, &initial, &cfg);
    let feasible_acc =
        out.nas_phase.best_feasible.map(|b| b.result.acc).unwrap_or(0.0);
    assert!(
        feasible_acc < 0.76,
        "degenerate initial arch should cap phase-search quality (got {feasible_acc})"
    );
}

#[test]
fn history_replay_is_deterministic() {
    let a = run_search(NasSpaceId::EfficientNet, RewardCfg::latency(0.5), "ppo", 200, 123);
    let b = run_search(NasSpaceId::EfficientNet, RewardCfg::latency(0.5), "ppo", 200, 123);
    assert_eq!(a.history.len(), b.history.len());
    for (x, y) in a.history.iter().zip(&b.history) {
        assert_eq!(x.nas_d, y.nas_d);
        assert_eq!(x.has_d, y.has_d);
        assert_eq!(x.reward, y.reward);
    }
}

#[test]
fn segmentation_objective_search() {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(&space, &has);
    let mut ev = SurrogateSim::new(space, 9).segmentation();
    let mut ctl = PpoController::new(&cards);
    let cfg = SearchCfg::new(400, RewardCfg::latency(3.5), 9);
    let out = joint_search(&mut ev, &mut ctl, &layout, None, None, &cfg);
    let best = out.best_feasible.expect("feasible seg design");
    assert!((0.5..0.85).contains(&best.result.acc), "mIOU fraction {:?}", best.result.acc);
    assert!(best.result.latency_ms <= 3.5);
}
