//! Sweep determinism: every scenario inside a *concurrent* sweep over
//! a shared [`EvalBroker`] must be bit-identical to the same scenario
//! run standalone with the same seed — same sampled decisions, same
//! rewards, same `best_feasible`, same frontier. Sharing the broker
//! (its backend and its cross-search memo cache) may change how often
//! and where a joint decision is computed, never what any search sees.
//! Pinned for seeds {1, 7, 42} across the `local` and `parallel`
//! backends, and over a two-host `cluster` backend.

use nahas::cluster::ShardedEvaluator;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::{
    builtin_registry, compile_substrates, run_scenario, run_sweep, scenario_grid, CostObjective,
    EvalBroker, Evaluator, MultiTaskEval, ParallelSim, Scenario, ScenarioOutcome, SubstrateParams,
    SurrogateSim, SweepDriver,
};
use nahas::service::Server;

const SAMPLES: usize = 96;

/// The sweep under test: latency x energy targets as joint scenarios
/// (all on one controller seed — the controlled-comparison default,
/// which also guarantees cross-scenario cache traffic), plus one
/// phase-driver scenario.
fn scenarios(seed: u64) -> Vec<Scenario> {
    let mut out = scenario_grid(
        &[0.35, 0.5],
        &[CostObjective::Latency, CostObjective::Energy],
        &[SweepDriver::Joint],
        NasSpaceId::EfficientNet,
        SAMPLES,
        16,
        seed,
    );
    out.push(
        Scenario::new(
            "lat0.5ms-phase",
            NasSpaceId::EfficientNet,
            nahas::search::RewardCfg::latency(0.5),
            seed,
        )
        .samples(SAMPLES)
        .driver(SweepDriver::Phase),
    );
    out
}

fn backend(kind: &str, eval_seed: u64) -> Box<dyn Evaluator + Send> {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    match kind {
        "local" => Box::new(SurrogateSim::new(space, eval_seed)),
        "parallel" => Box::new(ParallelSim::new(space, eval_seed, 4)),
        other => panic!("unknown backend kind {other}"),
    }
}

fn assert_scenario_identical(want: &ScenarioOutcome, got: &ScenarioOutcome, ctx: &str) {
    assert_eq!(want.search.history.len(), got.search.history.len(), "{ctx}: history length");
    for (w, g) in want.search.history.iter().zip(&got.search.history) {
        assert_eq!(w.index, g.index, "{ctx}");
        assert_eq!(w.nas_d, g.nas_d, "{ctx}: sample {} nas decisions", w.index);
        assert_eq!(w.has_d, g.has_d, "{ctx}: sample {} has decisions", w.index);
        assert_eq!(w.result.valid, g.result.valid, "{ctx}: sample {}", w.index);
        assert_eq!(w.reward.to_bits(), g.reward.to_bits(), "{ctx}: sample {}", w.index);
        assert_eq!(w.result.acc.to_bits(), g.result.acc.to_bits(), "{ctx}");
        assert_eq!(w.result.latency_ms.to_bits(), g.result.latency_ms.to_bits(), "{ctx}");
        assert_eq!(w.result.energy_mj.to_bits(), g.result.energy_mj.to_bits(), "{ctx}");
        assert_eq!(w.result.area_mm2.to_bits(), g.result.area_mm2.to_bits(), "{ctx}");
    }
    assert_eq!(want.search.num_invalid, got.search.num_invalid, "{ctx}: invalid count");
    assert_eq!(want.selected_hw, got.selected_hw, "{ctx}: selected hw");
    assert_eq!(want.frontier, got.frontier, "{ctx}: frontier");
    match (&want.search.best_feasible, &got.search.best_feasible) {
        (None, None) => {}
        (Some(w), Some(g)) => {
            assert_eq!(w.index, g.index, "{ctx}: best_feasible index");
            assert_eq!(w.nas_d, g.nas_d, "{ctx}: best_feasible nas");
            assert_eq!(w.has_d, g.has_d, "{ctx}: best_feasible hw");
        }
        (w, g) => panic!("{ctx}: best_feasible {:?} vs {:?}", w.is_some(), g.is_some()),
    }
}

fn check_sweep_against_standalone(
    scs: &[Scenario],
    sweep_broker: EvalBroker,
    solo: impl Fn() -> EvalBroker,
    ctx_prefix: &str,
) {
    let sweep = run_sweep(&sweep_broker, scs);
    assert_eq!(sweep.outcomes.len(), scs.len());
    // Bookkeeping balances across the merged per-scenario deltas, the
    // broker's global view agrees, and concurrency paid off: scenarios
    // share a controller seed, so their identical opening batches MUST
    // produce cross-scenario cache hits.
    let m = &sweep.eval_stats;
    assert_eq!(m.requests, scs.iter().map(|s| s.samples).sum::<usize>(), "{ctx_prefix}");
    assert_eq!(m.evals + m.cache_hits, m.requests, "{ctx_prefix}");
    assert!(m.cross_session_hits > 0, "{ctx_prefix}: no cross-scenario cache hits");
    let g = sweep_broker.stats();
    assert_eq!(g.requests, m.requests, "{ctx_prefix}: broker vs merged requests");
    assert_eq!(g.evals, m.evals, "{ctx_prefix}: broker vs merged evals");
    assert_eq!(g.invalid, m.invalid, "{ctx_prefix}: broker vs merged invalid");
    assert_eq!(
        g.cross_session_hits, m.cross_session_hits,
        "{ctx_prefix}: broker vs merged cross hits"
    );
    // A union frontier exists for every objective the sweep ran.
    assert!(!sweep.union.is_empty(), "{ctx_prefix}: no union frontier");
    for (_, front) in &sweep.union {
        assert!(!front.is_empty(), "{ctx_prefix}: empty union frontier");
    }
    for (sc, got) in scs.iter().zip(&sweep.outcomes) {
        let want = run_scenario(&solo(), sc);
        assert_scenario_identical(&want, got, &format!("{ctx_prefix}, scenario {}", sc.name));
    }
}

#[test]
fn sweep_scenarios_bit_identical_to_standalone_local_and_parallel() {
    for kind in ["local", "parallel"] {
        for seed in [1u64, 7, 42] {
            let scs = scenarios(seed);
            check_sweep_against_standalone(
                &scs,
                EvalBroker::new(backend(kind, seed)),
                || EvalBroker::new(backend(kind, seed)),
                &format!("backend {kind}, seed {seed}"),
            );
        }
    }
}

#[test]
fn sweep_over_cluster_backend_matches_standalone_local_runs() {
    // ISSUE 3 acceptance: >= 4 scenarios concurrently over one shared
    // broker whose backend is the two-host cluster tier, each
    // bit-identical to its standalone run (standalone reference: the
    // plain local simulator — remote hardware metrics and local
    // accuracy must agree bit for bit across the whole stack).
    let servers: Vec<Server> =
        (0..2).map(|_| Server::spawn("127.0.0.1:0").unwrap()).collect();
    let hosts: Vec<String> = servers.iter().map(|s| s.addr.to_string()).collect();
    let seed = 7u64;
    let scs = scenarios(seed);
    assert!(scs.len() >= 4, "acceptance demands at least four concurrent scenarios");
    let cluster =
        ShardedEvaluator::connect(&hosts, NasSpaceId::EfficientNet, seed, 2).unwrap();
    check_sweep_against_standalone(
        &scs,
        EvalBroker::new(Box::new(cluster)),
        || EvalBroker::new(backend("local", seed)),
        "backend cluster(2 hosts), seed 7",
    );
    // The servers actually simulated on behalf of the sweep.
    use std::sync::atomic::Ordering;
    let sim_evals: u64 =
        servers.iter().map(|s| s.cache.sim_evals.load(Ordering::Relaxed)).sum();
    assert!(sim_evals > 0);
    for s in servers {
        s.stop();
    }
}

#[test]
fn registry_compiled_grids_bit_identical_to_hand_built_twins() {
    // ISSUE 7 acceptance: a sweep over registry-compiled substrates
    // must replay, bit for bit, the sweep a user would have hand-built
    // from `scenario_grid` before the registry existed — across seeds
    // and evaluator tiers.
    let registry = builtin_registry();
    for kind in ["local", "parallel"] {
        for seed in [1u64, 7, 42] {
            let ctx = format!("backend {kind}, seed {seed}");
            let params = SubstrateParams::new(NasSpaceId::EfficientNet, SAMPLES, 16, seed)
                .targets(vec![0.35, 0.5]);
            let compiled = compile_substrates(
                &registry,
                &["latency-grid".to_string(), "energy-grid".to_string()],
                &params,
            )
            .unwrap();
            let mut twins = scenario_grid(
                &[0.35, 0.5],
                &[CostObjective::Latency],
                &[SweepDriver::Joint],
                NasSpaceId::EfficientNet,
                SAMPLES,
                16,
                seed,
            );
            twins.extend(scenario_grid(
                &[0.35, 0.5],
                &[CostObjective::Energy],
                &[SweepDriver::Joint],
                NasSpaceId::EfficientNet,
                SAMPLES,
                16,
                seed,
            ));
            let names: Vec<&str> = compiled.iter().map(|s| s.name.as_str()).collect();
            let twin_names: Vec<&str> = twins.iter().map(|s| s.name.as_str()).collect();
            assert_eq!(names, twin_names, "{ctx}: compiled scenario names");
            let got = run_sweep(&EvalBroker::new(backend(kind, seed)), &compiled);
            let want = run_sweep(&EvalBroker::new(backend(kind, seed)), &twins);
            for ((w, g), sc) in want.outcomes.iter().zip(&got.outcomes).zip(&compiled) {
                assert_scenario_identical(w, g, &format!("{ctx}, scenario {}", sc.name));
            }
            assert_eq!(want.union, got.union, "{ctx}: union frontier");
        }
    }
}

/// The task-dispatching backend every multi-task scenario set runs on
/// (`workers = 1` is the local tier, `> 1` the parallel tier).
fn multitask_backend(
    scs: &[Scenario],
    seed: u64,
    workers: usize,
) -> Box<dyn Evaluator + Send> {
    let tasks = scs[0].tasks.as_ref().expect("multi-task scenarios");
    Box::new(MultiTaskEval::surrogate(tasks, NasSpaceId::EfficientNet, seed, workers))
}

#[test]
fn multi_task_sweep_bit_identical_to_standalone_with_per_task_frontiers() {
    let registry = builtin_registry();
    for seed in [1u64, 7, 42] {
        let ctx = format!("multi-task, seed {seed}");
        let params = SubstrateParams::new(NasSpaceId::EfficientNet, SAMPLES, 16, seed)
            .targets(vec![0.5, 0.6]);
        let scs =
            compile_substrates(&registry, &["multitask-cls-seg".to_string()], &params).unwrap();
        assert_eq!(scs.len(), 2, "{ctx}: one scenario per target");

        let sweep = run_sweep(&EvalBroker::new(multitask_backend(&scs, seed, 1)), &scs);
        assert_eq!(sweep.outcomes.len(), scs.len(), "{ctx}");
        // Every sample fans out to one evaluation per task, and the
        // same-seed scenarios share their opening batches through the
        // broker's cross-search memo cache.
        let expect: usize = scs.iter().map(|s| s.samples * s.tasks_key().len()).sum();
        assert_eq!(sweep.eval_stats.requests, expect, "{ctx}: per-task fan-out");
        assert!(sweep.eval_stats.cross_session_hits > 0, "{ctx}: no cross-scenario hits");

        // One frontier per (scenario, task), keyed "scenario@task",
        // every point tagged with its own key.
        let keys: Vec<String> = scs
            .iter()
            .flat_map(|sc| ["cls", "seg"].map(|t| format!("{}@{t}", sc.name)))
            .collect();
        assert_eq!(sweep.task_frontiers.len(), keys.len(), "{ctx}");
        for key in &keys {
            let (_, front) = sweep
                .task_frontiers
                .iter()
                .find(|(k, _)| k == key)
                .unwrap_or_else(|| panic!("{ctx}: missing per-task frontier {key}"));
            assert!(!front.is_empty(), "{ctx}: empty frontier {key}");
            assert!(front.iter().all(|p| p.tag == *key), "{ctx}: mistagged points in {key}");
        }

        // Sharing the sweep's broker changed nothing: each scenario is
        // bit-identical to its standalone run, on the local AND the
        // parallel multi-task tier.
        for workers in [1usize, 4] {
            for (sc, got) in scs.iter().zip(&sweep.outcomes) {
                let want = run_scenario(
                    &EvalBroker::new(multitask_backend(&scs, seed, workers)),
                    sc,
                );
                let sctx = format!("{ctx}, workers {workers}, scenario {}", sc.name);
                assert_scenario_identical(&want, got, &sctx);
                assert_eq!(want.task_frontiers, got.task_frontiers, "{sctx}: task frontiers");
            }
        }
    }
}
