//! Sweep determinism: every scenario inside a *concurrent* sweep over
//! a shared [`EvalBroker`] must be bit-identical to the same scenario
//! run standalone with the same seed — same sampled decisions, same
//! rewards, same `best_feasible`, same frontier. Sharing the broker
//! (its backend and its cross-search memo cache) may change how often
//! and where a joint decision is computed, never what any search sees.
//! Pinned for seeds {1, 7, 42} across the `local` and `parallel`
//! backends, and over a two-host `cluster` backend.

use nahas::cluster::ShardedEvaluator;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::{
    run_scenario, run_sweep, scenario_grid, CostObjective, EvalBroker, Evaluator, ParallelSim,
    Scenario, ScenarioOutcome, SurrogateSim, SweepDriver,
};
use nahas::service::Server;

const SAMPLES: usize = 96;

/// The sweep under test: latency x energy targets as joint scenarios
/// (all on one controller seed — the controlled-comparison default,
/// which also guarantees cross-scenario cache traffic), plus one
/// phase-driver scenario.
fn scenarios(seed: u64) -> Vec<Scenario> {
    let mut out = scenario_grid(
        &[0.35, 0.5],
        &[CostObjective::Latency, CostObjective::Energy],
        &[SweepDriver::Joint],
        NasSpaceId::EfficientNet,
        SAMPLES,
        16,
        seed,
    );
    out.push(
        Scenario::new(
            "lat0.5ms-phase",
            NasSpaceId::EfficientNet,
            nahas::search::RewardCfg::latency(0.5),
            seed,
        )
        .samples(SAMPLES)
        .driver(SweepDriver::Phase),
    );
    out
}

fn backend(kind: &str, eval_seed: u64) -> Box<dyn Evaluator + Send> {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    match kind {
        "local" => Box::new(SurrogateSim::new(space, eval_seed)),
        "parallel" => Box::new(ParallelSim::new(space, eval_seed, 4)),
        other => panic!("unknown backend kind {other}"),
    }
}

fn assert_scenario_identical(want: &ScenarioOutcome, got: &ScenarioOutcome, ctx: &str) {
    assert_eq!(want.search.history.len(), got.search.history.len(), "{ctx}: history length");
    for (w, g) in want.search.history.iter().zip(&got.search.history) {
        assert_eq!(w.index, g.index, "{ctx}");
        assert_eq!(w.nas_d, g.nas_d, "{ctx}: sample {} nas decisions", w.index);
        assert_eq!(w.has_d, g.has_d, "{ctx}: sample {} has decisions", w.index);
        assert_eq!(w.result.valid, g.result.valid, "{ctx}: sample {}", w.index);
        assert_eq!(w.reward.to_bits(), g.reward.to_bits(), "{ctx}: sample {}", w.index);
        assert_eq!(w.result.acc.to_bits(), g.result.acc.to_bits(), "{ctx}");
        assert_eq!(w.result.latency_ms.to_bits(), g.result.latency_ms.to_bits(), "{ctx}");
        assert_eq!(w.result.energy_mj.to_bits(), g.result.energy_mj.to_bits(), "{ctx}");
        assert_eq!(w.result.area_mm2.to_bits(), g.result.area_mm2.to_bits(), "{ctx}");
    }
    assert_eq!(want.search.num_invalid, got.search.num_invalid, "{ctx}: invalid count");
    assert_eq!(want.selected_hw, got.selected_hw, "{ctx}: selected hw");
    assert_eq!(want.frontier, got.frontier, "{ctx}: frontier");
    match (&want.search.best_feasible, &got.search.best_feasible) {
        (None, None) => {}
        (Some(w), Some(g)) => {
            assert_eq!(w.index, g.index, "{ctx}: best_feasible index");
            assert_eq!(w.nas_d, g.nas_d, "{ctx}: best_feasible nas");
            assert_eq!(w.has_d, g.has_d, "{ctx}: best_feasible hw");
        }
        (w, g) => panic!("{ctx}: best_feasible {:?} vs {:?}", w.is_some(), g.is_some()),
    }
}

fn check_sweep_against_standalone(
    scs: &[Scenario],
    sweep_broker: EvalBroker,
    solo: impl Fn() -> EvalBroker,
    ctx_prefix: &str,
) {
    let sweep = run_sweep(&sweep_broker, scs);
    assert_eq!(sweep.outcomes.len(), scs.len());
    // Bookkeeping balances across the merged per-scenario deltas, the
    // broker's global view agrees, and concurrency paid off: scenarios
    // share a controller seed, so their identical opening batches MUST
    // produce cross-scenario cache hits.
    let m = &sweep.eval_stats;
    assert_eq!(m.requests, scs.iter().map(|s| s.samples).sum::<usize>(), "{ctx_prefix}");
    assert_eq!(m.evals + m.cache_hits, m.requests, "{ctx_prefix}");
    assert!(m.cross_session_hits > 0, "{ctx_prefix}: no cross-scenario cache hits");
    let g = sweep_broker.stats();
    assert_eq!(g.requests, m.requests, "{ctx_prefix}: broker vs merged requests");
    assert_eq!(g.evals, m.evals, "{ctx_prefix}: broker vs merged evals");
    assert_eq!(g.invalid, m.invalid, "{ctx_prefix}: broker vs merged invalid");
    assert_eq!(
        g.cross_session_hits, m.cross_session_hits,
        "{ctx_prefix}: broker vs merged cross hits"
    );
    // A union frontier exists for every objective the sweep ran.
    assert!(!sweep.union.is_empty(), "{ctx_prefix}: no union frontier");
    for (_, front) in &sweep.union {
        assert!(!front.is_empty(), "{ctx_prefix}: empty union frontier");
    }
    for (sc, got) in scs.iter().zip(&sweep.outcomes) {
        let want = run_scenario(&solo(), sc);
        assert_scenario_identical(&want, got, &format!("{ctx_prefix}, scenario {}", sc.name));
    }
}

#[test]
fn sweep_scenarios_bit_identical_to_standalone_local_and_parallel() {
    for kind in ["local", "parallel"] {
        for seed in [1u64, 7, 42] {
            let scs = scenarios(seed);
            check_sweep_against_standalone(
                &scs,
                EvalBroker::new(backend(kind, seed)),
                || EvalBroker::new(backend(kind, seed)),
                &format!("backend {kind}, seed {seed}"),
            );
        }
    }
}

#[test]
fn sweep_over_cluster_backend_matches_standalone_local_runs() {
    // ISSUE 3 acceptance: >= 4 scenarios concurrently over one shared
    // broker whose backend is the two-host cluster tier, each
    // bit-identical to its standalone run (standalone reference: the
    // plain local simulator — remote hardware metrics and local
    // accuracy must agree bit for bit across the whole stack).
    let servers: Vec<Server> =
        (0..2).map(|_| Server::spawn("127.0.0.1:0").unwrap()).collect();
    let hosts: Vec<String> = servers.iter().map(|s| s.addr.to_string()).collect();
    let seed = 7u64;
    let scs = scenarios(seed);
    assert!(scs.len() >= 4, "acceptance demands at least four concurrent scenarios");
    let cluster =
        ShardedEvaluator::connect(&hosts, NasSpaceId::EfficientNet, seed, 2).unwrap();
    check_sweep_against_standalone(
        &scs,
        EvalBroker::new(Box::new(cluster)),
        || EvalBroker::new(backend("local", seed)),
        "backend cluster(2 hosts), seed 7",
    );
    // The servers actually simulated on behalf of the sweep.
    use std::sync::atomic::Ordering;
    let sim_evals: u64 =
        servers.iter().map(|s| s.cache.sim_evals.load(Ordering::Relaxed)).sum();
    assert!(sim_evals > 0);
    for s in servers {
        s.stop();
    }
}
