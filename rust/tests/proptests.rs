//! Coordinator invariants under the in-crate property harness
//! (`nahas::util::proptest`): decode totality over every search space,
//! validator totality over the HAS space, and memo-cache transparency.

use nahas::has::{validate, HasSpace};
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::{EvalResult, Evaluator, ParallelSim, SurrogateSim};
use nahas::util::proptest;

const ALL_SPACES: [NasSpaceId; 4] = [
    NasSpaceId::MobileNetV2,
    NasSpaceId::EfficientNet,
    NasSpaceId::Evolved,
    NasSpaceId::Proxy,
];

#[test]
fn prop_random_nas_decisions_decode_in_range_for_all_spaces() {
    for id in ALL_SPACES {
        let sp = NasSpace::new(id);
        proptest::check(
            "nas random in-range + decode total",
            proptest::CASES,
            |r| sp.random(r),
            |d| {
                if d.len() != sp.num_decisions() {
                    return Err(format!("length {} != {}", d.len(), sp.num_decisions()));
                }
                for (i, (x, s)) in d.iter().zip(sp.specs()).enumerate() {
                    if *x >= s.cardinality {
                        return Err(format!("decision {i} = {x} >= {}", s.cardinality));
                    }
                }
                // Decode must be total over in-range vectors: no panic,
                // and a structurally sane network.
                let net = sp.decode(d);
                if net.total_macs() == 0 || net.total_params() == 0 {
                    return Err("degenerate network".into());
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_has_decode_and_validate_never_panic() {
    let has = HasSpace::new();
    proptest::check(
        "has decode/validate total",
        proptest::CASES,
        |r| has.random(r),
        |d| {
            let cfg = has.decode(d);
            // Both outcomes are legal; the property is totality (the
            // starvation/capacity rules reject, they must not panic).
            let _ = validate(&cfg);
            Ok(())
        },
    );
}

fn bits_equal(a: &EvalResult, b: &EvalResult) -> bool {
    a.valid == b.valid
        && a.acc.to_bits() == b.acc.to_bits()
        && a.latency_ms.to_bits() == b.latency_ms.to_bits()
        && a.energy_mj.to_bits() == b.energy_mj.to_bits()
        && a.area_mm2.to_bits() == b.area_mm2.to_bits()
}

#[test]
fn prop_memo_cache_returns_same_result_as_fresh_evaluation() {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let fresh = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 5);
    let mut cached = ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), 5, 2);
    proptest::check(
        "memo cache transparent",
        128,
        |r| (space.random(r), has.random(r)),
        |(nas_d, has_d)| {
            let want = fresh.evaluate_pure(nas_d, has_d);
            let miss = cached.evaluate(nas_d, has_d);
            let hit = cached.evaluate(nas_d, has_d);
            if !bits_equal(&want, &miss) {
                return Err(format!("first evaluation diverged: {want:?} vs {miss:?}"));
            }
            if !bits_equal(&want, &hit) {
                return Err(format!("cached evaluation diverged: {want:?} vs {hit:?}"));
            }
            Ok(())
        },
    );
    let st = cached.stats();
    assert_eq!(st.requests, 256);
    assert_eq!(st.evals, 128, "every second request must be a memo hit");
}
