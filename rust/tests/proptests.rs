//! Coordinator invariants under the in-crate property harness
//! (`nahas::util::proptest`): decode totality over every search space,
//! validator totality over the HAS space, memo-cache transparency, and
//! the persistent-store invariants (bit-exact round-trip,
//! append-then-reload equals the in-memory map, no cross-file
//! contamination between concurrently flushing brokers), and the
//! elastic-membership invariants (ring join/leave moves keys only
//! to/from the changed host; a mangled warm-handoff stream decodes
//! all-or-nothing, never panicking and never inventing entries).

use std::collections::HashMap;
use std::path::PathBuf;

use nahas::cluster::HashRing;
use nahas::has::{validate, HasSpace};
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::pareto::{
    frontier, frontier_nd, union_frontier, union_frontier_nd, MultiPoint, Point,
};
use nahas::search::{
    CacheStore, CacheValue, EvalBroker, EvalResult, Evaluator, MemoCache, ParallelSim,
    SurrogateSim,
};
use nahas::util::codec::{self, ByteReader, ReadPolicy};
use nahas::util::proptest;
use nahas::util::Rng;

const ALL_SPACES: [NasSpaceId; 4] = [
    NasSpaceId::MobileNetV2,
    NasSpaceId::EfficientNet,
    NasSpaceId::Evolved,
    NasSpaceId::Proxy,
];

#[test]
fn prop_random_nas_decisions_decode_in_range_for_all_spaces() {
    for id in ALL_SPACES {
        let sp = NasSpace::new(id);
        proptest::check(
            "nas random in-range + decode total",
            proptest::CASES,
            |r| sp.random(r),
            |d| {
                if d.len() != sp.num_decisions() {
                    return Err(format!("length {} != {}", d.len(), sp.num_decisions()));
                }
                for (i, (x, s)) in d.iter().zip(sp.specs()).enumerate() {
                    if *x >= s.cardinality {
                        return Err(format!("decision {i} = {x} >= {}", s.cardinality));
                    }
                }
                // Decode must be total over in-range vectors: no panic,
                // and a structurally sane network.
                let net = sp.decode(d);
                if net.total_macs() == 0 || net.total_params() == 0 {
                    return Err("degenerate network".into());
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_has_decode_and_validate_never_panic() {
    let has = HasSpace::new();
    proptest::check(
        "has decode/validate total",
        proptest::CASES,
        |r| has.random(r),
        |d| {
            let cfg = has.decode(d);
            // Both outcomes are legal; the property is totality (the
            // starvation/capacity rules reject, they must not panic).
            let _ = validate(&cfg);
            Ok(())
        },
    );
}

fn bits_equal(a: &EvalResult, b: &EvalResult) -> bool {
    a.valid == b.valid
        && a.acc.to_bits() == b.acc.to_bits()
        && a.latency_ms.to_bits() == b.latency_ms.to_bits()
        && a.energy_mj.to_bits() == b.energy_mj.to_bits()
        && a.area_mm2.to_bits() == b.area_mm2.to_bits()
}

#[test]
fn prop_memo_cache_returns_same_result_as_fresh_evaluation() {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let fresh = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 5);
    let mut cached = ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), 5, 2);
    proptest::check(
        "memo cache transparent",
        128,
        |r| (space.random(r), has.random(r)),
        |(nas_d, has_d)| {
            let want = fresh.evaluate_pure(nas_d, has_d);
            let miss = cached.evaluate(nas_d, has_d);
            let hit = cached.evaluate(nas_d, has_d);
            if !bits_equal(&want, &miss) {
                return Err(format!("first evaluation diverged: {want:?} vs {miss:?}"));
            }
            if !bits_equal(&want, &hit) {
                return Err(format!("cached evaluation diverged: {want:?} vs {hit:?}"));
            }
            Ok(())
        },
    );
    let st = cached.stats();
    assert_eq!(st.requests, 256);
    assert_eq!(st.evals, 128, "every second request must be a memo hit");
}

// ---- streaming dispatch properties (`nahas::search::broker`) ----

/// Backend that logs the joint keys of every dispatch it receives.
struct RecordingBackend {
    calls: std::sync::Arc<std::sync::Mutex<Vec<Vec<Vec<usize>>>>>,
}

impl Evaluator for RecordingBackend {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        prop_det_result(nas_d, has_d)
    }

    fn evaluate_batch_tagged(
        &mut self,
        batch: &[(Vec<usize>, Vec<usize>)],
    ) -> Vec<(EvalResult, bool)> {
        self.calls
            .lock()
            .unwrap()
            .push(batch.iter().map(|(n, h)| nahas::search::joint_key(n, h)).collect());
        batch.iter().map(|(n, h)| (prop_det_result(n, h), true)).collect()
    }

    fn capacity(&self) -> usize {
        8
    }
}

/// Pure reference function for the recording backend.
fn prop_det_result(nas_d: &[usize], has_d: &[usize]) -> EvalResult {
    let s = nas_d.iter().chain(has_d).sum::<usize>() as f64;
    EvalResult {
        acc: 0.5 + s * 1e-3,
        latency_ms: 1.0 + s,
        energy_mj: 0.25 * s,
        area_mm2: 1.0,
        valid: true,
    }
}

/// Chunked dispatch is a pure partition of the dedup'd queue: for any
/// batch (duplicate keys included) and any chunk limit, the per-chunk
/// key lists concatenate to exactly the batch's unique keys in
/// first-occurrence (FIFO) order — every queued key exactly once,
/// never more than the chunk limit per dispatch, and a key deduped
/// against an earlier slot never reappears in a later chunk. Results
/// stay bit-identical to the pure function throughout.
#[test]
fn prop_chunk_partition_preserves_fifo_order_and_covers_each_key_once() {
    proptest::check(
        "chunked dispatch partitions the queue",
        128,
        |r| {
            // Keys from a small pool so in-batch duplicates are common.
            let batch: Vec<(Vec<usize>, Vec<usize>)> = (0..1 + r.below(20))
                .map(|_| (vec![r.below(8), r.below(4)], vec![r.below(3)]))
                .collect();
            let chunk = 1 + r.below(5);
            (batch, chunk)
        },
        |(batch, chunk)| {
            let calls = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
            let broker = EvalBroker::new(Box::new(RecordingBackend { calls: calls.clone() }))
                .with_dispatch_chunk(*chunk);
            let mut session = broker.session();
            let results = session.evaluate_batch(batch);
            for ((n, h), got) in batch.iter().zip(&results) {
                if !bits_equal(got, &prop_det_result(n, h)) {
                    return Err(format!("result for {n:?}/{h:?} diverged"));
                }
            }
            // Unique keys in first-occurrence order: the expected
            // concatenation of all chunks.
            let mut expect: Vec<Vec<usize>> = Vec::new();
            for (n, h) in batch {
                let k = nahas::search::joint_key(n, h);
                if !expect.contains(&k) {
                    expect.push(k);
                }
            }
            let calls = calls.lock().unwrap();
            for (i, call) in calls.iter().enumerate() {
                if call.is_empty() || call.len() > *chunk {
                    return Err(format!(
                        "dispatch {i} carried {} keys (chunk limit {chunk})",
                        call.len()
                    ));
                }
            }
            let flat: Vec<Vec<usize>> = calls.iter().flatten().cloned().collect();
            if flat != expect {
                return Err(format!(
                    "chunks {flat:?} are not the FIFO unique-key partition {expect:?}"
                ));
            }
            Ok(())
        },
    );
}

// ---- persistent store properties (`nahas::search::store`) ----

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("nahas-prop-{}-{name}.cache", std::process::id()))
}

/// Comparable bit-exact projection of an [`EvalResult`].
type ResultBits = (bool, u64, u64, u64, u64);

fn bits(r: &EvalResult) -> ResultBits {
    (
        r.valid,
        r.acc.to_bits(),
        r.latency_ms.to_bits(),
        r.energy_mj.to_bits(),
        r.area_mm2.to_bits(),
    )
}

/// Arbitrary entries: short random keys, and metric f64s drawn from
/// raw bit patterns so NaNs, infinities, subnormals and negative zero
/// are all exercised (the bit-pattern format must round-trip them
/// exactly; a decimal format would not).
fn arbitrary_entries(r: &mut Rng, n: usize) -> Vec<(Vec<usize>, EvalResult)> {
    (0..n)
        .map(|_| {
            let key: Vec<usize> = (0..r.below(6)).map(|_| r.below(1000)).collect();
            let result = EvalResult {
                acc: f64::from_bits(r.next_u64()),
                latency_ms: f64::from_bits(r.next_u64()),
                energy_mj: f64::from_bits(r.next_u64()),
                area_mm2: f64::from_bits(r.next_u64()),
                valid: r.below(2) == 0,
            };
            (key, result)
        })
        .collect()
}

/// Last-wins map view of an entry sequence (the store's append-only
/// reload semantics).
fn as_map(entries: &[(Vec<usize>, EvalResult)]) -> HashMap<Vec<usize>, ResultBits> {
    entries.iter().map(|(k, v)| (k.clone(), bits(v))).collect()
}

#[test]
fn prop_store_roundtrips_arbitrary_entry_sets_bit_exactly() {
    let path = tmp("roundtrip");
    proptest::check(
        "store serialize/deserialize roundtrip",
        64,
        |r| {
            let n = r.below(24);
            arbitrary_entries(r, n)
        },
        |entries| {
            let _ = std::fs::remove_file(&path);
            {
                let mut store: CacheStore =
                    CacheStore::open(&path, "prop/fp").map_err(|e| e.to_string())?;
                for (k, v) in entries {
                    store.append(k, v);
                }
            }
            let mut store: CacheStore =
                CacheStore::open(&path, "prop/fp").map_err(|e| e.to_string())?;
            if let Some(why) = store.discarded() {
                return Err(format!("clean file discarded: {why}"));
            }
            let got = as_map(&store.take_loaded());
            let want = as_map(entries);
            if got != want {
                return Err(format!("reload mismatch: {got:?} vs {want:?}"));
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn prop_append_then_reload_equals_in_memory_map() {
    // Two append sessions against one file must reload to exactly the
    // map an in-memory MemoCache built from the same inserts holds.
    let path = tmp("append-reload");
    proptest::check(
        "append across sessions == in-memory map",
        32,
        |r| {
            let (n, m) = (1 + r.below(12), 1 + r.below(12));
            (arbitrary_entries(r, n), arbitrary_entries(r, m))
        },
        |(first, second)| {
            let _ = std::fs::remove_file(&path);
            let mut memo: MemoCache = MemoCache::new(1024);
            {
                let mut store: CacheStore =
                    CacheStore::open(&path, "prop/fp").map_err(|e| e.to_string())?;
                for (k, v) in first {
                    store.append(k, v);
                    memo.insert(k.clone(), *v);
                }
            }
            {
                let mut store: CacheStore =
                    CacheStore::open(&path, "prop/fp").map_err(|e| e.to_string())?;
                if store.discarded().is_some() {
                    return Err("mid-sequence reopen discarded the file".to_string());
                }
                for (k, v) in second {
                    store.append(k, v);
                    memo.insert(k.clone(), *v);
                }
            }
            let mut store: CacheStore =
                CacheStore::open(&path, "prop/fp").map_err(|e| e.to_string())?;
            let got = as_map(&store.take_loaded());
            let want: HashMap<_, _> =
                memo.entries().map(|(k, v)| (k.to_vec(), bits(v))).collect();
            if got != want {
                return Err(format!("disk {} entries vs memory {}", got.len(), want.len()));
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_file(&path);
}

// ---- binary codec properties (`nahas::util::codec`) ----

/// The wire frame codec round-trips arbitrary cache entries — NaN,
/// infinity, subnormal and negative-zero metric values included —
/// bit-exactly through a concatenated frame stream, the same encoding
/// the binary service protocol and the v2 cache segments carry.
#[test]
fn prop_frame_codec_roundtrips_arbitrary_entries_bit_exactly() {
    proptest::check(
        "wire frames roundtrip entries",
        128,
        |r| {
            let n = 1 + r.below(12);
            arbitrary_entries(r, n)
        },
        |entries| {
            let mut buf = Vec::new();
            for (k, v) in entries {
                let mut payload = Vec::new();
                codec::put_usize_slice(&mut payload, k);
                v.encode_bin(&mut payload);
                buf.extend_from_slice(&codec::frame(&payload));
            }
            let mut at = 0;
            let mut got: Vec<(Vec<usize>, EvalResult)> = Vec::new();
            while at < buf.len() {
                let Some((payload, used)) = codec::frame_payload(&buf[at..])? else {
                    return Err("complete stream parsed as incomplete".to_string());
                };
                let mut rd = ByteReader::new(payload);
                let k = rd.usize_slice().ok_or_else(|| "bad key".to_string())?;
                let v =
                    EvalResult::decode_bin(&mut rd).ok_or_else(|| "bad value".to_string())?;
                if !rd.is_empty() {
                    return Err("trailing payload bytes".to_string());
                }
                got.push((k, v));
                at += used;
            }
            if got.len() != entries.len() {
                return Err(format!("{} frames decoded of {}", got.len(), entries.len()));
            }
            for ((wk, wv), (gk, gv)) in entries.iter().zip(&got) {
                if wk != gk || bits(wv) != bits(gv) {
                    return Err(format!("entry diverged: {wk:?}/{wv:?} vs {gk:?}/{gv:?}"));
                }
            }
            Ok(())
        },
    );
}

/// Truncating or bit-flipping a framed stream never panics the parser
/// and never stalls it: every step either consumes bytes, reports an
/// incomplete tail, or rejects the stream with an error. The segment
/// reader gets the same fuzz, and Salvage mode must never error.
#[test]
fn prop_mangled_frame_and_segment_streams_never_panic_or_stall() {
    proptest::check(
        "mangled byte streams parse totally",
        128,
        |r| {
            let entries = arbitrary_entries(r, 1 + r.below(8));
            let mut buf = Vec::new();
            for (k, v) in &entries {
                let mut payload = Vec::new();
                codec::put_usize_slice(&mut payload, k);
                v.encode_bin(&mut payload);
                buf.extend_from_slice(&codec::frame(&payload));
                let mut seg = Vec::new();
                codec::write_segment(&mut seg, &payload, 1, r.below(2) == 0);
                buf.extend_from_slice(&seg);
            }
            // Mutate: truncate to an arbitrary prefix, then flip a bit.
            buf.truncate(r.below(buf.len() + 1));
            if !buf.is_empty() {
                let i = r.below(buf.len());
                buf[i] ^= 1 << r.below(8);
            }
            buf
        },
        |buf| {
            let mut at = 0;
            while at < buf.len() {
                match codec::frame_payload(&buf[at..]) {
                    Ok(Some((_, used))) => {
                        if used == 0 {
                            return Err("frame parser made no progress".to_string());
                        }
                        at += used;
                    }
                    Ok(None) | Err(_) => break,
                }
            }
            // Strict may reject, Salvage must always return a (possibly
            // empty) verified prefix; neither may panic.
            let _ = codec::read_segments(buf, ReadPolicy::Strict);
            if let Err(e) = codec::read_segments(buf, ReadPolicy::Salvage) {
                return Err(format!("salvage read errored: {e}"));
            }
            Ok(())
        },
    );
}

/// Damaged or stale v2 cache files degrade, never panic and never
/// invent data: whatever a reopen loads is byte-for-byte something the
/// writer wrote (the checksummed segments guarantee it), and a
/// fingerprint mismatch always discards with a reason.
#[test]
fn prop_corrupt_or_stale_v2_store_files_cold_start_cleanly() {
    let path = tmp("corrupt-v2");
    proptest::check(
        "corrupt v2 store bytes degrade cleanly",
        64,
        |r| {
            let n = 1 + r.below(12);
            (arbitrary_entries(r, n), r.next_u64(), r.next_u64())
        },
        |(entries, m, pos)| {
            let _ = std::fs::remove_file(&path);
            {
                let mut store: CacheStore =
                    CacheStore::open(&path, "prop/fp").map_err(|e| e.to_string())?;
                for (k, v) in entries {
                    store.append(k, *v);
                }
            }
            let mut bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
            let kind = m % 3;
            let fp = if kind == 2 { "prop/other-fp" } else { "prop/fp" };
            if kind == 0 {
                bytes.truncate(*pos as usize % (bytes.len() + 1));
            } else if kind == 1 {
                let i = *pos as usize % bytes.len();
                bytes[i] ^= 1 << (m % 8) as u8;
            }
            std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
            let mut store: CacheStore =
                CacheStore::open(&path, fp).map_err(|e| e.to_string())?;
            if kind == 2 && !store.discarded().is_some_and(|w| w.contains("fingerprint")) {
                return Err(format!("stale header not discarded: {:?}", store.discarded()));
            }
            for (k, v) in &store.take_loaded() {
                let genuine = entries.iter().any(|(wk, wv)| wk == k && bits(wv) == bits(v));
                if !genuine {
                    return Err(format!("loaded entry {k:?} was never written"));
                }
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn prop_interleaved_brokers_on_separate_files_never_cross_contaminate() {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let (path_a, path_b) = (tmp("broker-a"), tmp("broker-b"));
    proptest::check(
        "two brokers, two files, interleaved flushes",
        12,
        |r| {
            let mut batch = |n: usize| -> Vec<(Vec<usize>, Vec<usize>)> {
                (0..n).map(|_| (space.random(r), has.random(r))).collect()
            };
            (batch(6), batch(6), batch(6), batch(6))
        },
        |(a1, b1, a2, b2)| {
            let _ = std::fs::remove_file(&path_a);
            let _ = std::fs::remove_file(&path_b);
            let mk = |path: &PathBuf| -> Result<EvalBroker, String> {
                let store: CacheStore =
                    CacheStore::open(path, "prop/fp").map_err(|e| e.to_string())?;
                let sim = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
                Ok(EvalBroker::with_store(Box::new(sim), store))
            };
            let (broker_a, broker_b) = (mk(&path_a)?, mk(&path_b)?);
            let (mut sa, mut sb) = (broker_a.session(), broker_b.session());
            // Interleave batches and flushes between the two brokers.
            sa.evaluate_batch(a1);
            sb.evaluate_batch(b1);
            broker_a.flush_store();
            sb.evaluate_batch(b2);
            sa.evaluate_batch(a2);
            broker_b.flush_store();
            let keys = |x: &[(Vec<usize>, Vec<usize>)], y: &[(Vec<usize>, Vec<usize>)]| {
                x.iter()
                    .chain(y.iter())
                    .map(|(n, h)| nahas::search::joint_key(n, h))
                    .collect::<Vec<Vec<usize>>>()
            };
            let (keys_a, keys_b) = (keys(a1, a2), keys(b1, b2));
            drop((sa, sb, broker_a, broker_b));
            for (path, own, evals) in [(&path_a, &keys_a, &keys_b), (&path_b, &keys_b, &keys_a)]
            {
                let mut store: CacheStore =
                    CacheStore::open(path, "prop/fp").map_err(|e| e.to_string())?;
                let loaded = store.take_loaded();
                for (k, _) in &loaded {
                    if !own.contains(k) {
                        let foreign = evals.contains(k);
                        return Err(format!(
                            "{} holds key {k:?} it never evaluated (foreign: {foreign})",
                            path.display()
                        ));
                    }
                }
                // Every unique key the broker evaluated is present.
                let mut unique = own.clone();
                unique.sort();
                unique.dedup();
                if loaded.len() != unique.len() {
                    return Err(format!(
                        "{}: {} entries for {} unique keys",
                        path.display(),
                        loaded.len(),
                        unique.len()
                    ));
                }
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_file(&path_a);
    let _ = std::fs::remove_file(&path_b);
}

// ---- elastic membership properties (`nahas::cluster`) ----

/// A join moves keys only *to* the new host and a leave only *from*
/// the departed one: rendezvous scores are per-(host, key), so the
/// changed host's score is the only one that appears or disappears —
/// every pairwise argmax among the untouched hosts is unchanged. This
/// is the invariant that makes a warm handoff slice well-defined (the
/// joining host's range is exactly the keys it now wins) and keeps
/// everyone else's cache affinity intact through churn.
#[test]
fn prop_ring_join_and_leave_move_keys_only_to_or_from_the_changed_host() {
    proptest::check(
        "rendezvous join/leave isolation",
        proptest::CASES,
        |r| {
            let n = 2 + r.below(5); // 2..=6 hosts
            let key: Vec<usize> = (0..(1 + r.below(30))).map(|_| r.below(8)).collect();
            // Joining weight spans light to heavy (0.25 .. 4.0).
            let weight = 0.25 * (1 + r.below(16)) as f64;
            let leave = r.below(n);
            (n, key, weight, leave)
        },
        |(n, key, weight, leave)| {
            let named: Vec<String> = (0..*n).map(|i| format!("10.0.0.{i}:7878")).collect();
            let before = HashRing::new(&named);
            let owner = before.owner(key).unwrap();

            // Join: the new host lands at index n; keys either keep
            // their owner or move to the newcomer, never between two
            // incumbent hosts.
            let mut joined = before.clone();
            joined.join("10.0.9.9:7878", *weight);
            let after_join = joined.owner(key).unwrap();
            if after_join != owner && after_join != *n {
                return Err(format!(
                    "join (weight {weight}) moved a key between incumbents {owner} -> {after_join}"
                ));
            }

            // Leave: survivors keep their keys; the departed host's
            // keys land on a survivor. Indices above the removed slot
            // shift down by one, so map back before comparing.
            let mut left = before.clone();
            left.leave(*leave);
            let shifted = left.owner(key).unwrap();
            let after_leave = if shifted >= *leave { shifted + 1 } else { shifted };
            if owner != *leave && after_leave != owner {
                return Err(format!(
                    "leave of {leave} moved a key between survivors {owner} -> {after_leave}"
                ));
            }

            // Join then leave of the same host is a no-op on ownership.
            joined.leave(*n);
            if joined.owner(key) != Some(owner) {
                return Err("join+leave of the same host changed an owner".into());
            }
            Ok(())
        },
    );
}

/// A truncated or bit-flipped handoff stream never panics the decoder
/// and never half-installs: [`nahas::search::store::decode_handoff`]
/// is strict all-or-nothing per segment, so whatever it accepts is a
/// byte-exact prefix of what the sender encoded — a mangled transfer
/// leaves the joining host cold (or short) but consistent, never
/// holding an entry the sender did not write.
#[test]
fn prop_mangled_handoff_stream_decodes_all_or_nothing() {
    proptest::check(
        "mangled handoff decode total",
        128,
        |r| {
            let entries: Vec<(Vec<usize>, String)> = (0..1 + r.below(12))
                .map(|i| {
                    let key: Vec<usize> = (0..1 + r.below(8)).map(|_| r.below(100)).collect();
                    (key, format!("{{\"valid\": true, \"latency_ms\": {i}.5}}"))
                })
                .collect();
            let mut bytes = nahas::search::store::encode_handoff(&entries);
            // kind 0: pristine; 1: truncate; 2: truncate + bit-flip.
            let kind = r.below(3);
            if kind >= 1 {
                bytes.truncate(r.below(bytes.len() + 1));
            }
            if kind == 2 && !bytes.is_empty() {
                let i = r.below(bytes.len());
                bytes[i] ^= 1 << r.below(8);
            }
            (entries, bytes, kind)
        },
        |(entries, bytes, kind)| {
            let got: Result<Vec<(Vec<usize>, String)>, String> =
                nahas::search::store::decode_handoff(bytes);
            match got {
                Ok(got) => {
                    if *kind == 0 && got.len() != entries.len() {
                        return Err(format!(
                            "pristine stream decoded {} of {} entries",
                            got.len(),
                            entries.len()
                        ));
                    }
                    // Whatever survives the checksums is a prefix of
                    // the genuine entry sequence — never invented data.
                    if got.len() > entries.len() {
                        return Err("decoder invented entries".into());
                    }
                    for (i, (g, w)) in got.iter().zip(entries.iter()).enumerate() {
                        if g != w {
                            return Err(format!("entry {i} diverged: {g:?} vs {w:?}"));
                        }
                    }
                    Ok(())
                }
                // Rejection is the expected outcome for mangled bytes;
                // the property is totality plus all-or-nothing.
                Err(_) if *kind >= 1 => Ok(()),
                Err(e) => Err(format!("pristine stream rejected: {e}")),
            }
        },
    );
}

// ---------------------------------------------------------------------------
// Pareto totality over hostile (NaN / ±inf) metrics
// ---------------------------------------------------------------------------
// A degenerate reward config can hand the frontier code NaN or
// infinite metrics. The ranking convention (`total_cmp`, NaN sorts
// last and sits outside the dominance order) must make every frontier
// entry point *total*: no panic, deterministic output, and no NaN
// coordinate ever on a 2-D frontier.

/// A coordinate that is frequently non-finite: explicit specials and
/// raw-bit f64s (which include NaNs of every payload) mixed with small
/// reals.
fn hostile(r: &mut Rng) -> f64 {
    match r.below(6) {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => f64::NEG_INFINITY,
        3 => f64::from_bits(r.next_u64()),
        _ => (r.below(100) as f64) / 10.0,
    }
}

fn point_bits(f: &[Point]) -> Vec<(u64, u64, String)> {
    f.iter().map(|p| (p.acc.to_bits(), p.cost.to_bits(), p.tag.clone())).collect()
}

fn mp_bits(f: &[MultiPoint]) -> Vec<(u64, Vec<u64>, String)> {
    f.iter()
        .map(|p| {
            (p.acc.to_bits(), p.costs.iter().map(|c| c.to_bits()).collect(), p.tag.clone())
        })
        .collect()
}

#[test]
fn prop_frontier_total_and_nan_free_on_hostile_metrics() {
    proptest::check(
        "frontier hostile totality",
        proptest::CASES,
        |r: &mut Rng| {
            (0..r.below(24))
                .map(|i| Point::new(hostile(r), hostile(r), format!("{i}")))
                .collect::<Vec<_>>()
        },
        |pts| {
            let f = frontier(pts);
            // Deterministic: the same input yields the same bits.
            if point_bits(&f) != point_bits(&frontier(pts)) {
                return Err("frontier nondeterministic on hostile input".into());
            }
            // The NaN convention: a NaN coordinate never reaches the
            // frontier (NaN sits outside the dominance order).
            if f.iter().any(|p| p.acc.is_nan() || p.cost.is_nan()) {
                return Err(format!("NaN point in frontier: {f:?}"));
            }
            // Mutually non-dominated (NaN-free output, so `!=` is a
            // real distinctness test), and a fixed point of re-merging.
            for a in &f {
                for b in &f {
                    if a != b && a.dominates(b) {
                        return Err(format!("{a:?} dominates {b:?} in frontier"));
                    }
                }
            }
            if point_bits(&union_frontier(&[f.clone()])) != point_bits(&f) {
                return Err("union_frontier not idempotent on hostile frontier".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_frontier_nd_total_and_deterministic_on_hostile_metrics() {
    proptest::check(
        "frontier_nd hostile totality",
        proptest::CASES,
        |r: &mut Rng| {
            (0..r.below(20))
                .map(|i| {
                    MultiPoint::new(hostile(r), vec![hostile(r), hostile(r)], format!("{i}"))
                })
                .collect::<Vec<_>>()
        },
        |pts| {
            let f = frontier_nd(pts);
            if mp_bits(&f) != mp_bits(&frontier_nd(pts)) {
                return Err("frontier_nd nondeterministic on hostile input".into());
            }
            if f.len() > pts.len() {
                return Err("frontier_nd grew".into());
            }
            // NaN points are incomparable (they dominate nothing and
            // nothing dominates them), so they may survive — but the
            // survivors must still be mutually non-dominated and a
            // fixed point of re-merging.
            for a in &f {
                for b in &f {
                    if a.dominates(b) {
                        return Err(format!("{a:?} dominates {b:?} in frontier_nd"));
                    }
                }
            }
            if mp_bits(&union_frontier_nd(&[f.clone()])) != mp_bits(&f) {
                return Err("union_frontier_nd not idempotent on hostile frontier".into());
            }
            Ok(())
        },
    );
}
