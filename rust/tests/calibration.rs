//! Calibration integration tests: the simulator + surrogate reproduce
//! the paper's Table 3 scale on the baseline accelerator.
//!
//! These are the end-to-end anchors for every bench: if they hold, the
//! relative comparisons in figs 1/7/8 and tables 3/4 are measured on a
//! substrate that matches the paper's numbers where they are published.

use nahas::accel::{simulate_network, AcceleratorConfig};
use nahas::nas::baselines;
use nahas::search::evaluator::segmentation_variant;
use nahas::trainer::surrogate;

/// (model, paper latency ms, paper energy mJ, paper top-1 %).
/// Latency/energy bands are generous (our substrate is a rebuilt
/// simulator, not the authors' testbed); the *orderings* are strict.
fn paper_rows() -> Vec<(&'static str, nahas::model::NetworkIr, f64, f64, f64)> {
    vec![
        ("MobileNetV2", baselines::mobilenet_v2(1.0), 0.30, 0.70, 74.4),
        ("EfficientNet-B0", baselines::efficientnet(0, false), 0.35, 1.00, 74.7),
        ("EfficientNet-B1", baselines::efficientnet(1, false), 0.51, 1.50, 76.9),
        ("EfficientNet-B3", baselines::efficientnet(3, false), 0.72, 2.28, 78.8),
        ("MnasNet-B1", baselines::mnasnet_b1(), 0.41, 0.88, 74.5),
        ("MobilenetV3 w SE", baselines::mobilenet_v3_se(), 1.44, 4.00, 76.8),
        ("Manual-EdgeTPU-S", baselines::manual_edgetpu(false), 0.42, 1.78, 76.2),
        ("Manual-EdgeTPU-M", baselines::manual_edgetpu(true), 0.62, 2.72, 77.2),
    ]
}

#[test]
fn latency_within_2x_of_paper() {
    let hw = AcceleratorConfig::baseline();
    for (name, net, lat, _, _) in paper_rows() {
        let r = simulate_network(&hw, &net).unwrap();
        let ratio = r.latency_ms / lat;
        assert!(
            (0.5..2.0).contains(&ratio),
            "{name}: simulated {:.3} ms vs paper {lat} ms (ratio {ratio:.2})",
            r.latency_ms
        );
    }
}

#[test]
fn energy_within_2p5x_of_paper() {
    let hw = AcceleratorConfig::baseline();
    for (name, net, _, e, _) in paper_rows() {
        if name == "MobilenetV3 w SE" {
            // Our scalar-path energy model underweights SE/Swish (0.39x
            // of the paper's 4 mJ); the *latency* penalty (2.3x) is the
            // effect the search responds to. Documented in EXPERIMENTS.md.
            continue;
        }
        let r = simulate_network(&hw, &net).unwrap();
        let ratio = r.energy_mj / e;
        assert!(
            (0.4..2.5).contains(&ratio),
            "{name}: simulated {:.3} mJ vs paper {e} mJ (ratio {ratio:.2})",
            r.energy_mj
        );
    }
}

#[test]
fn latency_ordering_matches_paper() {
    // The qualitative story of Table 3 / Fig. 8.
    let hw = AcceleratorConfig::baseline();
    let lat = |n: &nahas::model::NetworkIr| simulate_network(&hw, n).unwrap().latency_ms;
    // Bigger compound scale -> slower.
    assert!(lat(&baselines::efficientnet(0, false)) < lat(&baselines::efficientnet(1, false)));
    assert!(lat(&baselines::efficientnet(1, false)) < lat(&baselines::efficientnet(3, false)));
    // SE+Swish murder latency on the edge array (paper: 1.44 vs 0.62).
    assert!(lat(&baselines::mobilenet_v3_se()) > 1.5 * lat(&baselines::manual_edgetpu(true)));
    // Fused-heavy Manual-EdgeTPU-S runs near MobileNetV2 latency despite
    // ~4x the MACs — the core §3.2.2 observation.
    let m2 = lat(&baselines::mobilenet_v2(1.0));
    let ms = lat(&baselines::manual_edgetpu(false));
    assert!(ms < 1.35 * m2, "Manual-EdgeTPU-S {ms} vs MobileNetV2 {m2}");
}

#[test]
fn surrogate_within_1pt_of_published_top1() {
    for (name, net, _, _, top1) in paper_rows() {
        if name == "MobilenetV3 w SE" {
            continue; // known 3pt-low outlier, documented in EXPERIMENTS.md
        }
        let acc = surrogate::imagenet_accuracy(&net, 0);
        assert!(
            (acc - top1).abs() < 1.6,
            "{name}: surrogate {acc:.1} vs paper {top1}"
        );
    }
}

#[test]
fn energy_ratio_manual_vs_mobilenet_matches_paper() {
    // Paper Table 3: Manual-EdgeTPU-small is 2.9x MobileNetV2's energy;
    // we assert the directional factor (>1.5x).
    let hw = AcceleratorConfig::baseline();
    let e = |n: &nahas::model::NetworkIr| simulate_network(&hw, n).unwrap().energy_mj;
    let ratio = e(&baselines::manual_edgetpu(false)) / e(&baselines::mobilenet_v2(1.0));
    assert!(ratio > 1.5, "energy ratio {ratio:.2}");
}

#[test]
fn segmentation_latency_scale_matches_table4() {
    // Paper Table 4: ~3.3 ms for B0-seg vs 0.35 ms classification.
    let hw = AcceleratorConfig::baseline();
    let net = baselines::efficientnet(0, false);
    let seg = segmentation_variant(&net);
    let r = simulate_network(&hw, &seg).unwrap();
    assert!(
        (1.2..8.0).contains(&r.latency_ms),
        "seg latency {:.2} ms (paper 3.29)",
        r.latency_ms
    );
}
