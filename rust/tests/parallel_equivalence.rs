//! Serial/parallel equivalence: for every seed and worker count, a
//! joint search evaluated through [`ParallelSim`] — or sharded over a
//! multi-host cluster through [`ShardedEvaluator`] — must replay the
//! serial [`SurrogateSim`] trajectory **bit for bit** — same sampled
//! decisions, same rewards, same `best_feasible`. This is the contract
//! that makes `--workers N` / `--hosts A,B,...` pure throughput knobs:
//! parallelism, memoization and routing may change how often and where
//! a sample is computed, never what it computes.

use nahas::cluster::ShardedEvaluator;
use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::{
    joint_search, Evaluator, ParallelSim, RewardCfg, SearchCfg, SearchOutcome, SurrogateSim,
};
use nahas::service::Server;

const SAMPLES: usize = 160;

fn run(ev: &mut dyn Evaluator, seed: u64) -> SearchOutcome {
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(&space, &has);
    let mut ctl = PpoController::new(&cards);
    let cfg = SearchCfg::new(SAMPLES, RewardCfg::latency(0.4), seed);
    joint_search(ev, &mut ctl, &layout, None, None, &cfg)
}

fn assert_identical(want: &SearchOutcome, got: &SearchOutcome, seed: u64, workers: usize) {
    let ctx = format!("seed {seed}, workers {workers}");
    assert_eq!(want.history.len(), got.history.len(), "{ctx}: history length");
    for (w, g) in want.history.iter().zip(&got.history) {
        assert_eq!(w.index, g.index, "{ctx}");
        assert_eq!(w.nas_d, g.nas_d, "{ctx}: sample {} nas decisions", w.index);
        assert_eq!(w.has_d, g.has_d, "{ctx}: sample {} has decisions", w.index);
        assert_eq!(w.result.valid, g.result.valid, "{ctx}: sample {}", w.index);
        assert_eq!(
            w.reward.to_bits(),
            g.reward.to_bits(),
            "{ctx}: sample {} reward {} vs {}",
            w.index,
            w.reward,
            g.reward
        );
        assert_eq!(w.result.acc.to_bits(), g.result.acc.to_bits(), "{ctx}");
        assert_eq!(w.result.latency_ms.to_bits(), g.result.latency_ms.to_bits(), "{ctx}");
        assert_eq!(w.result.energy_mj.to_bits(), g.result.energy_mj.to_bits(), "{ctx}");
        assert_eq!(w.result.area_mm2.to_bits(), g.result.area_mm2.to_bits(), "{ctx}");
    }
    assert_eq!(want.num_invalid, got.num_invalid, "{ctx}: invalid count");
    match (&want.best_feasible, &got.best_feasible) {
        (None, None) => {}
        (Some(w), Some(g)) => {
            assert_eq!(w.index, g.index, "{ctx}: best_feasible index");
            assert_eq!(w.nas_d, g.nas_d, "{ctx}: best_feasible nas");
            assert_eq!(w.has_d, g.has_d, "{ctx}: best_feasible hw");
            assert_eq!(w.reward.to_bits(), g.reward.to_bits(), "{ctx}: best_feasible reward");
        }
        (w, g) => panic!("{ctx}: best_feasible {:?} vs {:?}", w.is_some(), g.is_some()),
    }
}

#[test]
fn parallel_matches_serial_across_seeds_and_workers() {
    for seed in [1u64, 7, 42] {
        let mut serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed);
        let want = run(&mut serial, seed);
        assert_eq!(want.history.len(), SAMPLES);
        for workers in [1usize, 4, 8] {
            let mut par =
                ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed, workers);
            let got = run(&mut par, seed);
            assert_identical(&want, &got, seed, workers);
            // Stats bookkeeping must balance exactly.
            let st = &got.eval_stats;
            assert_eq!(st.requests, SAMPLES, "workers {workers}");
            assert_eq!(st.evals + st.cache_hits, st.requests, "workers {workers}");
            assert_eq!(st.invalid, got.num_invalid, "workers {workers}");
        }
    }
}

#[test]
fn cluster_matches_serial_over_two_and_three_hosts() {
    // ISSUE 2 acceptance: `ShardedEvaluator` over N in-process servers
    // is bit-identical to the serial path for the same seed, N ∈ {2, 3}.
    for n_hosts in [2usize, 3] {
        let servers: Vec<Server> =
            (0..n_hosts).map(|_| Server::spawn("127.0.0.1:0").unwrap()).collect();
        let hosts: Vec<String> = servers.iter().map(|s| s.addr.to_string()).collect();
        for seed in [1u64, 7] {
            let mut serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed);
            let want = run(&mut serial, seed);
            let mut cluster =
                ShardedEvaluator::connect(&hosts, NasSpaceId::EfficientNet, seed, 2).unwrap();
            let got = run(&mut cluster, seed);
            assert_identical(&want, &got, seed, n_hosts);
            let st = &got.eval_stats;
            assert_eq!(st.requests, SAMPLES, "{n_hosts} hosts");
            assert_eq!(st.evals + st.cache_hits, st.requests, "{n_hosts} hosts");
            assert_eq!(st.invalid, got.num_invalid, "{n_hosts} hosts");
            assert_eq!(st.hosts_down, 0, "{n_hosts} hosts");
            assert_eq!(st.per_host.len(), n_hosts);
            // Rendezvous routing accounts for every request, and with a
            // healthy pool every host carries part of the key space.
            let routed: usize = st.per_host.iter().map(|h| h.requests).sum();
            assert_eq!(routed, SAMPLES, "{n_hosts} hosts");
            for h in &st.per_host {
                assert!(h.requests > 0, "host {} routed nothing", h.host);
                assert!(!h.down, "host {} wrongly down", h.host);
            }
        }
        for s in servers {
            s.stop();
        }
    }
}

#[test]
fn parallel_matches_serial_with_fixed_hardware() {
    // Platform-aware NAS (fixed accelerator): the free vector is only
    // the NAS half, exercising the fixed-half key layout.
    let seed = 7u64;
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(&space, &has);
    let nas_cards = cards[..layout.nas_len].to_vec();
    let baseline = has.baseline_decisions();
    let cfg = SearchCfg::new(96, RewardCfg::latency(0.3), seed);

    let mut serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed);
    let mut ctl = PpoController::new(&nas_cards);
    let want = joint_search(&mut serial, &mut ctl, &layout, Some(&baseline), None, &cfg);

    for workers in [2usize, 8] {
        let mut par = ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed, workers);
        let mut ctl = PpoController::new(&nas_cards);
        let got = joint_search(&mut par, &mut ctl, &layout, Some(&baseline), None, &cfg);
        assert_identical(&want, &got, seed, workers);
    }
}
