//! Service concurrency hammer: 8 client threads x 50 interleaved
//! requests each — valid queries, in-protocol invalid ones (out-of-
//! range hardware), and malformed JSON — against one ephemeral-port
//! server. Every line must come back as parseable JSON with a `valid`
//! field, counts must match exactly, and the server must survive to
//! serve the next client (the paper's multi-client deployment, §4.1).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;

use nahas::cluster::query_host_stats;
use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::service::{Client, Server};
use nahas::util::json::Json;
use nahas::util::Rng;

const THREADS: usize = 8;
const REQUESTS_PER_THREAD: usize = 50;

fn json_arr(v: &[usize]) -> String {
    let items: Vec<String> = v.iter().map(|x| x.to_string()).collect();
    format!("[{}]", items.join(","))
}

#[test]
fn eight_threads_fifty_mixed_requests_each() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let mut joins = Vec::new();
    for t in 0..THREADS as u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let space = NasSpace::new(NasSpaceId::EfficientNet);
            let has = HasSpace::new();
            let baseline = has.baseline_decisions();
            let mut rng = Rng::new(0xC0DE + t);
            let stream = TcpStream::connect(&addr).unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader = BufReader::new(stream);
            let (mut accepted, mut rejected) = (0usize, 0usize);
            for i in 0..REQUESTS_PER_THREAD {
                match i % 3 {
                    0 => {
                        // Valid: random in-space nas on the (always
                        // simulable) baseline accelerator.
                        let nas = space.random(&mut rng);
                        writeln!(
                            writer,
                            "{{\"space\":\"efficientnet\",\"nas\":{},\"hw\":{},\"task\":\"cls\"}}",
                            json_arr(&nas),
                            json_arr(&baseline)
                        )
                        .unwrap();
                    }
                    1 => {
                        // In-protocol invalid: hw decision out of range.
                        let nas = space.random(&mut rng);
                        writeln!(
                            writer,
                            "{{\"space\":\"efficientnet\",\"nas\":{},\"hw\":[9,9,9,9,9,9,9]}}",
                            json_arr(&nas)
                        )
                        .unwrap();
                    }
                    _ => {
                        // Malformed JSON line.
                        writeln!(writer, "{{this is not json, thread {t} request {i}").unwrap();
                    }
                }
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let j = Json::parse(&line)
                    .unwrap_or_else(|e| panic!("unparseable response '{line}': {e}"));
                match j.get("valid") {
                    Some(&Json::Bool(true)) => accepted += 1,
                    Some(&Json::Bool(false)) => rejected += 1,
                    other => panic!("response without boolean 'valid': {other:?} in {line}"),
                }
            }
            (accepted, rejected)
        }));
    }
    let (mut accepted, mut rejected) = (0usize, 0usize);
    for j in joins {
        let (a, r) = j.join().unwrap();
        accepted += a;
        rejected += r;
    }
    // Per thread: i % 3 == 0 on 17 of 50 requests; the rest must be
    // rejected (bad hw index or parse error) — never dropped.
    assert_eq!(accepted, THREADS * 17, "valid-request count");
    assert_eq!(rejected, THREADS * 33, "rejected-request count");
    assert_eq!(
        server.requests.load(Ordering::Relaxed),
        (THREADS * REQUESTS_PER_THREAD) as u64,
        "every line must be answered exactly once"
    );

    // The server is still healthy after the hammer: one more clean query.
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let mut rng = Rng::new(1);
    let nas = space.random(&mut rng);
    let stream = TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(
        writer,
        "{{\"space\":\"efficientnet\",\"nas\":{},\"hw\":{}}}",
        json_arr(&nas),
        json_arr(&has.baseline_decisions())
    )
    .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(&line).unwrap().get("valid"), Some(&Json::Bool(true)));
    server.stop();
}

/// Pipelining on ONE connection: 50 id-tagged requests written as a
/// single burst before any response is read, with deliberately
/// shuffled ids. The server answers id'd requests in *completion*
/// order (whatever its sim workers finish first), so the echoed id is
/// the only valid way to match responses — the test pins that every
/// id comes back exactly once and that the response carrying id `k`
/// is bit-for-bit the answer to request `k` (checked against serial
/// roundtrips for the same keys on a second connection).
#[test]
fn pipelined_burst_of_fifty_matches_serial_responses_by_id() {
    const BURST: usize = 50;
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let baseline = has.baseline_decisions();
    let mut rng = Rng::new(0xBEEF);
    let nas_pool: Vec<Vec<usize>> = (0..BURST).map(|_| space.random(&mut rng)).collect();

    // Write the whole burst — request j carries id (j*17+5) % 50, a
    // permutation, so arrival order and id order never coincide.
    let stream = TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut burst = String::new();
    for j in 0..BURST {
        let id = (j * 17 + 5) % BURST;
        burst.push_str(&format!(
            "{{\"space\":\"efficientnet\",\"nas\":{},\"hw\":{},\"id\":{id}}}\n",
            json_arr(&nas_pool[id]),
            json_arr(&baseline)
        ));
    }
    writer.write_all(burst.as_bytes()).unwrap();

    // 50 responses, matched purely by echoed id.
    let mut by_id: Vec<Option<Json>> = vec![None; BURST];
    for _ in 0..BURST {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap_or_else(|e| panic!("unparseable '{line}': {e}"));
        let id = j.get("id").and_then(Json::as_usize).expect("response without echoed id");
        assert!(by_id[id].is_none(), "id {id} answered twice");
        by_id[id] = Some(j);
    }

    // Each id's response is the answer to *that* request: identical to
    // a serial roundtrip for the same key on a fresh connection (the
    // simulator is deterministic).
    let mut serial = Client::connect(&addr).unwrap();
    for (id, resp) in by_id.iter().enumerate() {
        let resp = resp.as_ref().unwrap();
        assert_eq!(resp.get("valid"), Some(&Json::Bool(true)), "id {id}");
        let want = serial.query("efficientnet", &nas_pool[id], &baseline, false).unwrap();
        let (got, want_lat) = (resp.get("latency_ms"), want.get("latency_ms"));
        assert_eq!(got, want_lat, "id {id} got another key's answer");
        assert_eq!(resp.get("energy_mj"), want.get("energy_mj"), "id {id}");
    }
    assert_eq!(
        server.requests.load(Ordering::Relaxed),
        2 * BURST as u64,
        "burst + serial check, every line answered exactly once"
    );
    server.stop();
}

/// Slow-loris robustness: connections that write half a request line
/// and then stall must not stall anyone else — more of them than the
/// server has event threads, so a blocking-read loop anywhere would
/// wedge the whole service. Normal clients keep getting answers, and
/// a loris that finally completes its line still gets its response on
/// the same connection.
#[test]
fn stalled_partial_line_connections_do_not_stall_other_clients() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let baseline = has.baseline_decisions();
    let mut rng = Rng::new(0x10E1);

    // Four stalled connections (server default is two event threads),
    // each holding an unterminated request fragment.
    let mut loris: Vec<TcpStream> = (0..4)
        .map(|_| {
            let mut s = TcpStream::connect(&addr).unwrap();
            write!(s, "{{\"space\":\"efficientnet\",").unwrap();
            s.flush().unwrap();
            s
        })
        .collect();

    // A normal client gets prompt answers while all four loris streams
    // sit mid-line. The io timeout turns a wedged server into a loud
    // failure instead of a hung test.
    let mut client =
        Client::connect_with_io_timeout(&addr, std::time::Duration::from_secs(10)).unwrap();
    for _ in 0..5 {
        let nas = space.random(&mut rng);
        let resp = client.query("efficientnet", &nas, &baseline, false).unwrap();
        assert_eq!(resp.get("valid"), Some(&Json::Bool(true)));
    }

    // A loris that completes its line is served like anyone else: the
    // buffered fragment and the completion frame into one request.
    let mut s = loris.pop().unwrap();
    s.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
    let nas = space.random(&mut rng);
    writeln!(s, "\"nas\":{},\"hw\":{}}}", json_arr(&nas), json_arr(&baseline)).unwrap();
    let mut line = String::new();
    BufReader::new(s).read_line(&mut line).unwrap();
    assert_eq!(Json::parse(&line).unwrap().get("valid"), Some(&Json::Bool(true)));
    server.stop();
}

#[test]
fn stats_probe_reports_server_cache_size() {
    // The `{"stats": true}` probe must expose the resident size of the
    // server-side result cache, both over the raw protocol and through
    // `query_host_stats` — the exact path `nahas cluster-status` uses
    // to print its Cache column.
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let baseline = has.baseline_decisions();
    let mut rng = Rng::new(0xCAFE);
    let (a, b) = (space.random(&mut rng), space.random(&mut rng));
    let mut client = Client::connect(&addr).unwrap();
    client.query("efficientnet", &a, &baseline, false).unwrap();
    client.query("efficientnet", &b, &baseline, false).unwrap();
    client.query("efficientnet", &a, &baseline, false).unwrap(); // repeat: a hit

    // Raw protocol probe.
    let mut stream = TcpStream::connect(&addr).unwrap();
    writeln!(stream, "{{\"stats\": true}}").unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    let st = Json::parse(line.trim()).unwrap();
    assert_eq!(st.get("cache_size").and_then(Json::as_usize), Some(2));
    assert_eq!(st.get("cache_hits").and_then(Json::as_usize), Some(1));

    // The cluster-status path reads the same field.
    let hs = query_host_stats(&addr, std::time::Duration::from_millis(1000)).unwrap();
    assert_eq!(hs.cache_size, 2);
    assert_eq!(hs.cache_hits, 1);
    assert_eq!(hs.sim_evals, 2);
    server.stop();
}
