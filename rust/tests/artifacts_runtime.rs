//! Integration over the AOT artifacts + PJRT runtime (the L3-L2-L1
//! seam). Skipped gracefully when `artifacts/` has not been built.

use nahas::nas::{NasSpace, NasSpaceId};
use nahas::runtime::{lit_f32, to_vec_f32, Runtime};
use nahas::trainer::ProxyTrainer;
use nahas::util::Rng;

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built; skipping (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(dir).expect("artifacts present but unloadable"))
}

#[test]
fn quickstart_matmul_matches_host() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Rng::new(3);
    let a: Vec<f32> = (0..256).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..256).map(|_| rng.normal()).collect();
    let out = rt
        .run(
            "quickstart_matmul",
            &[&lit_f32(&a, &[16, 16]).unwrap(), &lit_f32(&b, &[16, 16]).unwrap()],
        )
        .unwrap();
    let got = to_vec_f32(&out[0]).unwrap();
    for i in 0..16 {
        for j in 0..16 {
            let mut want = 0.0f32;
            for k in 0..16 {
                want += a[i * 16 + k] * b[k * 16 + j];
            }
            assert!(
                (got[i * 16 + j] - want).abs() < 1e-3,
                "pallas [{i},{j}] {} vs host {want}",
                got[i * 16 + j]
            );
        }
    }
}

#[test]
fn manifest_signature_validation_rejects_bad_inputs() {
    let Some(mut rt) = runtime() else { return };
    // Wrong arity.
    assert!(rt.run("quickstart_matmul", &[]).is_err());
    // Wrong shape.
    let bad = lit_f32(&vec![0.0; 4], &[2, 2]).unwrap();
    let ok = lit_f32(&vec![0.0; 256], &[16, 16]).unwrap();
    assert!(rt.run("quickstart_matmul", &[&bad, &ok]).is_err());
    // Unknown program.
    let a = lit_f32(&vec![0.0; 256], &[16, 16]).unwrap();
    let b = lit_f32(&vec![0.0; 256], &[16, 16]).unwrap();
    assert!(rt.run("nonexistent", &[&a, &b]).is_err());
}

#[test]
fn no_artifact_contains_elided_constants() {
    // The silent-zero failure mode of the HLO-text interchange (see
    // model.py kernel_mask): guard every shipped artifact.
    let dir = Runtime::default_dir();
    if !dir.exists() {
        return;
    }
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().map(|e| e == "txt").unwrap_or(false) {
            let text = std::fs::read_to_string(&path).unwrap();
            assert!(
                !text.contains("constant({...})"),
                "{path:?} contains an elided constant (would execute as zeros)"
            );
        }
    }
}

#[test]
fn child_training_learns_above_chance() {
    let Some(rt) = runtime() else { return };
    let mut trainer = ProxyTrainer::new(rt, 5).unwrap();
    trainer.steps = 40;
    let space = NasSpace::new(NasSpaceId::Proxy);
    // A mid-size child: IBN, k=5, exp=6, filter 1.0 everywhere.
    let d: Vec<usize> = (0..space.blocks.len()).flat_map(|_| [1usize, 1, 0, 2]).collect();
    let acc = trainer.train_child(&d, 11).unwrap();
    // Chance is 1/16 = 0.0625 on the 16-class proxy task.
    assert!(acc > 0.15, "trained child accuracy {acc} not above chance");
}

#[test]
fn supernet_oneshot_step_and_eval_consistent() {
    let Some(rt) = runtime() else { return };
    let mut trainer = ProxyTrainer::new(rt, 6).unwrap();
    let mut st = trainer.init_supernet(1).unwrap();
    let space = NasSpace::new(NasSpaceId::Proxy);
    let mut rng = Rng::new(8);
    let d = space.random(&mut rng);
    for _ in 0..3 {
        let (loss, acc) = trainer.supernet_step(&mut st, &d, 0.005).unwrap();
        assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
    }
    let e1 = trainer.supernet_eval(&st, &d).unwrap();
    let e2 = trainer.supernet_eval(&st, &d).unwrap();
    assert_eq!(e1, e2, "eval must be deterministic for fixed weights+masks");
}

#[test]
fn costmodel_roundtrip_learns() {
    let Some(mut rt) = runtime() else { return };
    use nahas::costmodel::{generate_dataset, CostModel};
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let mut rng = Rng::new(9);
    let (data, norm) = generate_dataset(&space, 512, &mut rng);
    let mut cm = CostModel::init(&mut rt, norm, 1).unwrap();
    let losses = cm.train(&mut rt, &data, 120, &mut rng).unwrap();
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.5),
        "cost model loss {} -> {}",
        losses[0],
        losses.last().unwrap()
    );
    // Predictions in a sane physical range.
    let feats: Vec<Vec<f32>> = data[..16].iter().map(|s| s.features.clone()).collect();
    let preds = cm.predict(&mut rt, &feats).unwrap();
    for (lat, area) in preds {
        assert!(lat > 1e-3 && lat < 100.0, "latency {lat}");
        assert!(area > 5.0 && area < 1000.0, "area {area}");
    }
}
