//! Run logging: CSV/JSON emitters for search histories and bench rows,
//! written under `results/` so every paper figure can be re-plotted —
//! plus [`stream`]: the live JSONL metrics side channel
//! (`--metrics FILE --metrics-interval SECS`) that makes long sweeps
//! observable while they run.

use std::collections::VecDeque;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::search::joint::Sample;

pub mod stream;

pub use stream::{MetricsRow, MetricsSink, MetricsStreamer};

/// Write a search history as CSV (one row per trial — the raw data
/// behind Fig. 7's scatter and Fig. 9's curves).
pub fn write_history_csv(path: impl AsRef<Path>, history: &[Sample]) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating parent directory {parent:?}"))?;
    }
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    writeln!(f, "index,valid,acc,latency_ms,energy_mj,area_mm2,reward")?;
    for s in history {
        writeln!(
            f,
            "{},{},{:.6},{:.6},{:.6},{:.3},{:.6}",
            s.index,
            s.result.valid as u8,
            s.result.acc,
            s.result.latency_ms,
            s.result.energy_mj,
            s.result.area_mm2,
            s.reward
        )?;
    }
    Ok(())
}

/// Write generic (x, series...) rows as CSV.
pub fn write_csv(
    path: impl AsRef<Path>,
    headers: &[&str],
    rows: &[Vec<String>],
) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating parent directory {parent:?}"))?;
    }
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    writeln!(f, "{}", headers.join(","))?;
    for r in rows {
        writeln!(f, "{}", r.join(","))?;
    }
    Ok(())
}

/// Running mean/max tracker for reward curves.
#[derive(Default, Clone, Debug)]
pub struct RewardCurve {
    pub steps: Vec<usize>,
    pub mean: Vec<f64>,
    pub max: Vec<f64>,
    window: VecDeque<f64>,
    best: f64,
}

impl RewardCurve {
    pub fn new() -> Self {
        RewardCurve { best: f64::NEG_INFINITY, ..Default::default() }
    }

    pub fn push(&mut self, step: usize, reward: f64, window: usize) {
        // Ring buffer: O(1) per push where `Vec::remove(0)` was O(n)
        // (quadratic over a long search). The deque iterates front to
        // back, the same order the Vec summed in, so the mean series
        // stays bit-identical.
        self.window.push_back(reward);
        if self.window.len() > window {
            self.window.pop_front();
        }
        self.best = self.best.max(reward);
        self.steps.push(step);
        self.mean.push(self.window.iter().sum::<f64>() / self.window.len() as f64);
        self.max.push(self.best);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::evaluator::EvalResult;

    #[test]
    fn history_csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join("nahas_test_metrics");
        let path = dir.join("h.csv");
        let hist = vec![Sample {
            index: 0,
            nas_d: vec![0],
            has_d: vec![0],
            result: EvalResult {
                acc: 0.75,
                latency_ms: 0.4,
                energy_mj: 0.9,
                area_mm2: 80.0,
                valid: true,
            },
            reward: 0.75,
        }];
        write_history_csv(&path, &hist).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("index,valid,acc"));
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reward_curve_tracks_max_and_mean() {
        let mut c = RewardCurve::new();
        for (i, r) in [0.1, 0.5, 0.3].iter().enumerate() {
            c.push(i, *r, 2);
        }
        assert_eq!(c.max, vec![0.1, 0.5, 0.5]);
        assert!((c.mean[2] - 0.4).abs() < 1e-12);
    }
}
