//! Live metrics streaming: the JSONL side channel behind `nahas sweep
//! --metrics FILE --metrics-interval SECS` (and `nahas serve
//! --metrics`).
//!
//! A long sweep is otherwise a black box until it prints its final
//! tables; this module makes it observable while it runs without
//! perturbing what it computes:
//!
//! * [`MetricsSink`] owns the output file and writes one compact JSON
//!   object per line ([`MetricsRow`]), flushed per row so `tail -f`
//!   (or a crashed run's partial file) always ends on a complete line;
//! * rows are built from [`EvalBroker::snapshot`] — the broker's
//!   *non-blocking* observation seam. Unlike `EvalBroker::stats`, a
//!   snapshot never waits out an in-flight dispatch, so the observer
//!   can never stall the sweep; the price is that the backend's own
//!   counters (wire bytes, per-host attribution) are only fresh when
//!   the backend happened to be parked, and the sink carries the last
//!   known values forward (`backend_fresh` says which);
//! * [`MetricsStreamer`] runs the sink on a background thread at a
//!   fixed interval, printing a one-line progress summary to stderr
//!   per row; [`MetricsStreamer::stop`] emits one final row (so even a
//!   sweep shorter than the interval gets a complete stream) and a
//!   final stderr summary line.
//!
//! Determinism contract: observation is read-only. The snapshot takes
//! the broker's state lock for bounded bookkeeping only, and the sweep
//! progress gauge is relaxed atomics — a run with `--metrics` attached
//! produces bit-identical search results to one without
//! (`tests/metrics_stream.rs`).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::cluster::membership::{MembershipEvent, MembershipLog};
use crate::search::broker::{BrokerSnapshot, EvalBroker, SessionCounters};
use crate::search::evaluator::HostEvalStats;
use crate::search::sweep::SweepProgress;
use crate::util::json::{obj, Json};

fn num(n: usize) -> Json {
    Json::Num(n as f64)
}

/// One emitted metrics row: cumulative broker counters, live gauges,
/// per-interval rates, and the per-session / per-host breakdowns.
/// Serialized as one JSON object per line by [`MetricsRow::to_json`].
#[derive(Clone, Debug)]
pub struct MetricsRow {
    /// 0-based row index within the stream.
    pub row: usize,
    /// Seconds since the stream started.
    pub t_s: f64,
    /// Cumulative samples requested through the broker.
    pub requests: usize,
    /// Cumulative backend evaluations (deduped misses).
    pub evals: usize,
    /// `requests - evals`: every flavor of cache/dedup hit.
    pub cache_hits: usize,
    pub invalid: usize,
    pub cross_session_hits: usize,
    pub persisted_hits: usize,
    pub inflight_hits: usize,
    /// Claimed keys parked in the dispatch queue right now (gauge).
    pub queue_depth: usize,
    /// Session batches currently admitted (gauge).
    pub admitted: usize,
    /// Claimed-but-unfinished keys in flight (gauge).
    pub inflight_keys: usize,
    pub dispatches: usize,
    pub coalesced_dispatches: usize,
    pub chunked_dispatches: usize,
    /// Backend evaluations since the previous row.
    pub evals_delta: usize,
    /// `evals_delta` over the wall-clock interval since the previous
    /// row (0 for the first row or a zero-length interval).
    pub evals_per_sec: f64,
    /// Cumulative wire bytes written (remote backends; carried forward
    /// from the last fresh backend view when mid-dispatch).
    pub wire_tx_bytes: u64,
    /// Cumulative wire bytes read.
    pub wire_rx_bytes: u64,
    /// Whether the backend counters in this row were read at snapshot
    /// time (`true`) or carried forward from an earlier row because a
    /// dispatch was in flight (`false`).
    pub backend_fresh: bool,
    /// Hosts currently marked down (cluster backend; carried forward
    /// like the wire counters).
    pub hosts_down: usize,
    /// Per-session cumulative deltas; these sum to the broker-wide
    /// counters above at every row.
    pub sessions: Vec<SessionCounters>,
    /// Per-host attribution (cluster backend; carried forward).
    pub per_host: Vec<HostEvalStats>,
    /// Sweep scenarios completed, when a progress gauge is attached.
    pub scenarios_done: Option<usize>,
    /// Total sweep scenarios, when a progress gauge is attached.
    pub scenarios_total: Option<usize>,
    /// Cluster membership transitions applied since the previous row
    /// (empty unless a [`MembershipLog`] is attached and a join/leave
    /// happened in this interval).
    pub membership: Vec<MembershipEvent>,
}

impl MetricsRow {
    /// The row as a compact single-line JSON object.
    pub fn to_json(&self) -> Json {
        let sessions = Json::Arr(
            self.sessions
                .iter()
                .map(|s| {
                    obj(vec![
                        ("id", num(s.id as usize)),
                        ("requests", num(s.requests)),
                        ("evals", num(s.evals)),
                        ("invalid", num(s.invalid)),
                        ("cross_session_hits", num(s.cross_session_hits)),
                        ("persisted_hits", num(s.persisted_hits)),
                        ("inflight_hits", num(s.inflight_hits)),
                        ("dispatched_chunks", num(s.dispatched_chunks)),
                    ])
                })
                .collect(),
        );
        let per_host = Json::Arr(
            self.per_host
                .iter()
                .map(|h| {
                    obj(vec![
                        ("host", Json::Str(h.host.clone())),
                        ("requests", num(h.requests)),
                        ("evals", num(h.evals)),
                        ("down", Json::Bool(h.down)),
                    ])
                })
                .collect(),
        );
        let mut pairs = vec![
            ("row", num(self.row)),
            ("t_s", Json::Num(self.t_s)),
            ("requests", num(self.requests)),
            ("evals", num(self.evals)),
            ("cache_hits", num(self.cache_hits)),
            ("invalid", num(self.invalid)),
            ("cross_session_hits", num(self.cross_session_hits)),
            ("persisted_hits", num(self.persisted_hits)),
            ("inflight_hits", num(self.inflight_hits)),
            ("queue_depth", num(self.queue_depth)),
            ("admitted", num(self.admitted)),
            ("inflight_keys", num(self.inflight_keys)),
            ("dispatches", num(self.dispatches)),
            ("coalesced_dispatches", num(self.coalesced_dispatches)),
            ("chunked_dispatches", num(self.chunked_dispatches)),
            ("evals_delta", num(self.evals_delta)),
            ("evals_per_sec", Json::Num(self.evals_per_sec)),
            ("wire_tx_bytes", Json::Num(self.wire_tx_bytes as f64)),
            ("wire_rx_bytes", Json::Num(self.wire_rx_bytes as f64)),
            ("backend_fresh", Json::Bool(self.backend_fresh)),
            ("hosts_down", num(self.hosts_down)),
            ("sessions", sessions),
            ("per_host", per_host),
        ];
        if let Some(done) = self.scenarios_done {
            pairs.push(("scenarios_done", num(done)));
        }
        if let Some(total) = self.scenarios_total {
            pairs.push(("scenarios_total", num(total)));
        }
        if !self.membership.is_empty() {
            let events = self
                .membership
                .iter()
                .map(|e| {
                    obj(vec![
                        ("batch", num(e.batch)),
                        ("action", Json::Str(e.action.to_string())),
                        ("addr", Json::Str(e.addr.clone())),
                        ("hosts", num(e.hosts)),
                        ("handed_off", num(e.handed_off)),
                    ])
                })
                .collect();
            pairs.push(("membership", Json::Arr(events)));
        }
        obj(pairs)
    }

    /// The one-line stderr progress summary for this row.
    pub fn progress_line(&self) -> String {
        let mut line = format!(
            "[metrics] t={:.1}s evals={} (+{}, {:.1}/s) cache_hits={} queue={} admitted={}",
            self.t_s,
            self.evals,
            self.evals_delta,
            self.evals_per_sec,
            self.cache_hits,
            self.queue_depth,
            self.admitted,
        );
        if let (Some(done), Some(total)) = (self.scenarios_done, self.scenarios_total) {
            line.push_str(&format!(" scenarios={done}/{total}"));
        }
        for e in &self.membership {
            line.push_str(&format!(" [{} {}]", e.action, e.addr));
        }
        line
    }
}

/// Owns the JSONL output file and turns [`BrokerSnapshot`]s into
/// written [`MetricsRow`]s. Carries backend-tier values (wire bytes,
/// per-host stats) forward across snapshots that caught the backend
/// checked out, and tracks the per-interval eval delta/rate.
pub struct MetricsSink {
    out: BufWriter<File>,
    path: PathBuf,
    rows: usize,
    last_t: f64,
    last_evals: usize,
    last_wire: (u64, u64),
    last_hosts_down: usize,
    last_per_host: Vec<HostEvalStats>,
    /// Membership event source + drain cursor, when attached.
    membership: Option<(MembershipLog, usize)>,
}

impl MetricsSink {
    /// Create (truncate) the stream file, creating parent directories
    /// as needed. All I/O errors propagate with path context.
    pub fn create(path: impl AsRef<Path>) -> Result<MetricsSink> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating parent directory {parent:?}"))?;
            }
        }
        let f = File::create(&path).with_context(|| format!("creating {path:?}"))?;
        Ok(MetricsSink {
            out: BufWriter::new(f),
            path,
            rows: 0,
            last_t: 0.0,
            last_evals: 0,
            last_wire: (0, 0),
            last_hosts_down: 0,
            last_per_host: Vec::new(),
            membership: None,
        })
    }

    /// Attach a cluster [`MembershipLog`]: join/leave transitions
    /// applied since the previous row ride along in that row's
    /// `membership` array (and its stderr progress line), so a metrics
    /// stream records exactly when the pool changed shape.
    pub fn with_membership(mut self, log: MembershipLog) -> MetricsSink {
        self.membership = Some((log, 0));
        self
    }

    /// Where the stream is being written.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Rows written so far.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Build one row from a broker snapshot at stream time `t_s`
    /// (seconds since the stream started), write it as one JSON line,
    /// and flush — so the file always ends on a complete line.
    /// `scenarios` is `(completed, total)` when a sweep progress gauge
    /// is attached.
    pub fn emit(
        &mut self,
        t_s: f64,
        snap: &BrokerSnapshot,
        scenarios: Option<(usize, usize)>,
    ) -> Result<MetricsRow> {
        let backend_fresh = snap.backend.is_some();
        if let Some(b) = &snap.backend {
            self.last_wire = (b.wire_tx, b.wire_rx);
            self.last_hosts_down = b.hosts_down;
            self.last_per_host = b.per_host.clone();
        }
        let events = match &mut self.membership {
            Some((log, cursor)) => {
                let (events, next) = log.since(*cursor);
                *cursor = next;
                events
            }
            None => Vec::new(),
        };
        let dt = t_s - self.last_t;
        let evals_delta = snap.evals.saturating_sub(self.last_evals);
        let evals_per_sec =
            if self.rows > 0 && dt > 0.0 { evals_delta as f64 / dt } else { 0.0 };
        let row = MetricsRow {
            row: self.rows,
            t_s,
            requests: snap.requests,
            evals: snap.evals,
            cache_hits: snap.requests.saturating_sub(snap.evals),
            invalid: snap.invalid,
            cross_session_hits: snap.cross_session_hits,
            persisted_hits: snap.persisted_hits,
            inflight_hits: snap.inflight_hits,
            queue_depth: snap.queue_depth,
            admitted: snap.admitted,
            inflight_keys: snap.inflight_keys,
            dispatches: snap.dispatches,
            coalesced_dispatches: snap.coalesced_dispatches,
            chunked_dispatches: snap.chunked_dispatches,
            evals_delta,
            evals_per_sec,
            wire_tx_bytes: self.last_wire.0,
            wire_rx_bytes: self.last_wire.1,
            backend_fresh,
            hosts_down: self.last_hosts_down,
            sessions: snap.sessions.clone(),
            per_host: self.last_per_host.clone(),
            scenarios_done: scenarios.map(|(done, _)| done),
            scenarios_total: scenarios.map(|(_, total)| total),
            membership: events,
        };
        writeln!(self.out, "{}", row.to_json())
            .with_context(|| format!("writing metrics row to {:?}", self.path))?;
        self.out
            .flush()
            .with_context(|| format!("flushing metrics stream {:?}", self.path))?;
        self.rows += 1;
        self.last_t = t_s;
        self.last_evals = snap.evals;
        Ok(row)
    }
}

/// Background observer: snapshots a broker every `interval`, streams
/// rows through a [`MetricsSink`], and prints a progress line to
/// stderr per row. The observed broker/sweep never waits on it.
pub struct MetricsStreamer {
    stop_tx: mpsc::Sender<()>,
    handle: JoinHandle<Result<(PathBuf, usize)>>,
}

impl MetricsStreamer {
    /// Start streaming. `progress`, when given, attributes sweep
    /// completion (`scenarios_done/_total`) to every row. Intervals
    /// below 50 ms are clamped up — the snapshot itself is cheap, but
    /// a zero interval would busy-spin the observer thread.
    pub fn spawn(
        broker: EvalBroker,
        mut sink: MetricsSink,
        interval: Duration,
        progress: Option<Arc<SweepProgress>>,
    ) -> MetricsStreamer {
        let interval = interval.max(Duration::from_millis(50));
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        let handle = std::thread::spawn(move || -> Result<(PathBuf, usize)> {
            let t0 = Instant::now();
            loop {
                // An interruptible sleep: a stop request (or the
                // handle being dropped) ends the stream after one
                // final row, so short runs still get a complete file.
                let stopped = !matches!(
                    stop_rx.recv_timeout(interval),
                    Err(mpsc::RecvTimeoutError::Timeout)
                );
                let snap = broker.snapshot();
                let scen = progress.as_ref().map(|p| (p.completed(), p.total()));
                let row = sink.emit(t0.elapsed().as_secs_f64(), &snap, scen)?;
                if stopped {
                    eprintln!(
                        "[metrics] final: {} rows -> {} ({} evals, {} cache hits, {} dispatches)",
                        sink.rows(),
                        sink.path().display(),
                        row.evals,
                        row.cache_hits,
                        row.dispatches,
                    );
                    return Ok((sink.path().to_path_buf(), sink.rows()));
                }
                eprintln!("{}", row.progress_line());
            }
        });
        MetricsStreamer { stop_tx, handle }
    }

    /// Stop the stream: emits one final row and the final stderr
    /// summary, then returns `(path, rows_written)`. Propagates any
    /// write error the streamer thread hit.
    pub fn stop(self) -> Result<(PathBuf, usize)> {
        let _ = self.stop_tx.send(());
        self.handle.join().map_err(|_| anyhow!("metrics streamer thread panicked"))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(requests: usize, evals: usize) -> BrokerSnapshot {
        BrokerSnapshot { requests, evals, ..Default::default() }
    }

    #[test]
    fn rows_are_single_parseable_json_lines() {
        let dir = std::env::temp_dir().join("nahas_test_metrics_stream");
        let path = dir.join("rows.jsonl");
        let mut sink = MetricsSink::create(&path).unwrap();
        sink.emit(0.0, &snap(10, 4), Some((0, 3))).unwrap();
        sink.emit(1.0, &snap(30, 9), Some((2, 3))).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(first.get("evals").unwrap().as_usize(), Some(4));
        assert_eq!(second.get("evals").unwrap().as_usize(), Some(9));
        assert_eq!(second.get("evals_delta").unwrap().as_usize(), Some(5));
        assert_eq!(second.get("cache_hits").unwrap().as_usize(), Some(21));
        assert_eq!(second.get("scenarios_done").unwrap().as_usize(), Some(2));
        assert!((second.get("evals_per_sec").unwrap().as_f64().unwrap() - 5.0).abs() < 1e-9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn membership_events_ride_along_in_rows_once() {
        let dir = std::env::temp_dir().join("nahas_test_metrics_membership");
        let path = dir.join("rows.jsonl");
        let log = MembershipLog::default();
        let mut sink = MetricsSink::create(&path).unwrap().with_membership(log.clone());
        sink.emit(0.0, &snap(2, 2), None).unwrap();
        log.push(MembershipEvent {
            batch: 3,
            action: "join",
            addr: "10.0.0.4:7878".to_string(),
            hosts: 3,
            handed_off: 17,
            detail: String::new(),
        });
        let row = sink.emit(1.0, &snap(4, 4), None).unwrap();
        assert_eq!(row.membership.len(), 1);
        assert!(row.progress_line().contains("[join 10.0.0.4:7878]"), "{}", row.progress_line());
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        let first = Json::parse(lines[0]).unwrap();
        assert!(first.get("membership").is_none(), "no events -> no membership field");
        let second = Json::parse(lines[1]).unwrap();
        let events = second.get("membership").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("action").unwrap().as_str(), Some("join"));
        assert_eq!(events[0].get("addr").unwrap().as_str(), Some("10.0.0.4:7878"));
        assert_eq!(events[0].get("handed_off").unwrap().as_usize(), Some(17));
        // The event was drained: the next row carries nothing.
        let row = sink.emit(2.0, &snap(5, 5), None).unwrap();
        assert!(row.membership.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn backend_values_carry_forward_when_checked_out() {
        let dir = std::env::temp_dir().join("nahas_test_metrics_carry");
        let path = dir.join("rows.jsonl");
        let mut sink = MetricsSink::create(&path).unwrap();
        let mut fresh = snap(5, 5);
        fresh.backend = Some(crate::search::broker::BackendSnapshot {
            requests: 5,
            hosts_down: 1,
            per_host: Vec::new(),
            wire_tx: 100,
            wire_rx: 200,
        });
        let r0 = sink.emit(0.0, &fresh, None).unwrap();
        assert!(r0.backend_fresh);
        // Next snapshot catches the backend mid-dispatch: wire and
        // host values repeat instead of dropping to zero.
        let r1 = sink.emit(1.0, &snap(8, 8), None).unwrap();
        assert!(!r1.backend_fresh);
        assert_eq!((r1.wire_tx_bytes, r1.wire_rx_bytes), (100, 200));
        assert_eq!(r1.hosts_down, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
