//! Phase-based search (the Fig. 9 ablation): instead of the joint space,
//! first run HAS on a *fixed initial architecture* with the soft
//! constraint (find a latency/area-Pareto accelerator), then run NAS on
//! the selected accelerator with the hard constraint.
//!
//! The paper finds this consistently worse than joint search at equal
//! sample budgets, with large variance from the initial-architecture
//! choice — which these benches reproduce.

use crate::has::HasSpace;
use crate::nas::NasSpace;
use crate::search::broker::EvalBroker;
use crate::search::evaluator::EvalStats;
use crate::search::joint::{joint_search, JointLayout, SearchCfg, SearchOutcome};
use crate::search::ppo::PpoController;

pub struct PhaseOutcome {
    pub has_phase: SearchOutcome,
    pub nas_phase: SearchOutcome,
    /// The accelerator selected by phase 1.
    pub selected_hw: Vec<usize>,
    /// Evaluator counters summed over both phases — the whole run's
    /// cache-hit/throughput picture (each phase also keeps its own in
    /// its `SearchOutcome`).
    pub eval_stats: EvalStats,
}

/// Run HAS-then-NAS with the total budget split evenly.
///
/// `initial_nas` is the fixed architecture of phase 1 (the paper tries
/// MobileNetV2 / EfficientNet-B1 / EfficientNet-B2 and observes high
/// variance in the final quality).
///
/// The driver runs over the shared [`EvalBroker`] seam: each phase
/// opens its own broker session (so the two phases report separate
/// counter deltas), while both share the broker's cross-search memo
/// cache — and, inside a sweep, share it with every *other* scenario
/// running concurrently on the same broker. Both phases go through the
/// batch-structured [`joint_search`] driver, so whatever backend the
/// broker wraps (parallel workers, service farm, cluster pool)
/// parallelizes each phase's evaluations.
pub fn phase_search(
    broker: &EvalBroker,
    space: &NasSpace,
    initial_nas: &[usize],
    cfg: &SearchCfg,
) -> PhaseOutcome {
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(space, &has);
    let has_cards = cards[layout.nas_len..].to_vec();
    let nas_cards = cards[..layout.nas_len].to_vec();

    // Phase 1: HAS with the soft constraint on the fixed initial arch.
    let mut p1_cfg = cfg.clone();
    p1_cfg.samples = cfg.samples / 2;
    p1_cfg.reward = cfg.reward.soft();
    let mut has_ctl = PpoController::new(&has_cards);
    let mut p1_session = broker.session();
    let has_phase =
        joint_search(&mut p1_session, &mut has_ctl, &layout, None, Some(initial_nas), &p1_cfg);
    let selected_hw = has_phase
        .best
        .as_ref()
        .map(|s| s.has_d.clone())
        .unwrap_or_else(|| has.baseline_decisions());

    // Phase 2: NAS with the hard constraint on the selected hardware.
    let mut p2_cfg = cfg.clone();
    p2_cfg.samples = cfg.samples - p1_cfg.samples;
    p2_cfg.seed = cfg.seed ^ 0xF2;
    let mut nas_ctl = PpoController::new(&nas_cards);
    let mut p2_session = broker.session();
    let nas_phase =
        joint_search(&mut p2_session, &mut nas_ctl, &layout, Some(&selected_hw), None, &p2_cfg);

    let eval_stats = has_phase.eval_stats.merged(&nas_phase.eval_stats);
    PhaseOutcome { has_phase, nas_phase, selected_hw, eval_stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::NasSpaceId;
    use crate::search::evaluator::SurrogateSim;
    use crate::search::reward::RewardCfg;

    #[test]
    fn phase_search_runs_and_selects_hw() {
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let sim = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 5);
        let broker = EvalBroker::new(Box::new(sim));
        let initial = vec![0; space.num_decisions()];
        let cfg = SearchCfg::new(200, RewardCfg::latency(0.5), 5);
        let out = phase_search(&broker, &space, &initial, &cfg);
        assert_eq!(out.selected_hw.len(), 7);
        assert!(out.nas_phase.best_feasible.is_some());
        // The aggregated stats cover BOTH phases of the run: each
        // phase reports its own broker-session delta, and the
        // whole-run view is their sum.
        let (h, n) = (&out.has_phase.eval_stats, &out.nas_phase.eval_stats);
        assert_eq!(out.eval_stats.requests, h.requests + n.requests);
        assert_eq!(out.eval_stats.requests, 200);
        assert_eq!(out.eval_stats.evals, h.evals + n.evals);
        assert_eq!(out.eval_stats.invalid, h.invalid + n.invalid);
        // No double counting across the broker seam: the two session
        // deltas sum to the broker's global counters, and the backend
        // saw exactly the broker's deduped misses.
        let g = broker.stats();
        assert_eq!(g.requests, out.eval_stats.requests);
        assert_eq!(g.evals, out.eval_stats.evals);
        assert_eq!(g.cache_hits, out.eval_stats.cache_hits);
        assert_eq!(g.invalid, out.eval_stats.invalid);
        assert_eq!(g.cross_session_hits, out.eval_stats.cross_session_hits);
        assert_eq!(broker.backend_stats().requests, g.evals);
    }

    #[test]
    fn joint_beats_phase_at_equal_budget() {
        // Fig. 9's headline: phase search with 1x samples is much worse
        // than joint multi-trial. Assert on the majority of seeds.
        let mut joint_wins = 0;
        for seed in [1u64, 2, 3] {
            let space = NasSpace::new(NasSpaceId::EfficientNet);
            let cfg = SearchCfg::new(300, RewardCfg::latency(0.5), seed);

            let sim = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed);
            let broker = EvalBroker::new(Box::new(sim));
            let initial = vec![0; space.num_decisions()];
            let phase = phase_search(&broker, &space, &initial, &cfg);
            let phase_acc =
                phase.nas_phase.best_feasible.as_ref().map(|s| s.result.acc).unwrap_or(0.0);

            let has = HasSpace::new();
            let (cards, layout) = JointLayout::cards(&space, &has);
            let mut ev2 = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed);
            let mut ctl = PpoController::new(&cards);
            let joint = joint_search(&mut ev2, &mut ctl, &layout, None, None, &cfg);
            let joint_acc =
                joint.best_feasible.as_ref().map(|s| s.result.acc).unwrap_or(0.0);
            if joint_acc >= phase_acc - 0.003 {
                joint_wins += 1;
            }
        }
        assert!(joint_wins >= 2, "joint won only {joint_wins}/3 seeds");
    }
}
