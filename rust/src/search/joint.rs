//! Multi-trial joint search driver (paper §3.5.1).
//!
//! The controller samples the concatenated NAS ++ HAS decision vector;
//! each sample is evaluated (trained / surrogate-scored + simulated),
//! rewarded by Eq. 4, and fed back in PPO batches. Fixing the HAS half
//! (`has_fixed`) reduces the problem to platform-aware NAS — the paper's
//! "fixed accelerator" rows; fixing the NAS half gives pure HAS.
//!
//! This is the *leaf* driver under the shared evaluation seam: it
//! borrows one [`Evaluator`] for the duration of one search. Callers
//! that share an evaluation substrate between searches (the `phase`
//! driver's two phases, every `nahas sweep` scenario, the CLI itself)
//! hand it a [`crate::search::BrokerSession`] — each session is an
//! `Evaluator` view onto the shared [`crate::search::EvalBroker`].

use crate::nas::NasSpace;
use crate::search::evaluator::{EvalResult, Evaluator};
use crate::search::reward::RewardCfg;
use crate::search::Controller;
use crate::util::Rng;

/// One evaluated trial.
#[derive(Clone, Debug)]
pub struct Sample {
    pub index: usize,
    pub nas_d: Vec<usize>,
    pub has_d: Vec<usize>,
    pub result: EvalResult,
    pub reward: f64,
}

#[derive(Clone, Debug)]
pub struct SearchCfg {
    /// Total controller samples (the paper's search budget knob).
    pub samples: usize,
    /// Controller update batch (trials per PPO update).
    pub batch: usize,
    pub reward: RewardCfg,
    pub seed: u64,
    /// Keep full sample history (Fig. 7 plots need it).
    pub keep_history: bool,
}

impl SearchCfg {
    pub fn new(samples: usize, reward: RewardCfg, seed: u64) -> Self {
        SearchCfg { samples, batch: 16, reward, seed, keep_history: true }
    }
}

#[derive(Debug, Default)]
pub struct SearchOutcome {
    pub history: Vec<Sample>,
    pub best: Option<Sample>,
    /// Best among *feasible* samples (meeting both constraints).
    pub best_feasible: Option<Sample>,
    pub num_invalid: usize,
    /// Evaluator-side counters (cache hits, actual evaluations).
    pub eval_stats: crate::search::evaluator::EvalStats,
    /// Wall-clock of the search loop, for throughput reporting.
    pub elapsed_s: f64,
}

impl SearchOutcome {
    /// End-to-end sample throughput of the finished search.
    pub fn samples_per_s(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.history.len().max(self.eval_stats.requests) as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    fn consider(&mut self, s: &Sample, reward_cfg: &RewardCfg) {
        if !s.result.valid {
            self.num_invalid += 1;
        }
        if self.best.as_ref().map(|b| s.reward > b.reward).unwrap_or(true) {
            self.best = Some(s.clone());
        }
        if reward_cfg.feasible(&s.result)
            && self
                .best_feasible
                .as_ref()
                .map(|b| s.result.acc > b.result.acc)
                .unwrap_or(true)
        {
            self.best_feasible = Some(s.clone());
        }
    }
}

/// Decision-vector layout of a joint search.
pub struct JointLayout {
    pub nas_len: usize,
    pub has_len: usize,
}

impl JointLayout {
    pub fn cards(space: &NasSpace, has: &crate::has::HasSpace) -> (Vec<usize>, JointLayout) {
        let mut cards: Vec<usize> = space.specs().iter().map(|s| s.cardinality).collect();
        let nas_len = cards.len();
        cards.extend(has.specs().iter().map(|s| s.cardinality));
        (cards.clone(), JointLayout { nas_len, has_len: cards.len() - nas_len })
    }

    pub fn split<'a>(&self, d: &'a [usize]) -> (&'a [usize], &'a [usize]) {
        d.split_at(self.nas_len)
    }
}

/// Run a multi-trial search. `has_fixed` pins the hardware (platform-
/// aware NAS); `nas_fixed` pins the architecture (pure HAS). The
/// controller must be sized for the *free* decisions only.
///
/// The loop is batch-structured: a full PPO batch (`cfg.batch`) is
/// sampled up front, evaluated in one [`Evaluator::evaluate_batch`]
/// call (which parallel/remote evaluators fan out), and then rewarded
/// and applied **in sample order**. Because all `cfg.batch` samples
/// were always drawn from the same policy before any update (the
/// serial loop only updated once a batch filled), this produces
/// bit-identical trajectories to the historical one-at-a-time driver
/// for the same seed.
pub fn joint_search(
    evaluator: &mut dyn Evaluator,
    controller: &mut dyn Controller,
    layout: &JointLayout,
    has_fixed: Option<&[usize]>,
    nas_fixed: Option<&[usize]>,
    cfg: &SearchCfg,
) -> SearchOutcome {
    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let mut outcome = SearchOutcome::default();
    let batch_size = cfg.batch.max(1);
    // Evaluator counters are cumulative; report this search's delta.
    let stats_at_start = evaluator.stats();

    let mut index = 0;
    while index < cfg.samples {
        let n = batch_size.min(cfg.samples - index);
        // 1. Sample the whole batch from the current policy.
        let mut frees: Vec<Vec<usize>> = Vec::with_capacity(n);
        let mut pairs: Vec<(Vec<usize>, Vec<usize>)> = Vec::with_capacity(n);
        for _ in 0..n {
            let free = controller.sample(&mut rng);
            let (nas_d, has_d): (Vec<usize>, Vec<usize>) = match (has_fixed, nas_fixed) {
                (Some(h), None) => (free.clone(), h.to_vec()),
                (None, Some(n)) => (n.to_vec(), free.clone()),
                (None, None) => {
                    let (n, h) = layout.split(&free);
                    (n.to_vec(), h.to_vec())
                }
                (Some(_), Some(_)) => panic!("cannot fix both halves"),
            };
            frees.push(free);
            pairs.push((nas_d, has_d));
        }
        // 2. Evaluate it in one call (parallel evaluators fan out here).
        let results = evaluator.evaluate_batch(&pairs);
        // Hard assert: a short result vector would silently drop the
        // tail samples from rewards/history in a zip.
        assert_eq!(results.len(), n, "evaluate_batch must preserve batch length");
        // 3. Reward + record in sample order, then one controller update.
        let mut batch: Vec<(Vec<usize>, f64)> = Vec::with_capacity(n);
        for (i, ((nas_d, has_d), result)) in pairs.into_iter().zip(results).enumerate() {
            let reward = cfg.reward.reward(&result);
            let sample = Sample { index: index + i, nas_d, has_d, result, reward };
            outcome.consider(&sample, &cfg.reward);
            if cfg.keep_history {
                outcome.history.push(sample);
            }
            batch.push((std::mem::take(&mut frees[i]), reward));
        }
        controller.update(&batch);
        index += n;
    }
    outcome.eval_stats = evaluator.stats().since(&stats_at_start);
    outcome.elapsed_s = t0.elapsed().as_secs_f64();
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::has::HasSpace;
    use crate::nas::NasSpaceId;
    use crate::search::evaluator::SurrogateSim;
    use crate::search::ppo::PpoController;
    use crate::search::RandomController;

    fn run(samples: usize, fixed_hw: bool, seed: u64, t_ms: f64) -> SearchOutcome {
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let has = HasSpace::new();
        let (cards, layout) = JointLayout::cards(&space, &has);
        let mut ev = SurrogateSim::new(space, seed);
        let cfg = SearchCfg::new(samples, RewardCfg::latency(t_ms), seed);
        if fixed_hw {
            let nas_cards = cards[..layout.nas_len].to_vec();
            let mut ctl = PpoController::new(&nas_cards);
            let baseline = has.baseline_decisions();
            joint_search(&mut ev, &mut ctl, &layout, Some(&baseline), None, &cfg)
        } else {
            let mut ctl = PpoController::new(&cards);
            joint_search(&mut ev, &mut ctl, &layout, None, None, &cfg)
        }
    }

    #[test]
    fn search_produces_feasible_best() {
        let out = run(200, false, 3, 0.5);
        assert_eq!(out.history.len(), 200);
        let best = out.best_feasible.expect("found a feasible sample");
        assert!(best.result.latency_ms <= 0.5);
        assert!(best.result.acc > 0.5);
    }

    #[test]
    fn joint_beats_or_matches_fixed_hw_on_average() {
        // Fig. 2 / Table 3: the joint space dominates the fixed-hardware
        // one (it contains it). The gap is clearest at *tight* latency
        // targets where the production baseline accelerator is the wrong
        // design point (paper §4.4: small models want more PEs, less
        // memory). Assert over 3 seeds with controller noise.
        let mut joint_wins = 0;
        for seed in [11, 22, 33] {
            let j =
                run(400, false, seed, 0.25).best_feasible.map(|s| s.result.acc).unwrap_or(0.0);
            let f =
                run(400, true, seed, 0.25).best_feasible.map(|s| s.result.acc).unwrap_or(0.0);
            if j >= f - 0.002 {
                joint_wins += 1;
            }
        }
        assert!(joint_wins >= 2, "joint won {joint_wins}/3");
    }

    #[test]
    fn ppo_beats_random_given_budget() {
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let has = HasSpace::new();
        let (cards, layout) = JointLayout::cards(&space, &has);
        let cfg = SearchCfg::new(400, RewardCfg::latency(0.4), 7);

        let mut ev1 = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 7);
        let mut ppo = PpoController::new(&cards);
        let out_ppo = joint_search(&mut ev1, &mut ppo, &layout, None, None, &cfg);

        let mut ev2 = SurrogateSim::new(space, 7);
        let mut rnd = RandomController::new(cards);
        let out_rnd = joint_search(&mut ev2, &mut rnd, &layout, None, None, &cfg);

        let mean_tail = |o: &SearchOutcome| {
            let tail: Vec<f64> =
                o.history.iter().rev().take(50).map(|s| s.reward).collect();
            tail.iter().sum::<f64>() / tail.len() as f64
        };
        assert!(
            mean_tail(&out_ppo) > mean_tail(&out_rnd),
            "PPO tail {} vs random tail {}",
            mean_tail(&out_ppo),
            mean_tail(&out_rnd)
        );
    }
}
