//! PPO controller over a joint categorical decision space (paper §3.5.1
//! and §4.1: PPO, Adam lr 5e-4, policy gradients clipped at 1.0, reward
//! averaged over trials).
//!
//! The policy factorizes over decisions: independent learned logits per
//! decision position (the recurrent controller of the paper reduces to
//! this for a fixed-length decision sequence; factorized logits are what
//! TuNAS and most modern RL-NAS implementations use).

use crate::search::Controller;
use crate::util::Rng;

/// Factorized categorical policy.
#[derive(Clone, Debug)]
pub struct Policy {
    pub logits: Vec<Vec<f32>>,
}

impl Policy {
    pub fn new(cards: &[usize]) -> Self {
        Policy { logits: cards.iter().map(|&c| vec![0.0; c]).collect() }
    }

    pub fn probs(&self, i: usize) -> Vec<f32> {
        softmax(&self.logits[i])
    }

    pub fn sample(&self, rng: &mut Rng) -> Vec<usize> {
        self.logits.iter().map(|l| rng.categorical(&softmax(l))).collect()
    }

    pub fn log_prob(&self, d: &[usize]) -> f64 {
        d.iter()
            .enumerate()
            .map(|(i, &a)| (softmax(&self.logits[i])[a].max(1e-20) as f64).ln())
            .sum()
    }

    pub fn argmax(&self) -> Vec<usize> {
        // Total order so a NaN logit (diverged update) cannot panic the
        // argmax; NaN explicitly loses to every real logit (sorts
        // last), and `max_by`'s last-of-equals tie-break is unchanged
        // so the pick stays deterministic even when every logit is NaN.
        self.logits
            .iter()
            .map(|l| {
                l.iter()
                    .enumerate()
                    .max_by(|a, b| {
                        (!a.1.is_nan()).cmp(&!b.1.is_nan()).then(a.1.total_cmp(b.1))
                    })
                    .unwrap()
                    .0
            })
            .collect()
    }

    pub fn entropy(&self) -> f64 {
        self.logits
            .iter()
            .map(|l| {
                let p = softmax(l);
                -p.iter().map(|&x| (x.max(1e-20) as f64) * (x.max(1e-20) as f64).ln()).sum::<f64>()
            })
            .sum()
    }
}

pub fn softmax(l: &[f32]) -> Vec<f32> {
    let m = l.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let e: Vec<f32> = l.iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = e.iter().sum();
    e.iter().map(|&x| x / s).collect()
}

/// Flat Adam optimizer over the policy logits.
#[derive(Clone, Debug)]
pub struct Adam {
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: i32,
    pub lr: f32,
}

impl Adam {
    pub fn new(cards: &[usize], lr: f32) -> Self {
        Adam {
            m: cards.iter().map(|&c| vec![0.0; c]).collect(),
            v: cards.iter().map(|&c| vec![0.0; c]).collect(),
            t: 0,
            lr,
        }
    }

    /// Ascend `grad` (maximization), with global-norm clipping.
    pub fn step(&mut self, logits: &mut [Vec<f32>], grad: &mut [Vec<f32>], clip: f32) {
        let norm: f32 = grad
            .iter()
            .flat_map(|g| g.iter())
            .map(|&x| x * x)
            .sum::<f32>()
            .sqrt();
        if norm > clip {
            let s = clip / norm;
            for g in grad.iter_mut() {
                for x in g.iter_mut() {
                    *x *= s;
                }
            }
        }
        self.t += 1;
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let bc1 = 1.0 - b1.powi(self.t);
        let bc2 = 1.0 - b2.powi(self.t);
        for i in 0..logits.len() {
            for j in 0..logits[i].len() {
                let g = grad[i][j];
                self.m[i][j] = b1 * self.m[i][j] + (1.0 - b1) * g;
                self.v[i][j] = b2 * self.v[i][j] + (1.0 - b2) * g * g;
                let mh = self.m[i][j] / bc1;
                let vh = self.v[i][j] / bc2;
                logits[i][j] += self.lr * mh / (vh.sqrt() + eps);
            }
        }
    }
}

/// PPO with clipped surrogate objective + entropy bonus.
pub struct PpoController {
    pub policy: Policy,
    old: Policy,
    adam: Adam,
    baseline: f64,
    baseline_init: bool,
    /// Clip epsilon (0.2), entropy coefficient, epochs per update.
    pub clip: f32,
    pub entropy_coef: f32,
    pub epochs: usize,
}

impl PpoController {
    pub fn new(cards: &[usize]) -> Self {
        let policy = Policy::new(cards);
        PpoController {
            old: policy.clone(),
            policy,
            adam: Adam::new(cards, 5e-4 * 10.0), // paper lr 5e-4 per-trial; x10 for batched updates
            baseline: 0.0,
            baseline_init: false,
            clip: 0.2,
            entropy_coef: 0.01,
            epochs: 3,
        }
    }
}

impl Controller for PpoController {
    fn sample(&mut self, rng: &mut Rng) -> Vec<usize> {
        self.policy.sample(rng)
    }

    fn update(&mut self, batch: &[(Vec<usize>, f64)]) {
        if batch.is_empty() {
            return;
        }
        let mean_r: f64 = batch.iter().map(|(_, r)| r).sum::<f64>() / batch.len() as f64;
        if !self.baseline_init {
            self.baseline = mean_r;
            self.baseline_init = true;
        }
        self.old = self.policy.clone();
        let old_logp: Vec<f64> = batch.iter().map(|(d, _)| self.old.log_prob(d)).collect();

        for _ in 0..self.epochs {
            let mut grad: Vec<Vec<f32>> =
                self.policy.logits.iter().map(|l| vec![0.0; l.len()]).collect();
            for ((d, r), &olp) in batch.iter().zip(&old_logp) {
                let adv = (r - self.baseline) as f32;
                let ratio = (self.policy.log_prob(d) - olp).exp() as f32;
                // Clipped surrogate: gradient flows only when the ratio
                // is inside the trust region (or moving back into it).
                let use_grad = if adv >= 0.0 {
                    ratio <= 1.0 + self.clip
                } else {
                    ratio >= 1.0 - self.clip
                };
                if !use_grad {
                    continue;
                }
                let w = ratio * adv / batch.len() as f32;
                for (i, &a) in d.iter().enumerate() {
                    let p = softmax(&self.policy.logits[i]);
                    for j in 0..p.len() {
                        let onehot = if j == a { 1.0 } else { 0.0 };
                        grad[i][j] += w * (onehot - p[j]);
                    }
                }
            }
            // Entropy bonus: grad of H wrt logits = -p * (log p + H_i).
            for i in 0..self.policy.logits.len() {
                let p = softmax(&self.policy.logits[i]);
                let h: f32 = -p.iter().map(|&x| x.max(1e-20) * x.max(1e-20).ln()).sum::<f32>();
                for j in 0..p.len() {
                    grad[i][j] -= self.entropy_coef * p[j] * (p[j].max(1e-20).ln() + h);
                }
            }
            self.adam.step(&mut self.policy.logits, &mut grad, 1.0);
        }
        // EMA reward baseline (the paper's value estimate).
        self.baseline = 0.9 * self.baseline + 0.1 * mean_r;
    }

    fn best(&self) -> Vec<usize> {
        self.policy.argmax()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn policy_sample_in_range() {
        let pol = Policy::new(&[3, 5, 2]);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let d = pol.sample(&mut rng);
            assert!(d[0] < 3 && d[1] < 5 && d[2] < 2);
        }
    }

    #[test]
    fn ppo_learns_a_planted_optimum() {
        // Reward 1.0 iff decision == [2, 0, 3], partial credit per match.
        let cards = vec![3, 2, 4];
        let target = [2usize, 0, 3];
        let mut ctl = PpoController::new(&cards);
        let mut rng = Rng::new(42);
        for _ in 0..60 {
            let batch: Vec<(Vec<usize>, f64)> = (0..16)
                .map(|_| {
                    let d = ctl.sample(&mut rng);
                    let r = d.iter().zip(&target).filter(|(a, b)| a == b).count() as f64 / 3.0;
                    (d, r)
                })
                .collect();
            ctl.update(&batch);
        }
        assert_eq!(ctl.best(), target.to_vec(), "PPO should find the planted optimum");
    }

    #[test]
    fn entropy_decreases_as_policy_sharpens() {
        let cards = vec![4, 4];
        let mut ctl = PpoController::new(&cards);
        let h0 = ctl.policy.entropy();
        let mut rng = Rng::new(7);
        for _ in 0..40 {
            let batch: Vec<(Vec<usize>, f64)> = (0..8)
                .map(|_| {
                    let d = ctl.sample(&mut rng);
                    let r = if d[0] == 1 { 1.0 } else { 0.0 };
                    (d, r)
                })
                .collect();
            ctl.update(&batch);
        }
        assert!(ctl.policy.entropy() < h0);
    }

    #[test]
    fn adam_clips_gradient_norm() {
        let cards = vec![2];
        let mut adam = Adam::new(&cards, 0.1);
        let mut logits = vec![vec![0.0f32, 0.0]];
        let mut grad = vec![vec![1e6f32, -1e6]];
        adam.step(&mut logits, &mut grad, 1.0);
        // Post-clip norm 1.0, Adam first step ~ lr in magnitude.
        assert!(logits[0][0].abs() <= 0.11);
    }
}
