//! Persistent cross-run evaluation cache (the warm-start layer).
//!
//! The paper's joint search is tractable only because evaluations are
//! amortized: the same joint design points recur across latency
//! targets, objectives and repeated sweeps (§4), and every cache tier
//! in this repo — `ParallelSim`, the `nahas serve` result cache, the
//! cluster front, the cross-search [`crate::search::EvalBroker`] —
//! dedups them *within* one process. This module makes the savings
//! survive the process: a [`CacheStore`] is a versioned, append-only
//! cache file mapping a joint decision key to a memoized value, so a
//! later `nahas sweep` with the same `--cache-dir` warm-starts from
//! every evaluation an earlier run already paid for.
//!
//! Design rules, in order of importance:
//!
//! * **Never lie.** A cached value is only reusable if it is still a
//!   bit-identical replay of what the backend would compute. The file
//!   header carries a *fingerprint* (format version + simulator
//!   fingerprint + the evaluation context: space, task, seed); any
//!   mismatch rejects the whole file and the run degrades to a cold
//!   start. Floats are stored as exact IEEE-754 bit patterns, so a
//!   round-trip through disk cannot perturb a single ULP.
//! * **Never crash the run.** A corrupt, truncated or stale file is
//!   data loss, not an error: `open` reports *why* the contents were
//!   discarded and starts a fresh file. Append failures (disk full,
//!   permissions racing) disable the store for the rest of the run and
//!   keep evaluating.
//! * **Never persist a transport failure.** Callers only append
//!   results their own cache admitted as *cacheable*; the
//!   non-cacheable markers of the service/cluster tiers (see
//!   [`crate::search::Evaluator::evaluate_batch_tagged`]) therefore
//!   never reach disk by construction — pinned by
//!   `tests/cluster_failover.rs`.
//!
//! The store is value-generic via [`CacheValue`], so the same file
//! format serves both the broker's `EvalResult` entries and the
//! `nahas serve` server-side cache of serialized response lines.
//!
//! On-disk formats. New files are written as `nahas-cache v2`: a
//! one-line text header followed by binary segment blocks from
//! [`crate::util::codec`]:
//!
//! ```text
//! nahas-cache v2 eval/s2-efficientnet/classification/seed7/<sim fp>\n
//! [0xC5][flags][u32 payload_len][u32 entry_count][u64 fnv1a][payload]
//! ...
//! ```
//!
//! Each segment payload is a run of entries — `put_usize_slice` joint
//! key + [`CacheValue::encode_bin`] value. A warm open compacts the
//! whole inventory (duplicates deduped, last write wins) into
//! block-compressed *cold* segments of up to [`COLD_SEGMENT_ENTRIES`]
//! entries and renames it into place atomically; fresh appends then
//! land as uncompressed single-entry segments, flushed per entry, so a
//! crash tears at most the final block. Segments are read with
//! [`crate::util::codec::ReadPolicy::Strict`]: any defect discards the
//! whole file — a cold start is always correct, a salvaged half-cache
//! may not be.
//!
//! The previous text format (`nahas-cache v1`, one `key|value` record
//! per `\n`-terminated line with f64s as hex bit patterns) still
//! loads bit-identically; the first warm open migrates the file to v2.

use std::collections::HashMap;
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::nas::NasSpaceId;
use crate::search::evaluator::{EvalResult, Task};
use crate::util::codec::{self, ByteReader, ReadPolicy};

/// Legacy text format tag (one `key|value` record per line). Files
/// carrying it still load; new files are written as [`STORE_FORMAT_V2`].
pub const STORE_FORMAT: &str = "nahas-cache v1";

/// Current on-disk format tag: text header line + binary segment
/// blocks. Bump on any incompatible layout change so old files are
/// rejected instead of misparsed.
pub const STORE_FORMAT_V2: &str = "nahas-cache v2";

/// Entries per block-compressed cold segment written by a warm-open
/// compaction. Bounds both the compression window reset and the
/// per-segment allocation a reader makes.
pub const COLD_SEGMENT_ENTRIES: usize = 1024;

/// Fingerprint of the evaluation semantics baked into this binary.
/// Bump whenever the simulator, surrogate accuracy, or decision
/// decoding changes in a result-visible way: persisted entries from
/// the old semantics must be invalidated, not replayed.
pub const SIM_FINGERPRINT: &str = "sim-v1";

/// A value the store can persist bit-exactly, in both codecs: the
/// text pair (`encode`/`decode`) reads legacy v1 files, the binary
/// pair (`encode_bin`/`decode_bin`) is what v2 segments store.
pub trait CacheValue: Clone {
    /// Encode to a single `\n`-free line (legacy v1 record format).
    fn encode(&self) -> String;
    fn decode(s: &str) -> Option<Self>;
    /// Append the binary encoding to `out` (v2 segment payloads).
    fn encode_bin(&self, out: &mut Vec<u8>);
    /// Inverse of [`CacheValue::encode_bin`]; `None` on malformed or
    /// truncated bytes, never a panic.
    fn decode_bin(r: &mut ByteReader) -> Option<Self>;
}

impl CacheValue for EvalResult {
    /// Valid flag + the four metrics as IEEE-754 bit patterns in hex —
    /// exact round-trip by construction (including NaN payloads, which
    /// a decimal float format would not preserve).
    fn encode(&self) -> String {
        format!(
            "{} {:016x} {:016x} {:016x} {:016x}",
            self.valid as u8,
            self.acc.to_bits(),
            self.latency_ms.to_bits(),
            self.energy_mj.to_bits(),
            self.area_mm2.to_bits()
        )
    }

    fn decode(s: &str) -> Option<Self> {
        let mut it = s.split_ascii_whitespace();
        let valid = match it.next()? {
            "0" => false,
            "1" => true,
            _ => return None,
        };
        let mut bits = [0u64; 4];
        for b in &mut bits {
            *b = u64::from_str_radix(it.next()?, 16).ok()?;
        }
        if it.next().is_some() {
            return None;
        }
        Some(EvalResult {
            acc: f64::from_bits(bits[0]),
            latency_ms: f64::from_bits(bits[1]),
            energy_mj: f64::from_bits(bits[2]),
            area_mm2: f64::from_bits(bits[3]),
            valid,
        })
    }

    /// Valid flag byte + the four metrics as raw little-endian bit
    /// patterns — the binary twin of the hex text encoding.
    fn encode_bin(&self, out: &mut Vec<u8>) {
        out.push(self.valid as u8);
        codec::put_f64_bits(out, self.acc);
        codec::put_f64_bits(out, self.latency_ms);
        codec::put_f64_bits(out, self.energy_mj);
        codec::put_f64_bits(out, self.area_mm2);
    }

    fn decode_bin(r: &mut ByteReader) -> Option<Self> {
        let valid = match r.u8()? {
            0 => false,
            1 => true,
            _ => return None,
        };
        Some(EvalResult {
            acc: r.f64_bits()?,
            latency_ms: r.f64_bits()?,
            energy_mj: r.f64_bits()?,
            area_mm2: r.f64_bits()?,
            valid,
        })
    }
}

impl CacheValue for String {
    /// Serialized payloads (the `nahas serve` response cache). In the
    /// legacy v1 line format a newline-bearing value was
    /// unrepresentable and skipped at append time; the v2 binary
    /// encoding is length-prefixed, so any string round-trips.
    fn encode(&self) -> String {
        self.clone()
    }

    fn decode(s: &str) -> Option<Self> {
        Some(s.to_string())
    }

    fn encode_bin(&self, out: &mut Vec<u8>) {
        codec::put_str(out, self);
    }

    fn decode_bin(r: &mut ByteReader) -> Option<Self> {
        r.str()
    }
}

fn space_tag(space: NasSpaceId) -> &'static str {
    match space {
        NasSpaceId::MobileNetV2 => "s1-mobilenetv2",
        NasSpaceId::EfficientNet => "s2-efficientnet",
        NasSpaceId::Evolved => "s3-evolved",
        NasSpaceId::Proxy => "proxy",
    }
}

fn task_tag(task: Task) -> &'static str {
    match task {
        Task::Classification => "classification",
        Task::Segmentation => "segmentation",
    }
}

/// The evaluation-context fingerprint: a persisted `EvalResult` is a
/// deterministic function of (space, task, seed, decisions) plus the
/// simulator code itself, so all of those go into the header. The
/// *backend tier* deliberately does not: every tier is bit-identical
/// for a seed (`tests/parallel_equivalence.rs`), so a cache spilled by
/// a local run legitimately warm-starts a cluster run and vice versa.
pub fn eval_fingerprint(space: NasSpaceId, task: Task, seed: u64) -> String {
    format!("eval/{}/{}/seed{}/{}", space_tag(space), task_tag(task), seed, SIM_FINGERPRINT)
}

/// The ordered task-set tag of a scenario: `"classification"`,
/// `"multi-classification+segmentation"`, ... A multi-task cache keys
/// its entries with a task-index prefix
/// ([`crate::search::scenario::multitask::MultiTaskEval`]), so its
/// entries are meaningless to a single-task run (and vice versa): the
/// task *set* must be part of the fingerprint, not just one task.
fn task_set_tag(tasks: &[Task]) -> String {
    assert!(!tasks.is_empty(), "a task-set fingerprint needs at least one task");
    if tasks.len() == 1 {
        return task_tag(tasks[0]).to_string();
    }
    let parts: Vec<&str> = tasks.iter().map(|&t| task_tag(t)).collect();
    format!("multi-{}", parts.join("+"))
}

/// [`eval_fingerprint`] generalized to a scenario's ordered task set.
/// A single-task set reduces to exactly `eval_fingerprint` (old caches
/// stay valid); any multi-task set gets its own distinct context, so a
/// multi-task cache file can never warm-start a single-task run.
pub fn eval_fingerprint_tasks(space: NasSpaceId, tasks: &[Task], seed: u64) -> String {
    format!("eval/{}/{}/seed{}/{}", space_tag(space), task_set_tag(tasks), seed, SIM_FINGERPRINT)
}

/// Fingerprint of the `nahas serve` response cache. The serve key
/// already encodes space and task, and the server computes no
/// seed-dependent accuracy, so the components are the simulator
/// fingerprint plus a wire-protocol version — the cached values are
/// literal response lines, so bump `v1` whenever the simulate
/// response *schema* changes (new/renamed fields), even when the
/// simulator math does not.
pub fn serve_fingerprint() -> String {
    format!("serve/v1/{SIM_FINGERPRINT}")
}

/// The cache file a `--cache-dir` run uses: one file per evaluation
/// fingerprint, so runs with different contexts never invalidate each
/// other's entries.
pub fn eval_cache_file(dir: &Path, space: NasSpaceId, task: Task, seed: u64) -> PathBuf {
    dir.join(format!("evals-{}-{}-seed{}.cache", space_tag(space), task_tag(task), seed))
}

/// [`eval_cache_file`] generalized to a task set, mirroring
/// [`eval_fingerprint_tasks`]: single-task sets reduce to the classic
/// file name, multi-task sets get their own file.
pub fn eval_cache_file_tasks(dir: &Path, space: NasSpaceId, tasks: &[Task], seed: u64) -> PathBuf {
    dir.join(format!("evals-{}-{}-seed{}.cache", space_tag(space), task_set_tag(tasks), seed))
}

fn decode_key(s: &str) -> Option<Vec<usize>> {
    if s.is_empty() {
        return Some(Vec::new());
    }
    s.split(',').map(|t| t.parse().ok()).collect()
}

/// Encode `entries` as a *handoff stream*: exactly the v2 store body
/// (checksummed segments of `put_usize_slice` key +
/// [`CacheValue::encode_bin`] value runs, block-compressed, up to
/// [`COLD_SEGMENT_ENTRIES`] entries each) with no header line. The
/// store file *is* the wire format — a cluster membership join streams
/// a joining host's warm key range as one of these over the binary
/// service wire, and the receiver decodes it with [`decode_handoff`].
pub fn encode_handoff<V: CacheValue>(entries: &[(Vec<usize>, V)]) -> Vec<u8> {
    let mut bytes = Vec::new();
    for chunk in entries.chunks(COLD_SEGMENT_ENTRIES) {
        let mut payload = Vec::new();
        for (key, value) in chunk {
            codec::put_usize_slice(&mut payload, key);
            value.encode_bin(&mut payload);
        }
        codec::write_segment(&mut bytes, &payload, chunk.len(), true);
    }
    bytes
}

/// Decode a handoff stream (or a v2 store body — same bytes). Strict
/// all-or-nothing: any defect — truncated segment, flipped bit caught
/// by the FNV checksum, malformed entry, trailing bytes — returns
/// `Err` and the caller installs *nothing*, so a mangled transfer
/// leaves the receiving host cold but consistent, never half-warm.
pub fn decode_handoff<V: CacheValue>(bytes: &[u8]) -> Result<Vec<(Vec<usize>, V)>, String> {
    let segs = codec::read_segments(bytes, ReadPolicy::Strict)?;
    let mut out = Vec::new();
    for seg in &segs {
        let mut r = ByteReader::new(&seg.payload);
        for i in 0..seg.entries {
            let entry = r.usize_slice().zip(V::decode_bin(&mut r));
            match entry {
                Some(e) => out.push(e),
                None => {
                    return Err(format!(
                        "corrupt entry {i} in segment at offset {}",
                        seg.pos.offset
                    ));
                }
            }
        }
        if !r.is_empty() {
            return Err(format!("trailing bytes in segment at offset {}", seg.pos.offset));
        }
    }
    Ok(out)
}

/// Disk-backed, append-only cache of `joint key -> V`, with a
/// fingerprint header guarding staleness. See the module docs for the
/// format and the safety rules.
///
/// # Examples
///
/// ```
/// use nahas::search::{CacheStore, EvalResult};
///
/// let path =
///     std::env::temp_dir().join(format!("nahas-store-doc-{}.cache", std::process::id()));
/// # let _ = std::fs::remove_file(&path);
/// {
///     let mut store: CacheStore = CacheStore::open(&path, "eval/doc-example").unwrap();
///     store.append(&[3, 1, 4], &EvalResult { acc: 0.76, valid: true, ..Default::default() });
/// } // Dropping flushes.
///
/// // A later run with the same fingerprint warm-starts from the file.
/// let mut store: CacheStore = CacheStore::open(&path, "eval/doc-example").unwrap();
/// assert!(store.discarded().is_none());
/// let loaded = store.take_loaded();
/// assert_eq!(loaded.len(), 1);
/// assert_eq!(loaded[0].0, vec![3, 1, 4]);
/// assert_eq!(loaded[0].1.acc.to_bits(), 0.76f64.to_bits()); // exact round-trip
/// # let _ = std::fs::remove_file(&path);
/// ```
pub struct CacheStore<V: CacheValue = EvalResult> {
    path: PathBuf,
    writer: BufWriter<File>,
    /// Entries successfully read at open (empty after
    /// [`CacheStore::take_loaded`]). Later lines win over earlier ones
    /// on a duplicate key when loaded in order, matching append-only
    /// semantics.
    loaded: Vec<(Vec<usize>, V)>,
    /// Why pre-existing contents were discarded at open, if they were.
    discarded: Option<String>,
    appended: usize,
    /// A write failed; stop appending (the run continues uncached).
    write_failed: bool,
}

impl<V: CacheValue> CacheStore<V> {
    /// Open (or create) the cache file at `path` for the given
    /// fingerprint. Existing contents load only if the header matches
    /// `STORE_FORMAT` + `fingerprint` and every entry line parses;
    /// otherwise the file is restarted empty and
    /// [`CacheStore::discarded`] reports why. Only I/O that prevents
    /// the store from operating at all (unwritable directory/file) is
    /// an error.
    pub fn open(path: impl Into<PathBuf>, fingerprint: &str) -> Result<CacheStore<V>> {
        let path = path.into();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent)
                    .with_context(|| format!("creating cache dir {}", parent.display()))?;
            }
        }
        let mut loaded = Vec::new();
        let mut discarded = None;
        let mut preserve = false;
        match fs::read(&path) {
            // No previous file: a genuinely fresh start.
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            // Any other read failure (permissions racing, flaky
            // network filesystem) may be transient and the file may be
            // perfectly healthy: leave it untouched and run with
            // persistence disabled rather than destroy a warm
            // inventory we merely failed to read.
            Err(e) => {
                discarded = Some(format!("unreadable ({e}); file kept, persistence off"));
                preserve = true;
            }
            Ok(bytes) => match Self::parse_bytes(&bytes, fingerprint) {
                Ok(entries) => loaded = entries,
                Err(why) => discarded = Some(why),
            },
        }
        // Every open rewrites the file as v2 atomically (temp file
        // renamed into place, so a concurrent writer still holding the
        // old file keeps appending to the orphaned inode instead of
        // splicing bytes into ours). A warm open compacts the loaded
        // inventory — duplicates deduped last-wins — into compressed
        // cold segments (this is also what migrates a v1 file);
        // anything else (fresh, stale, corrupt) restarts with just the
        // header.
        if !preserve {
            Self::write_compacted(&path, fingerprint, &loaded)?;
        }
        // Both paths end on an O_APPEND handle: every flushed segment
        // lands at the file's current end, whatever other handles did.
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening cache file {}", path.display()))?;
        let writer = BufWriter::new(file);
        Ok(CacheStore { path, writer, loaded, discarded, appended: 0, write_failed: preserve })
    }

    /// Parse a whole previous file against the expected fingerprint,
    /// dispatching on the header line: `nahas-cache v2` bodies are
    /// binary segment streams, `nahas-cache v1` bodies the legacy text
    /// records. Any defect — wrong header, stale fingerprint,
    /// malformed or truncated entry — rejects everything: a cold start
    /// is always correct, a salvaged half-file may not be.
    fn parse_bytes(bytes: &[u8], fingerprint: &str) -> Result<Vec<(Vec<usize>, V)>, String> {
        if bytes.is_empty() {
            return Err("empty file".to_string());
        }
        let nl = match bytes.iter().position(|&b| b == b'\n') {
            Some(i) => i,
            None => return Err("truncated header line".to_string()),
        };
        let head = match std::str::from_utf8(&bytes[..nl]) {
            Ok(h) => h,
            Err(_) => return Err("unreadable: non-UTF-8 header line".to_string()),
        };
        let body = &bytes[nl + 1..];
        if head == format!("{STORE_FORMAT_V2} {fingerprint}") {
            return Self::parse_v2(body);
        }
        if head == format!("{STORE_FORMAT} {fingerprint}") {
            let text = match std::str::from_utf8(bytes) {
                Ok(t) => t,
                Err(_) => return Err("unreadable: non-UTF-8 bytes in a v1 file".to_string()),
            };
            return Self::parse_v1(text);
        }
        Err(format!("fingerprint mismatch (found '{head}')"))
    }

    /// Decode a v2 segment stream (strictly: one bad segment rejects
    /// the file) into entries, in write order. Shared with the cluster
    /// warm-handoff path — the body and a handoff stream are the same
    /// bytes.
    fn parse_v2(body: &[u8]) -> Result<Vec<(Vec<usize>, V)>, String> {
        decode_handoff(body)
    }

    /// Decode a legacy v1 text body (header already verified).
    fn parse_v1(text: &str) -> Result<Vec<(Vec<usize>, V)>, String> {
        // A well-formed file ends in '\n'; a partial trailing line
        // (killed mid-append) shows up here as a parse failure.
        if !text.ends_with('\n') {
            return Err("truncated final line".to_string());
        }
        let mut out = Vec::new();
        for (i, line) in text.lines().skip(1).enumerate() {
            if line.is_empty() {
                continue;
            }
            let parsed =
                line.split_once('|').and_then(|(k, v)| decode_key(k).zip(V::decode(v)));
            match parsed {
                Some(entry) => out.push(entry),
                None => return Err(format!("corrupt entry at line {}", i + 2)),
            }
        }
        Ok(out)
    }

    /// Atomically (re)write the file as v2: header line + the entries
    /// deduped last-wins and packed into block-compressed cold
    /// segments of up to [`COLD_SEGMENT_ENTRIES`] entries each.
    fn write_compacted(path: &Path, fingerprint: &str, entries: &[(Vec<usize>, V)]) -> Result<()> {
        let mut compacted: Vec<(Vec<usize>, V)> = Vec::new();
        let mut index: HashMap<Vec<usize>, usize> = HashMap::new();
        for (key, value) in entries {
            match index.get(key) {
                // Later entries are newer: overwrite in place, keeping
                // first-occurrence order so the compacted file is a
                // deterministic function of the input.
                Some(&at) => compacted[at].1 = value.clone(),
                None => {
                    index.insert(key.clone(), compacted.len());
                    compacted.push((key.clone(), value.clone()));
                }
            }
        }
        let mut bytes = Vec::new();
        bytes.extend_from_slice(format!("{STORE_FORMAT_V2} {fingerprint}\n").as_bytes());
        for chunk in compacted.chunks(COLD_SEGMENT_ENTRIES) {
            let mut payload = Vec::new();
            for (key, value) in chunk {
                codec::put_usize_slice(&mut payload, key);
                value.encode_bin(&mut payload);
            }
            codec::write_segment(&mut bytes, &payload, chunk.len(), true);
        }
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("evals.cache");
        let tmp = path.with_file_name(format!("{name}.tmp{}", std::process::id()));
        fs::write(&tmp, &bytes)
            .with_context(|| format!("writing compacted cache file {}", tmp.display()))?;
        fs::rename(&tmp, path)
            .with_context(|| format!("installing cache file {}", path.display()))?;
        Ok(())
    }

    /// Entries read at open, in file order (later entries are newer).
    /// Leaves the store empty; call once when filling the in-memory
    /// cache tier.
    pub fn take_loaded(&mut self) -> Vec<(Vec<usize>, V)> {
        std::mem::take(&mut self.loaded)
    }

    /// How many entries the open loaded (0 after `take_loaded`).
    pub fn loaded_len(&self) -> usize {
        self.loaded.len()
    }

    /// Why pre-existing contents were discarded at open, if they were.
    pub fn discarded(&self) -> Option<&str> {
        self.discarded.as_deref()
    }

    /// Entries appended since open.
    pub fn appended(&self) -> usize {
        self.appended
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one entry. Failures are swallowed after a warning:
    /// persistence is an accelerator, never a reason to fail an
    /// evaluation.
    ///
    /// Each entry is written as one uncompressed single-entry segment
    /// and flushed immediately, so it reaches the OS as one small
    /// `O_APPEND` write: a crash can tear at most the final block, and
    /// a second writer on the same file (operator error, but
    /// survivable) interleaves whole segments rather than fragments.
    /// The cost — one syscall per *fresh* evaluation — is noise next
    /// to the evaluation itself.
    pub fn append(&mut self, key: &[usize], value: &V) {
        if self.write_failed {
            return;
        }
        let mut payload = Vec::new();
        codec::put_usize_slice(&mut payload, key);
        value.encode_bin(&mut payload);
        let mut block = Vec::new();
        codec::write_segment(&mut block, &payload, 1, false);
        if self.writer.write_all(&block).is_err() {
            eprintln!(
                "cache store {}: append failed; persistence disabled for this run",
                self.path.display()
            );
            self.write_failed = true;
            return;
        }
        self.appended += 1;
        self.flush();
    }

    /// Push buffered appends to the OS. Called on drop; call earlier
    /// if another reader needs to see the entries mid-run.
    pub fn flush(&mut self) {
        if self.writer.flush().is_err() && !self.write_failed {
            eprintln!(
                "cache store {}: flush failed; persistence disabled for this run",
                self.path.display()
            );
            self.write_failed = true;
        }
    }
}

impl<V: CacheValue> Drop for CacheStore<V> {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("nahas-store-unit-{}-{name}", std::process::id()))
    }

    fn result(acc: f64, lat: f64, valid: bool) -> EvalResult {
        EvalResult { acc, latency_ms: lat, energy_mj: 0.25, area_mm2: 80.0, valid }
    }

    #[test]
    fn roundtrips_entries_bit_exactly() {
        let path = tmp("roundtrip.cache");
        let _ = fs::remove_file(&path);
        let fp = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, 7);
        {
            let mut store: CacheStore = CacheStore::open(&path, &fp).unwrap();
            assert!(store.discarded().is_none());
            assert_eq!(store.loaded_len(), 0);
            store.append(&[1, 2, 3], &result(0.761234567890123, 0.35, true));
            store.append(&[], &result(f64::NAN, -0.0, false));
            store.append(&[9], &result(f64::INFINITY, 1e-300, true));
        }
        let mut store: CacheStore = CacheStore::open(&path, &fp).unwrap();
        assert!(store.discarded().is_none());
        let loaded = store.take_loaded();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded[0].0, vec![1, 2, 3]);
        assert_eq!(loaded[0].1.acc.to_bits(), 0.761234567890123f64.to_bits());
        assert_eq!(loaded[1].0, Vec::<usize>::new());
        assert!(loaded[1].1.acc.is_nan());
        assert_eq!(loaded[1].1.latency_ms.to_bits(), (-0.0f64).to_bits());
        assert!(!loaded[1].1.valid);
        assert_eq!(loaded[2].1.acc, f64::INFINITY);
        assert_eq!(loaded[2].1.latency_ms.to_bits(), 1e-300f64.to_bits());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn stale_fingerprint_discards_and_restarts() {
        let path = tmp("stale.cache");
        let _ = fs::remove_file(&path);
        {
            let mut store: CacheStore = CacheStore::open(&path, "eval/old-fp").unwrap();
            store.append(&[4, 2], &result(0.7, 0.4, true));
        }
        let mut store: CacheStore = CacheStore::open(&path, "eval/new-fp").unwrap();
        assert!(store.discarded().unwrap().contains("fingerprint mismatch"));
        assert_eq!(store.loaded_len(), 0);
        store.append(&[1], &result(0.5, 0.1, true));
        drop(store);
        // The restarted file carries the new fingerprint only.
        let mut again: CacheStore = CacheStore::open(&path, "eval/new-fp").unwrap();
        assert!(again.discarded().is_none());
        assert_eq!(again.take_loaded().len(), 1);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn corrupt_and_truncated_files_fall_back_cold() {
        let path = tmp("corrupt.cache");
        for damage in ["garbage in the middle", "1,2|1 aa"] {
            let _ = fs::remove_file(&path);
            let fp = "eval/fp";
            {
                let mut store: CacheStore = CacheStore::open(&path, fp).unwrap();
                store.append(&[1, 2], &result(0.7, 0.4, true));
            }
            let mut text = fs::read_to_string(&path).unwrap();
            text.push_str(damage); // No trailing newline: also truncated.
            fs::write(&path, &text).unwrap();
            let store: CacheStore = CacheStore::open(&path, fp).unwrap();
            assert!(store.discarded().is_some(), "damage '{damage}' not detected");
            assert_eq!(store.loaded_len(), 0);
        }
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn unreadable_bytes_discard_with_a_reason_not_silently() {
        let path = tmp("non-utf8.cache");
        let _ = fs::remove_file(&path);
        let fp = "eval/fp";
        {
            let mut store: CacheStore = CacheStore::open(&path, fp).unwrap();
            store.append(&[3], &result(0.6, 0.2, true));
        }
        // Garbage bytes after the last segment: the strict segment
        // reader must reject the whole file, not salvage a prefix.
        let mut bytes = fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xFF, 0xFE, 0xFD]);
        fs::write(&path, &bytes).unwrap();
        let store: CacheStore = CacheStore::open(&path, fp).unwrap();
        assert!(store.discarded().unwrap().contains("bad segment magic"));
        assert_eq!(store.loaded_len(), 0);
        // A file whose header line itself is not UTF-8 is unreadable.
        fs::write(&path, [0xFF, 0xFE, 0xFD, b'\n', 0x00]).unwrap();
        let store: CacheStore = CacheStore::open(&path, fp).unwrap();
        assert!(store.discarded().unwrap().contains("unreadable"));
        assert_eq!(store.loaded_len(), 0);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn string_values_roundtrip_for_the_serve_cache() {
        let path = tmp("serve.cache");
        let _ = fs::remove_file(&path);
        let fp = serve_fingerprint();
        let resp = r#"{"valid": true, "latency_ms": 0.41}"#.to_string();
        {
            let mut store: CacheStore<String> = CacheStore::open(&path, &fp).unwrap();
            store.append(&[1, 0, 7, 3], &resp);
            // Length-prefixed binary values: even a newline-bearing
            // string (unrepresentable in the v1 line format) persists.
            store.append(&[5], &"two\nlines".to_string());
            assert_eq!(store.appended(), 2);
        }
        let mut store: CacheStore<String> = CacheStore::open(&path, &fp).unwrap();
        let loaded = store.take_loaded();
        assert_eq!(loaded, vec![(vec![1, 0, 7, 3], resp), (vec![5], "two\nlines".to_string())]);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn v1_text_files_load_and_migrate_to_v2() {
        let path = tmp("v1-migrate.cache");
        let _ = fs::remove_file(&path);
        let fp = "eval/v1-fp";
        // A legacy v1 file, written byte-for-byte as PR 4 did.
        let r1 = result(0.75, 0.4, true);
        let r2 = result(f64::NAN, f64::INFINITY, false);
        let v1 = format!("{STORE_FORMAT} {fp}\n1,2,3|{}\n4|{}\n", r1.encode(), r2.encode());
        fs::write(&path, v1).unwrap();
        let mut store: CacheStore = CacheStore::open(&path, fp).unwrap();
        assert!(store.discarded().is_none(), "{:?}", store.discarded());
        let loaded = store.take_loaded();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].0, vec![1, 2, 3]);
        assert_eq!(loaded[0].1.acc.to_bits(), r1.acc.to_bits());
        assert!(loaded[1].1.acc.is_nan());
        assert_eq!(loaded[1].1.latency_ms.to_bits(), f64::INFINITY.to_bits());
        drop(store);
        // The warm open migrated the file: v2 header, same entries.
        let bytes = fs::read(&path).unwrap();
        assert!(bytes.starts_with(format!("{STORE_FORMAT_V2} {fp}\n").as_bytes()));
        let mut again: CacheStore = CacheStore::open(&path, fp).unwrap();
        assert!(again.discarded().is_none());
        let reloaded = again.take_loaded();
        assert_eq!(reloaded.len(), 2);
        assert_eq!(reloaded[0].1.acc.to_bits(), r1.acc.to_bits());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn warm_compaction_dedups_last_wins() {
        let path = tmp("dedup.cache");
        let _ = fs::remove_file(&path);
        let fp = "eval/dedup-fp";
        {
            let mut store: CacheStore = CacheStore::open(&path, fp).unwrap();
            store.append(&[1, 1], &result(0.1, 0.1, true));
            store.append(&[2, 2], &result(0.2, 0.2, true));
            store.append(&[1, 1], &result(0.9, 0.9, true)); // newer
        }
        // First warm open still sees the raw append order...
        let mut store: CacheStore = CacheStore::open(&path, fp).unwrap();
        assert_eq!(store.loaded_len(), 3);
        store.take_loaded();
        drop(store);
        // ...and compacts on the way: the next open loads the deduped
        // inventory with the newest value for the duplicated key.
        let mut store: CacheStore = CacheStore::open(&path, fp).unwrap();
        let loaded = store.take_loaded();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].0, vec![1, 1]);
        assert_eq!(loaded[0].1.acc.to_bits(), 0.9f64.to_bits());
        assert_eq!(loaded[1].0, vec![2, 2]);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn cold_segments_compress_a_large_inventory() {
        let path = tmp("compress.cache");
        let _ = fs::remove_file(&path);
        let fp = "eval/compress-fp";
        {
            let mut store: CacheStore = CacheStore::open(&path, fp).unwrap();
            for i in 0..COLD_SEGMENT_ENTRIES + 100 {
                store.append(&[i, i % 7, 3], &result(0.5, 0.25, true));
            }
        }
        let appended_size = fs::metadata(&path).unwrap().len();
        // Warm open compacts >1 segment's worth into compressed blocks.
        let mut store: CacheStore = CacheStore::open(&path, fp).unwrap();
        assert_eq!(store.loaded_len(), COLD_SEGMENT_ENTRIES + 100);
        store.take_loaded();
        drop(store);
        let compacted_size = fs::metadata(&path).unwrap().len();
        assert!(
            compacted_size < appended_size / 2,
            "compaction did not shrink the file: {compacted_size} !< {appended_size}/2"
        );
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn fingerprints_separate_contexts() {
        let a = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, 7);
        let b = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, 8);
        let c = eval_fingerprint(NasSpaceId::EfficientNet, Task::Segmentation, 7);
        let d = eval_fingerprint(NasSpaceId::MobileNetV2, Task::Classification, 7);
        let all = [a, b, c, d, serve_fingerprint()];
        for (i, x) in all.iter().enumerate() {
            for y in &all[i + 1..] {
                assert_ne!(x, y);
            }
        }
    }

    #[test]
    fn task_set_fingerprints_separate_multi_from_single() {
        // A single-task set through the task-set API is exactly the
        // classic fingerprint/file — old caches stay valid.
        assert_eq!(
            eval_fingerprint_tasks(NasSpaceId::EfficientNet, &[Task::Classification], 7),
            eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, 7),
        );
        let dir = Path::new("cache");
        assert_eq!(
            eval_cache_file_tasks(dir, NasSpaceId::EfficientNet, &[Task::Classification], 7),
            eval_cache_file(dir, NasSpaceId::EfficientNet, Task::Classification, 7),
        );
        // A multi-task set is distinct from every single-task context
        // (its entries carry task-index-prefixed keys), and sensitive
        // to task order — order defines the prefix indices.
        let multi = eval_fingerprint_tasks(
            NasSpaceId::EfficientNet,
            &[Task::Classification, Task::Segmentation],
            7,
        );
        let multi_rev = eval_fingerprint_tasks(
            NasSpaceId::EfficientNet,
            &[Task::Segmentation, Task::Classification],
            7,
        );
        let singles = [
            eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, 7),
            eval_fingerprint(NasSpaceId::EfficientNet, Task::Segmentation, 7),
        ];
        for s in &singles {
            assert_ne!(&multi, s);
            assert_ne!(&multi_rev, s);
        }
        assert_ne!(multi, multi_rev);
        assert!(multi.contains("multi-classification+segmentation"), "{multi}");
        let f = eval_cache_file_tasks(
            dir,
            NasSpaceId::EfficientNet,
            &[Task::Classification, Task::Segmentation],
            7,
        );
        assert_ne!(f, eval_cache_file(dir, NasSpaceId::EfficientNet, Task::Classification, 7));
    }
}
