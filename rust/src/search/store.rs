//! Persistent cross-run evaluation cache (the warm-start layer).
//!
//! The paper's joint search is tractable only because evaluations are
//! amortized: the same joint design points recur across latency
//! targets, objectives and repeated sweeps (§4), and every cache tier
//! in this repo — `ParallelSim`, the `nahas serve` result cache, the
//! cluster front, the cross-search [`crate::search::EvalBroker`] —
//! dedups them *within* one process. This module makes the savings
//! survive the process: a [`CacheStore`] is a versioned, append-only
//! cache file mapping a joint decision key to a memoized value, so a
//! later `nahas sweep` with the same `--cache-dir` warm-starts from
//! every evaluation an earlier run already paid for.
//!
//! Design rules, in order of importance:
//!
//! * **Never lie.** A cached value is only reusable if it is still a
//!   bit-identical replay of what the backend would compute. The file
//!   header carries a *fingerprint* (format version + simulator
//!   fingerprint + the evaluation context: space, task, seed); any
//!   mismatch rejects the whole file and the run degrades to a cold
//!   start. Floats are stored as exact IEEE-754 bit patterns, so a
//!   round-trip through disk cannot perturb a single ULP.
//! * **Never crash the run.** A corrupt, truncated or stale file is
//!   data loss, not an error: `open` reports *why* the contents were
//!   discarded and starts a fresh file. Append failures (disk full,
//!   permissions racing) disable the store for the rest of the run and
//!   keep evaluating.
//! * **Never persist a transport failure.** Callers only append
//!   results their own cache admitted as *cacheable*; the
//!   non-cacheable markers of the service/cluster tiers (see
//!   [`crate::search::Evaluator::evaluate_batch_tagged`]) therefore
//!   never reach disk by construction — pinned by
//!   `tests/cluster_failover.rs`.
//!
//! The store is value-generic via [`CacheValue`], so the same file
//! format serves both the broker's `EvalResult` entries and the
//! `nahas serve` server-side cache of serialized response lines.
//!
//! File format (one record per line, `\n`-terminated):
//!
//! ```text
//! nahas-cache v1 eval/s2-efficientnet/classification/seed7/<sim fp>
//! 3,0,1,4|1 3fe6b851eb851eb8 3fd0624dd2f1a9fc 3fe0000000000000 4053c00000000000
//! ...
//! ```
//!
//! Left of `|`: the comma-separated joint key. Right: the encoded
//! value (for [`EvalResult`]: valid flag + the four metric f64s as hex
//! bit patterns). Append-only means two runs can extend the same file
//! sequentially; concurrent writers should use separate files (the
//! CLI derives one file per evaluation fingerprint).

use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::nas::NasSpaceId;
use crate::search::evaluator::{EvalResult, Task};

/// On-disk format tag; bump on any incompatible layout change so old
/// files are rejected instead of misparsed.
pub const STORE_FORMAT: &str = "nahas-cache v1";

/// Fingerprint of the evaluation semantics baked into this binary.
/// Bump whenever the simulator, surrogate accuracy, or decision
/// decoding changes in a result-visible way: persisted entries from
/// the old semantics must be invalidated, not replayed.
pub const SIM_FINGERPRINT: &str = "sim-v1";

/// A value the store can persist: encoded to a single `\n`-free line
/// and decoded back bit-exactly.
pub trait CacheValue: Clone {
    fn encode(&self) -> String;
    fn decode(s: &str) -> Option<Self>;
}

impl CacheValue for EvalResult {
    /// Valid flag + the four metrics as IEEE-754 bit patterns in hex —
    /// exact round-trip by construction (including NaN payloads, which
    /// a decimal float format would not preserve).
    fn encode(&self) -> String {
        format!(
            "{} {:016x} {:016x} {:016x} {:016x}",
            self.valid as u8,
            self.acc.to_bits(),
            self.latency_ms.to_bits(),
            self.energy_mj.to_bits(),
            self.area_mm2.to_bits()
        )
    }

    fn decode(s: &str) -> Option<Self> {
        let mut it = s.split_ascii_whitespace();
        let valid = match it.next()? {
            "0" => false,
            "1" => true,
            _ => return None,
        };
        let mut bits = [0u64; 4];
        for b in &mut bits {
            *b = u64::from_str_radix(it.next()?, 16).ok()?;
        }
        if it.next().is_some() {
            return None;
        }
        Some(EvalResult {
            acc: f64::from_bits(bits[0]),
            latency_ms: f64::from_bits(bits[1]),
            energy_mj: f64::from_bits(bits[2]),
            area_mm2: f64::from_bits(bits[3]),
            valid,
        })
    }
}

impl CacheValue for String {
    /// Serialized single-line payloads (the `nahas serve` response
    /// cache). Values containing a newline are unrepresentable and are
    /// skipped at append time.
    fn encode(&self) -> String {
        self.clone()
    }

    fn decode(s: &str) -> Option<Self> {
        Some(s.to_string())
    }
}

fn space_tag(space: NasSpaceId) -> &'static str {
    match space {
        NasSpaceId::MobileNetV2 => "s1-mobilenetv2",
        NasSpaceId::EfficientNet => "s2-efficientnet",
        NasSpaceId::Evolved => "s3-evolved",
        NasSpaceId::Proxy => "proxy",
    }
}

fn task_tag(task: Task) -> &'static str {
    match task {
        Task::Classification => "classification",
        Task::Segmentation => "segmentation",
    }
}

/// The evaluation-context fingerprint: a persisted `EvalResult` is a
/// deterministic function of (space, task, seed, decisions) plus the
/// simulator code itself, so all of those go into the header. The
/// *backend tier* deliberately does not: every tier is bit-identical
/// for a seed (`tests/parallel_equivalence.rs`), so a cache spilled by
/// a local run legitimately warm-starts a cluster run and vice versa.
pub fn eval_fingerprint(space: NasSpaceId, task: Task, seed: u64) -> String {
    format!("eval/{}/{}/seed{}/{}", space_tag(space), task_tag(task), seed, SIM_FINGERPRINT)
}

/// The ordered task-set tag of a scenario: `"classification"`,
/// `"multi-classification+segmentation"`, ... A multi-task cache keys
/// its entries with a task-index prefix
/// ([`crate::search::scenario::multitask::MultiTaskEval`]), so its
/// entries are meaningless to a single-task run (and vice versa): the
/// task *set* must be part of the fingerprint, not just one task.
fn task_set_tag(tasks: &[Task]) -> String {
    assert!(!tasks.is_empty(), "a task-set fingerprint needs at least one task");
    if tasks.len() == 1 {
        return task_tag(tasks[0]).to_string();
    }
    let parts: Vec<&str> = tasks.iter().map(|&t| task_tag(t)).collect();
    format!("multi-{}", parts.join("+"))
}

/// [`eval_fingerprint`] generalized to a scenario's ordered task set.
/// A single-task set reduces to exactly `eval_fingerprint` (old caches
/// stay valid); any multi-task set gets its own distinct context, so a
/// multi-task cache file can never warm-start a single-task run.
pub fn eval_fingerprint_tasks(space: NasSpaceId, tasks: &[Task], seed: u64) -> String {
    format!("eval/{}/{}/seed{}/{}", space_tag(space), task_set_tag(tasks), seed, SIM_FINGERPRINT)
}

/// Fingerprint of the `nahas serve` response cache. The serve key
/// already encodes space and task, and the server computes no
/// seed-dependent accuracy, so the components are the simulator
/// fingerprint plus a wire-protocol version — the cached values are
/// literal response lines, so bump `v1` whenever the simulate
/// response *schema* changes (new/renamed fields), even when the
/// simulator math does not.
pub fn serve_fingerprint() -> String {
    format!("serve/v1/{SIM_FINGERPRINT}")
}

/// The cache file a `--cache-dir` run uses: one file per evaluation
/// fingerprint, so runs with different contexts never invalidate each
/// other's entries.
pub fn eval_cache_file(dir: &Path, space: NasSpaceId, task: Task, seed: u64) -> PathBuf {
    dir.join(format!("evals-{}-{}-seed{}.cache", space_tag(space), task_tag(task), seed))
}

/// [`eval_cache_file`] generalized to a task set, mirroring
/// [`eval_fingerprint_tasks`]: single-task sets reduce to the classic
/// file name, multi-task sets get their own file.
pub fn eval_cache_file_tasks(dir: &Path, space: NasSpaceId, tasks: &[Task], seed: u64) -> PathBuf {
    dir.join(format!("evals-{}-{}-seed{}.cache", space_tag(space), task_set_tag(tasks), seed))
}

fn encode_key(key: &[usize]) -> String {
    let parts: Vec<String> = key.iter().map(|k| k.to_string()).collect();
    parts.join(",")
}

fn decode_key(s: &str) -> Option<Vec<usize>> {
    if s.is_empty() {
        return Some(Vec::new());
    }
    s.split(',').map(|t| t.parse().ok()).collect()
}

/// Disk-backed, append-only cache of `joint key -> V`, with a
/// fingerprint header guarding staleness. See the module docs for the
/// format and the safety rules.
///
/// # Examples
///
/// ```
/// use nahas::search::{CacheStore, EvalResult};
///
/// let path =
///     std::env::temp_dir().join(format!("nahas-store-doc-{}.cache", std::process::id()));
/// # let _ = std::fs::remove_file(&path);
/// {
///     let mut store: CacheStore = CacheStore::open(&path, "eval/doc-example").unwrap();
///     store.append(&[3, 1, 4], &EvalResult { acc: 0.76, valid: true, ..Default::default() });
/// } // Dropping flushes.
///
/// // A later run with the same fingerprint warm-starts from the file.
/// let mut store: CacheStore = CacheStore::open(&path, "eval/doc-example").unwrap();
/// assert!(store.discarded().is_none());
/// let loaded = store.take_loaded();
/// assert_eq!(loaded.len(), 1);
/// assert_eq!(loaded[0].0, vec![3, 1, 4]);
/// assert_eq!(loaded[0].1.acc.to_bits(), 0.76f64.to_bits()); // exact round-trip
/// # let _ = std::fs::remove_file(&path);
/// ```
pub struct CacheStore<V: CacheValue = EvalResult> {
    path: PathBuf,
    writer: BufWriter<File>,
    /// Entries successfully read at open (empty after
    /// [`CacheStore::take_loaded`]). Later lines win over earlier ones
    /// on a duplicate key when loaded in order, matching append-only
    /// semantics.
    loaded: Vec<(Vec<usize>, V)>,
    /// Why pre-existing contents were discarded at open, if they were.
    discarded: Option<String>,
    appended: usize,
    /// A write failed; stop appending (the run continues uncached).
    write_failed: bool,
}

impl<V: CacheValue> CacheStore<V> {
    /// Open (or create) the cache file at `path` for the given
    /// fingerprint. Existing contents load only if the header matches
    /// `STORE_FORMAT` + `fingerprint` and every entry line parses;
    /// otherwise the file is restarted empty and
    /// [`CacheStore::discarded`] reports why. Only I/O that prevents
    /// the store from operating at all (unwritable directory/file) is
    /// an error.
    pub fn open(path: impl Into<PathBuf>, fingerprint: &str) -> Result<CacheStore<V>> {
        let path = path.into();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent)
                    .with_context(|| format!("creating cache dir {}", parent.display()))?;
            }
        }
        let header = format!("{STORE_FORMAT} {fingerprint}");
        let mut loaded = Vec::new();
        let mut discarded = None;
        let mut preserve = false;
        match fs::read_to_string(&path) {
            // No previous file: a genuinely fresh start.
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            // Non-UTF-8 bytes: the file is corrupt; restart it.
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                discarded = Some(format!("unreadable: {e}"));
            }
            // Any other read failure (permissions racing, flaky
            // network filesystem) may be transient and the file may be
            // perfectly healthy: leave it untouched and run with
            // persistence disabled rather than destroy a warm
            // inventory we merely failed to read.
            Err(e) => {
                discarded = Some(format!("unreadable ({e}); file kept, persistence off"));
                preserve = true;
            }
            Ok(text) => match Self::parse(&text, &header) {
                Ok(entries) => loaded = entries,
                Err(why) => discarded = Some(why),
            },
        }
        // A clean load appends to the existing file; anything else
        // (fresh, stale, corrupt) restarts it with just the header —
        // atomically, via a temp file renamed into place, so a
        // concurrent writer still holding the old file keeps appending
        // to the orphaned inode instead of splicing bytes into ours.
        let warm = discarded.is_none() && !loaded.is_empty();
        if !warm && !preserve {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("evals.cache");
            let tmp = path.with_file_name(format!("{name}.tmp{}", std::process::id()));
            let mut fresh = File::create(&tmp)
                .with_context(|| format!("creating cache file {}", tmp.display()))?;
            writeln!(fresh, "{header}")
                .with_context(|| format!("writing cache header to {}", tmp.display()))?;
            fs::rename(&tmp, &path)
                .with_context(|| format!("installing cache file {}", path.display()))?;
        }
        // Both paths end on an O_APPEND handle: every flushed line
        // lands at the file's current end, whatever other handles did.
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening cache file {}", path.display()))?;
        let writer = BufWriter::new(file);
        Ok(CacheStore { path, writer, loaded, discarded, appended: 0, write_failed: preserve })
    }

    /// Parse a whole previous file against the expected header. Any
    /// defect — wrong header, stale fingerprint, malformed or
    /// truncated entry — rejects everything: a cold start is always
    /// correct, a salvaged half-file may not be.
    fn parse(text: &str, header: &str) -> Result<Vec<(Vec<usize>, V)>, String> {
        let mut lines = text.lines();
        match lines.next() {
            None => return Err("empty file".to_string()),
            Some(h) if h != header => {
                return Err(format!("fingerprint mismatch (found '{h}')"));
            }
            Some(_) => {}
        }
        // A well-formed file ends in '\n'; a partial trailing line
        // (killed mid-append) shows up here as a parse failure.
        if !text.ends_with('\n') {
            return Err("truncated final line".to_string());
        }
        let mut out = Vec::new();
        for (i, line) in lines.enumerate() {
            if line.is_empty() {
                continue;
            }
            let parsed =
                line.split_once('|').and_then(|(k, v)| decode_key(k).zip(V::decode(v)));
            match parsed {
                Some(entry) => out.push(entry),
                None => return Err(format!("corrupt entry at line {}", i + 2)),
            }
        }
        Ok(out)
    }

    /// Entries read at open, in file order (later entries are newer).
    /// Leaves the store empty; call once when filling the in-memory
    /// cache tier.
    pub fn take_loaded(&mut self) -> Vec<(Vec<usize>, V)> {
        std::mem::take(&mut self.loaded)
    }

    /// How many entries the open loaded (0 after `take_loaded`).
    pub fn loaded_len(&self) -> usize {
        self.loaded.len()
    }

    /// Why pre-existing contents were discarded at open, if they were.
    pub fn discarded(&self) -> Option<&str> {
        self.discarded.as_deref()
    }

    /// Entries appended since open.
    pub fn appended(&self) -> usize {
        self.appended
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one entry. Failures (and unrepresentable values) are
    /// swallowed after a warning: persistence is an accelerator, never
    /// a reason to fail an evaluation.
    ///
    /// Each entry is flushed immediately, so a line reaches the OS as
    /// one small `O_APPEND` write: a crash can tear at most the final
    /// line, and a second writer on the same file (operator error, but
    /// survivable) interleaves whole lines rather than fragments. The
    /// cost — one syscall per *fresh* evaluation — is noise next to
    /// the evaluation itself.
    pub fn append(&mut self, key: &[usize], value: &V) {
        if self.write_failed {
            return;
        }
        let encoded = value.encode();
        if encoded.contains('\n') {
            return; // Unrepresentable in the line format; skip.
        }
        if writeln!(self.writer, "{}|{}", encode_key(key), encoded).is_err() {
            eprintln!(
                "cache store {}: append failed; persistence disabled for this run",
                self.path.display()
            );
            self.write_failed = true;
            return;
        }
        self.appended += 1;
        self.flush();
    }

    /// Push buffered appends to the OS. Called on drop; call earlier
    /// if another reader needs to see the entries mid-run.
    pub fn flush(&mut self) {
        if self.writer.flush().is_err() && !self.write_failed {
            eprintln!(
                "cache store {}: flush failed; persistence disabled for this run",
                self.path.display()
            );
            self.write_failed = true;
        }
    }
}

impl<V: CacheValue> Drop for CacheStore<V> {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("nahas-store-unit-{}-{name}", std::process::id()))
    }

    fn result(acc: f64, lat: f64, valid: bool) -> EvalResult {
        EvalResult { acc, latency_ms: lat, energy_mj: 0.25, area_mm2: 80.0, valid }
    }

    #[test]
    fn roundtrips_entries_bit_exactly() {
        let path = tmp("roundtrip.cache");
        let _ = fs::remove_file(&path);
        let fp = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, 7);
        {
            let mut store: CacheStore = CacheStore::open(&path, &fp).unwrap();
            assert!(store.discarded().is_none());
            assert_eq!(store.loaded_len(), 0);
            store.append(&[1, 2, 3], &result(0.761234567890123, 0.35, true));
            store.append(&[], &result(f64::NAN, -0.0, false));
            store.append(&[9], &result(f64::INFINITY, 1e-300, true));
        }
        let mut store: CacheStore = CacheStore::open(&path, &fp).unwrap();
        assert!(store.discarded().is_none());
        let loaded = store.take_loaded();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded[0].0, vec![1, 2, 3]);
        assert_eq!(loaded[0].1.acc.to_bits(), 0.761234567890123f64.to_bits());
        assert_eq!(loaded[1].0, Vec::<usize>::new());
        assert!(loaded[1].1.acc.is_nan());
        assert_eq!(loaded[1].1.latency_ms.to_bits(), (-0.0f64).to_bits());
        assert!(!loaded[1].1.valid);
        assert_eq!(loaded[2].1.acc, f64::INFINITY);
        assert_eq!(loaded[2].1.latency_ms.to_bits(), 1e-300f64.to_bits());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn stale_fingerprint_discards_and_restarts() {
        let path = tmp("stale.cache");
        let _ = fs::remove_file(&path);
        {
            let mut store: CacheStore = CacheStore::open(&path, "eval/old-fp").unwrap();
            store.append(&[4, 2], &result(0.7, 0.4, true));
        }
        let mut store: CacheStore = CacheStore::open(&path, "eval/new-fp").unwrap();
        assert!(store.discarded().unwrap().contains("fingerprint mismatch"));
        assert_eq!(store.loaded_len(), 0);
        store.append(&[1], &result(0.5, 0.1, true));
        drop(store);
        // The restarted file carries the new fingerprint only.
        let mut again: CacheStore = CacheStore::open(&path, "eval/new-fp").unwrap();
        assert!(again.discarded().is_none());
        assert_eq!(again.take_loaded().len(), 1);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn corrupt_and_truncated_files_fall_back_cold() {
        let path = tmp("corrupt.cache");
        for damage in ["garbage in the middle", "1,2|1 aa"] {
            let _ = fs::remove_file(&path);
            let fp = "eval/fp";
            {
                let mut store: CacheStore = CacheStore::open(&path, fp).unwrap();
                store.append(&[1, 2], &result(0.7, 0.4, true));
            }
            let mut text = fs::read_to_string(&path).unwrap();
            text.push_str(damage); // No trailing newline: also truncated.
            fs::write(&path, &text).unwrap();
            let store: CacheStore = CacheStore::open(&path, fp).unwrap();
            assert!(store.discarded().is_some(), "damage '{damage}' not detected");
            assert_eq!(store.loaded_len(), 0);
        }
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn unreadable_bytes_discard_with_a_reason_not_silently() {
        let path = tmp("non-utf8.cache");
        let _ = fs::remove_file(&path);
        let fp = "eval/fp";
        {
            let mut store: CacheStore = CacheStore::open(&path, fp).unwrap();
            store.append(&[3], &result(0.6, 0.2, true));
        }
        // Raw invalid-UTF-8 corruption: read_to_string cannot even
        // read it; that must surface as a discard, not a fresh file.
        let mut bytes = fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xFF, 0xFE, 0xFD]);
        fs::write(&path, &bytes).unwrap();
        let store: CacheStore = CacheStore::open(&path, fp).unwrap();
        assert!(store.discarded().unwrap().contains("unreadable"));
        assert_eq!(store.loaded_len(), 0);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn string_values_roundtrip_for_the_serve_cache() {
        let path = tmp("serve.cache");
        let _ = fs::remove_file(&path);
        let fp = serve_fingerprint();
        let resp = r#"{"valid": true, "latency_ms": 0.41}"#.to_string();
        {
            let mut store: CacheStore<String> = CacheStore::open(&path, &fp).unwrap();
            store.append(&[1, 0, 7, 3], &resp);
            // A newline-bearing value is unrepresentable: skipped.
            store.append(&[5], &"bad\nvalue".to_string());
            assert_eq!(store.appended(), 1);
        }
        let mut store: CacheStore<String> = CacheStore::open(&path, &fp).unwrap();
        let loaded = store.take_loaded();
        assert_eq!(loaded, vec![(vec![1, 0, 7, 3], resp)]);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn fingerprints_separate_contexts() {
        let a = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, 7);
        let b = eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, 8);
        let c = eval_fingerprint(NasSpaceId::EfficientNet, Task::Segmentation, 7);
        let d = eval_fingerprint(NasSpaceId::MobileNetV2, Task::Classification, 7);
        let all = [a, b, c, d, serve_fingerprint()];
        for (i, x) in all.iter().enumerate() {
            for y in &all[i + 1..] {
                assert_ne!(x, y);
            }
        }
    }

    #[test]
    fn task_set_fingerprints_separate_multi_from_single() {
        // A single-task set through the task-set API is exactly the
        // classic fingerprint/file — old caches stay valid.
        assert_eq!(
            eval_fingerprint_tasks(NasSpaceId::EfficientNet, &[Task::Classification], 7),
            eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, 7),
        );
        let dir = Path::new("cache");
        assert_eq!(
            eval_cache_file_tasks(dir, NasSpaceId::EfficientNet, &[Task::Classification], 7),
            eval_cache_file(dir, NasSpaceId::EfficientNet, Task::Classification, 7),
        );
        // A multi-task set is distinct from every single-task context
        // (its entries carry task-index-prefixed keys), and sensitive
        // to task order — order defines the prefix indices.
        let multi = eval_fingerprint_tasks(
            NasSpaceId::EfficientNet,
            &[Task::Classification, Task::Segmentation],
            7,
        );
        let multi_rev = eval_fingerprint_tasks(
            NasSpaceId::EfficientNet,
            &[Task::Segmentation, Task::Classification],
            7,
        );
        let singles = [
            eval_fingerprint(NasSpaceId::EfficientNet, Task::Classification, 7),
            eval_fingerprint(NasSpaceId::EfficientNet, Task::Segmentation, 7),
        ];
        for s in &singles {
            assert_ne!(&multi, s);
            assert_ne!(&multi_rev, s);
        }
        assert_ne!(multi, multi_rev);
        assert!(multi.contains("multi-classification+segmentation"), "{multi}");
        let f = eval_cache_file_tasks(
            dir,
            NasSpaceId::EfficientNet,
            &[Task::Classification, Task::Segmentation],
            7,
        );
        assert_ne!(f, eval_cache_file(dir, NasSpaceId::EfficientNet, Task::Classification, 7));
    }
}
