//! Regularized evolution (Real et al.) as a controller baseline for the
//! optimization-strategy comparison (§4.4 compares joint/alternating/
//! nested strategies; evolution and random give non-RL reference points).

use std::collections::VecDeque;

use crate::search::Controller;
use crate::util::Rng;

struct Member {
    decisions: Vec<usize>,
    reward: f64,
}

pub struct EvolutionController {
    cards: Vec<usize>,
    population: VecDeque<Member>,
    pub population_size: usize,
    pub tournament: usize,
    /// Decisions mutated per child.
    pub mutations: usize,
    pending: Vec<Vec<usize>>,
}

impl EvolutionController {
    pub fn new(cards: Vec<usize>) -> Self {
        EvolutionController {
            cards,
            population: VecDeque::new(),
            population_size: 64,
            tournament: 16,
            mutations: 1,
            pending: Vec::new(),
        }
    }

    fn mutate(&self, parent: &[usize], rng: &mut Rng) -> Vec<usize> {
        let mut child = parent.to_vec();
        for _ in 0..self.mutations {
            let i = rng.below(child.len());
            child[i] = rng.below(self.cards[i]);
        }
        child
    }
}

impl Controller for EvolutionController {
    fn sample(&mut self, rng: &mut Rng) -> Vec<usize> {
        let d = if self.population.len() < self.population_size {
            // Seeding phase: random.
            self.cards.iter().map(|&c| rng.below(c)).collect()
        } else {
            // Tournament selection over a random subset, mutate winner.
            let mut best: Option<&Member> = None;
            for _ in 0..self.tournament {
                let m = &self.population[rng.below(self.population.len())];
                if best.map(|b| m.reward > b.reward).unwrap_or(true) {
                    best = Some(m);
                }
            }
            self.mutate(&best.unwrap().decisions.clone(), rng)
        };
        self.pending.push(d.clone());
        d
    }

    fn update(&mut self, batch: &[(Vec<usize>, f64)]) {
        for (d, r) in batch {
            self.population.push_back(Member { decisions: d.clone(), reward: *r });
            // Regularized: kill the OLDEST, not the worst.
            if self.population.len() > self.population_size {
                self.population.pop_front();
            }
        }
        self.pending.clear();
    }

    fn best(&self) -> Vec<usize> {
        // Total order so a NaN reward (degenerate objective) cannot
        // panic the selection; NaN explicitly loses to every real
        // reward (sorts last) and ties break via `total_cmp` so the
        // pick stays deterministic even when all rewards are NaN.
        self.population
            .iter()
            .max_by(|a, b| {
                (!a.reward.is_nan())
                    .cmp(&!b.reward.is_nan())
                    .then(a.reward.total_cmp(&b.reward))
            })
            .map(|m| m.decisions.clone())
            .unwrap_or_else(|| vec![0; self.cards.len()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evolution_improves_on_onemax() {
        // Reward = fraction of decisions equal to 1.
        let cards = vec![2; 20];
        let mut ctl = EvolutionController::new(cards);
        let mut rng = Rng::new(9);
        let fitness = |d: &[usize]| d.iter().filter(|&&x| x == 1).count() as f64 / 20.0;
        let mut last = 0.0;
        for gen in 0..40 {
            let batch: Vec<(Vec<usize>, f64)> = (0..16)
                .map(|_| {
                    let d = ctl.sample(&mut rng);
                    let r = fitness(&d);
                    (d, r)
                })
                .collect();
            ctl.update(&batch);
            if gen == 39 {
                last = fitness(&ctl.best());
            }
        }
        assert!(last > 0.8, "evolution best fitness {last}");
    }

    #[test]
    fn population_is_bounded_and_ages_out() {
        let mut ctl = EvolutionController::new(vec![2; 4]);
        ctl.population_size = 8;
        let mut rng = Rng::new(10);
        for _ in 0..64 {
            let d = ctl.sample(&mut rng);
            ctl.update(&[(d, 0.5)]);
        }
        assert_eq!(ctl.population.len(), 8);
    }
}
