//! Batched, multi-threaded evaluation (paper §4.1).
//!
//! The paper deploys its estimators "as a service where multiple NAHAS
//! clients can send parallel requests" because per-sample evaluation
//! cost dominates joint search. This module is the local half of that
//! design:
//!
//! * [`MemoCache`] — a bounded memo cache keyed on the joint decision
//!   vector. RL controllers resample the same decisions constantly as
//!   the policy sharpens, so late-search batches are mostly hits;
//! * [`ParallelSim`] — a [`SurrogateSim`]-backed [`Evaluator`] whose
//!   `evaluate_batch` dedups the batch through the cache and fans the
//!   misses out over `std::thread::scope` workers (std-only build: no
//!   rayon/tokio).
//!
//! Both are **bit-identical** to the serial path for the same seed:
//! the underlying evaluation ([`SurrogateSim::evaluate_pure`]) is a
//! deterministic function of (space, task, seed, decisions), so
//! caching and thread placement cannot change any result — only how
//! fast and how often it is computed. `tests/parallel_equivalence.rs`
//! pins this down across seeds and worker counts.

use std::collections::HashMap;

use crate::nas::NasSpace;
use crate::search::evaluator::{
    EvalCounters, EvalResult, EvalStats, Evaluator, SimScratch, SurrogateSim,
};

/// Bounded memo cache over joint `nas ++ has` decision vectors.
///
/// Eviction is segmented-LRU: entries live in a *current* generation;
/// when it fills, it becomes the *previous* generation and a fresh one
/// starts. Hits in the previous generation promote back into the
/// current one, so anything touched within the last `capacity` unique
/// inserts survives — classic two-generation approximation of LRU with
/// O(1) operations and at most `2 * capacity` resident entries.
///
/// Generic over the memoized value so the same eviction policy serves
/// every cache tier: [`EvalResult`] in the evaluators (the default),
/// `(EvalResult, session)` in the cross-search
/// [`crate::search::EvalBroker`], and serialized response lines in the
/// `nahas serve` server-side cache.
#[derive(Debug)]
pub struct MemoCache<V: Clone = EvalResult> {
    capacity: usize,
    cur: HashMap<Vec<usize>, V>,
    prev: HashMap<Vec<usize>, V>,
}

impl<V: Clone> MemoCache<V> {
    pub fn new(capacity: usize) -> Self {
        MemoCache { capacity: capacity.max(1), cur: HashMap::new(), prev: HashMap::new() }
    }

    pub fn get(&mut self, key: &[usize]) -> Option<V> {
        if let Some(r) = self.cur.get(key) {
            return Some(r.clone());
        }
        if let Some(r) = self.prev.remove(key) {
            self.insert_rotating(key.to_vec(), r.clone());
            return Some(r);
        }
        None
    }

    pub fn insert(&mut self, key: Vec<usize>, result: V) {
        self.insert_rotating(key, result);
    }

    fn insert_rotating(&mut self, key: Vec<usize>, result: V) {
        if self.cur.len() >= self.capacity {
            self.prev = std::mem::take(&mut self.cur);
        }
        self.cur.insert(key, result);
    }

    pub fn len(&self) -> usize {
        self.cur.len() + self.prev.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Iterate every resident entry (both generations), newest
    /// generation first, in unspecified order within a generation. A
    /// key present in both generations (possible only when `insert` is
    /// called without a preceding `get`, which promotes-and-removes)
    /// yields its current-generation value once. Persistence
    /// ([`crate::search::store`]) spills incrementally rather than by
    /// snapshot, so today this is the in-memory *reference* view its
    /// property tests compare a reloaded file against — and the export
    /// seam for any future snapshot-style spill.
    pub fn entries(&self) -> impl Iterator<Item = (&[usize], &V)> {
        let shadowed =
            self.prev.iter().filter(|(k, _)| !self.cur.contains_key(k.as_slice()));
        self.cur
            .iter()
            .chain(shadowed)
            .map(|(k, v)| (k.as_slice(), v))
    }
}

/// Concatenated memo key for one sample.
pub fn joint_key(nas_d: &[usize], has_d: &[usize]) -> Vec<usize> {
    let mut k = Vec::with_capacity(nas_d.len() + has_d.len());
    k.extend_from_slice(nas_d);
    k.extend_from_slice(has_d);
    k
}

/// Cache-aware batch execution plan, shared by the parallel tiers
/// ([`ParallelSim`], [`crate::service::ServiceEvaluator`],
/// [`crate::cluster::ShardedEvaluator`]): `build` resolves cache hits
/// and dedups the misses preserving first-seen
/// order; the caller evaluates `pending()` however it fans out; then
/// `finish` reassembles everything in batch order, memoizing only the
/// results marked cacheable (a transport failure must not poison the
/// cache — the next resample has to retry the evaluation).
pub(crate) struct BatchPlan {
    results: Vec<Option<(EvalResult, bool)>>,
    pending: Vec<Vec<usize>>,
    waiting: HashMap<Vec<usize>, Vec<usize>>,
}

impl BatchPlan {
    pub(crate) fn build(cache: &mut MemoCache, batch: &[(Vec<usize>, Vec<usize>)]) -> Self {
        let mut results: Vec<Option<(EvalResult, bool)>> = vec![None; batch.len()];
        let mut pending: Vec<Vec<usize>> = Vec::new();
        let mut waiting: HashMap<Vec<usize>, Vec<usize>> = HashMap::new();
        for (i, (nas_d, has_d)) in batch.iter().enumerate() {
            let key = joint_key(nas_d, has_d);
            if let Some(r) = cache.get(&key) {
                // A memoized result was cacheable by definition.
                results[i] = Some((r, true));
            } else {
                let slots = waiting.entry(key.clone()).or_default();
                if slots.is_empty() {
                    pending.push(key);
                }
                slots.push(i);
            }
        }
        BatchPlan { results, pending, waiting }
    }

    /// Deduped cache misses, in first-seen batch order.
    pub(crate) fn pending(&self) -> &[Vec<usize>] {
        &self.pending
    }

    /// `fresh[i]` pairs with `pending()[i]`: the result and whether it
    /// may be memoized.
    pub(crate) fn finish(
        self,
        cache: &mut MemoCache,
        fresh: Vec<(EvalResult, bool)>,
    ) -> Vec<EvalResult> {
        self.finish_tagged(cache, fresh).into_iter().map(|(r, _)| r).collect()
    }

    /// [`BatchPlan::finish`], but keeping each slot's cacheable marker
    /// (cache hits are `true` by construction) so callers implementing
    /// [`Evaluator::evaluate_batch_tagged`] can pass the verdicts up
    /// the stack.
    pub(crate) fn finish_tagged(
        self,
        cache: &mut MemoCache,
        fresh: Vec<(EvalResult, bool)>,
    ) -> Vec<(EvalResult, bool)> {
        assert_eq!(fresh.len(), self.pending.len(), "one result per deduped key");
        let BatchPlan { mut results, pending, waiting } = self;
        for (key, (r, cacheable)) in pending.into_iter().zip(fresh) {
            for &i in &waiting[&key] {
                results[i] = Some((r, cacheable));
            }
            if cacheable {
                cache.insert(key, r);
            }
        }
        results.into_iter().map(|r| r.expect("all batch slots resolved")).collect()
    }
}

/// Parallel batched surrogate+simulator evaluator: memo cache in
/// front, scoped worker threads behind.
pub struct ParallelSim {
    /// The shared evaluation core (config + pure evaluation).
    pub sim: SurrogateSim,
    /// Worker threads for a batch (1 = in-thread serial).
    pub workers: usize,
    cache: MemoCache,
    counters: EvalCounters,
}

const DEFAULT_CACHE_CAPACITY: usize = 16 * 1024;

impl ParallelSim {
    pub fn new(space: NasSpace, seed: u64, workers: usize) -> Self {
        ParallelSim {
            sim: SurrogateSim::new(space, seed),
            workers: workers.max(1),
            cache: MemoCache::new(DEFAULT_CACHE_CAPACITY),
            counters: EvalCounters::default(),
        }
    }

    pub fn segmentation(mut self) -> Self {
        self.sim = self.sim.segmentation();
        self
    }

    pub fn with_cache_capacity(mut self, capacity: usize) -> Self {
        self.cache = MemoCache::new(capacity);
        self
    }

    /// Evaluate deduped keys, in order, across up to `self.workers`
    /// scoped threads. Results are reassembled in key order, so the
    /// caller sees exactly what a serial loop would have produced.
    fn run_workers(&self, keys: &[Vec<usize>], nas_len: usize) -> Vec<EvalResult> {
        let workers = self.workers.min(keys.len()).max(1);
        if workers == 1 {
            let mut scratch = SimScratch::default();
            return keys
                .iter()
                .map(|k| self.sim.evaluate_pure_in(&k[..nas_len], &k[nas_len..], &mut scratch))
                .collect();
        }
        let sim = &self.sim;
        let chunk = keys.len().div_ceil(workers);
        let mut out = Vec::with_capacity(keys.len());
        std::thread::scope(|s| {
            let handles: Vec<_> = keys
                .chunks(chunk)
                .map(|ck| {
                    // One decode scratch per worker thread: the chunk
                    // reuses its buffers, threads never share them.
                    s.spawn(move || {
                        let mut scratch = SimScratch::default();
                        ck.iter()
                            .map(|k| {
                                sim.evaluate_pure_in(&k[..nas_len], &k[nas_len..], &mut scratch)
                            })
                            .collect::<Vec<EvalResult>>()
                    })
                })
                .collect();
            for h in handles {
                out.extend(h.join().expect("evaluation worker panicked"));
            }
        });
        out
    }
}

impl Evaluator for ParallelSim {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        self.counters.requests += 1;
        let key = joint_key(nas_d, has_d);
        let r = match self.cache.get(&key) {
            Some(r) => r,
            None => {
                let r = self.sim.evaluate_pure(nas_d, has_d);
                self.counters.evals += 1;
                self.cache.insert(key, r);
                r
            }
        };
        if !r.valid {
            self.counters.invalid += 1;
        }
        r
    }

    fn evaluate_batch(&mut self, batch: &[(Vec<usize>, Vec<usize>)]) -> Vec<EvalResult> {
        if batch.is_empty() {
            return Vec::new();
        }
        self.counters.requests += batch.len();
        let nas_len = batch[0].0.len();
        assert!(
            batch.iter().all(|(nas_d, _)| nas_d.len() == nas_len),
            "mixed decision lengths in one batch"
        );
        let plan = BatchPlan::build(&mut self.cache, batch);
        let fresh = self.run_workers(plan.pending(), nas_len);
        self.counters.evals += fresh.len();
        // Local simulation cannot fail transiently: always cacheable.
        let out = plan.finish(&mut self.cache, fresh.into_iter().map(|r| (r, true)).collect());
        self.counters.invalid += out.iter().filter(|r| !r.valid).count();
        out
    }

    fn stats(&self) -> EvalStats {
        self.counters.stats()
    }

    /// A batch fans out over up to `workers` scoped threads, so the
    /// broker may usefully keep that many session batches in flight.
    fn capacity(&self) -> usize {
        self.workers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::has::HasSpace;
    use crate::nas::NasSpaceId;
    use crate::util::Rng;

    fn random_batch(n: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let has = HasSpace::new();
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect()
    }

    #[test]
    fn batch_matches_serial_for_any_worker_count() {
        let batch = random_batch(24, 11);
        let mut serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
        let want: Vec<EvalResult> =
            batch.iter().map(|(n, h)| serial.evaluate(n, h)).collect();
        for workers in [1, 3, 8] {
            let mut par = ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3, workers);
            let got = par.evaluate_batch(&batch);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.valid, w.valid);
                assert_eq!(g.acc.to_bits(), w.acc.to_bits(), "workers {workers}");
                assert_eq!(g.latency_ms.to_bits(), w.latency_ms.to_bits());
                assert_eq!(g.energy_mj.to_bits(), w.energy_mj.to_bits());
                assert_eq!(g.area_mm2.to_bits(), w.area_mm2.to_bits());
            }
        }
    }

    #[test]
    fn cache_dedups_repeats_within_and_across_batches() {
        let mut batch = random_batch(8, 5);
        let dup = batch[0].clone();
        batch.push(dup);
        let mut par = ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3, 4);
        let first = par.evaluate_batch(&batch);
        assert_eq!(first.len(), 9);
        let s = par.stats();
        assert_eq!(s.requests, 9);
        assert_eq!(s.evals, 8, "in-batch duplicate must be evaluated once");
        let second = par.evaluate_batch(&batch);
        let s = par.stats();
        assert_eq!(s.requests, 18);
        assert_eq!(s.evals, 8, "second pass must be all cache hits");
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.acc.to_bits(), b.acc.to_bits());
        }
    }

    #[test]
    fn memo_cache_evicts_but_stays_bounded() {
        let mut c = MemoCache::new(4);
        for i in 0..100usize {
            c.insert(vec![i], EvalResult { acc: i as f64, valid: true, ..Default::default() });
            assert!(c.len() <= 8, "2x capacity bound violated: {}", c.len());
        }
        // The most recent insert always survives.
        assert_eq!(c.get(&[99]).map(|r| r.acc), Some(99.0));
        // Something ancient is gone.
        assert!(c.get(&[0]).is_none());
    }

    #[test]
    fn memo_cache_entries_cover_both_generations_without_duplicates() {
        let mut c = MemoCache::new(2);
        c.insert(vec![1], EvalResult { acc: 1.0, valid: true, ..Default::default() });
        c.insert(vec![2], EvalResult { acc: 2.0, valid: true, ..Default::default() });
        // Rotation: {1, 2} -> prev; 3 starts the new generation.
        c.insert(vec![3], EvalResult { acc: 3.0, valid: true, ..Default::default() });
        // Blind re-insert of 1 (no get first): now in both generations.
        c.insert(vec![1], EvalResult { acc: 10.0, valid: true, ..Default::default() });
        let mut got: Vec<(Vec<usize>, u64)> =
            c.entries().map(|(k, v)| (k.to_vec(), v.acc.to_bits())).collect();
        got.sort();
        let want: Vec<(Vec<usize>, u64)> = vec![
            (vec![1], 10.0f64.to_bits()),
            (vec![2], 2.0f64.to_bits()),
            (vec![3], 3.0f64.to_bits()),
        ];
        assert_eq!(got, want, "shadowed prev entry must not appear");
    }

    #[test]
    fn memo_cache_promotes_recent_across_rotation() {
        let mut c = MemoCache::new(2);
        c.insert(vec![1], EvalResult { acc: 1.0, valid: true, ..Default::default() });
        c.insert(vec![2], EvalResult { acc: 2.0, valid: true, ..Default::default() });
        // Rotation: cur -> prev.
        c.insert(vec![3], EvalResult { acc: 3.0, valid: true, ..Default::default() });
        // Hit in prev promotes 1 into cur.
        assert_eq!(c.get(&[1]).map(|r| r.acc), Some(1.0));
        assert_eq!(c.get(&[1]).map(|r| r.acc), Some(1.0));
    }
}
