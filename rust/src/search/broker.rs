//! [`EvalBroker`] — the shared, admission-controlled evaluation seam.
//!
//! PR 1/PR 2 built four evaluator tiers (local, parallel, service,
//! cluster), but every search driver *exclusively borrowed* its
//! evaluator (`&mut dyn Evaluator`), so a multi-target sweep — the
//! paper's headline figures are built from sweeps of searches — ran
//! serially and could not share the worker pool, service farm, or memo
//! cache between scenarios. The broker removes that restriction:
//!
//! * [`EvalBroker`] wraps **one** backend (`Box<dyn Evaluator + Send>`)
//!   and hands out any number of [`BrokerSession`] handles;
//! * each session implements [`Evaluator`], so every existing driver
//!   ([`crate::search::joint_search`],
//!   [`crate::search::phase::phase_search`]) runs unchanged on its own
//!   thread — N concurrent searches multiplex onto the one backend;
//! * a **cross-search memo cache** keyed on the joint decision vector
//!   sits in front of the backend: a (alpha, h) point discovered by one
//!   scenario is evaluated once, ever — later scenarios hit the cache
//!   (counted as [`EvalStats::cross_session_hits`]);
//! * sessions keep **per-session counter deltas**, and the broker keeps
//!   the global sum, so a sweep can report both per-scenario and
//!   whole-run throughput without double counting (the invariant
//!   "session deltas sum to the broker, broker misses equal backend
//!   requests" is pinned by tests below);
//! * optionally a persistent [`CacheStore`] backs the cache
//!   ([`EvalBroker::with_store`], CLI `--cache-dir`): entries spilled
//!   by an earlier run pre-load at open (hits on them count as
//!   [`EvalStats::persisted_hits`]) and every cacheable fresh
//!   evaluation is appended back, so repeated runs and sweeps
//!   warm-start across processes (`tests/cache_persistence.rs`).
//!
//! # Concurrency model: two tiers under one lock, dispatch outside it
//!
//! Until PR 5 the broker held its mutex **across the backend call**, so
//! a backend with idle worker capacity still served exactly one
//! session's batch at a time. The dispatch path is now an
//! admission-controlled scheduler split into two tiers:
//!
//! * the **cache/stats tier** (`CacheTier`) is only ever touched with
//!   the state lock held: memo-cache resolution, persistent-store
//!   appends, and the global counters;
//! * the **dispatch tier** (`DispatchTier`) tracks what is *between*
//!   the cache and the backend: an **in-flight table** (joint key →
//!   slot) of evaluations some session has claimed but the backend has
//!   not finished, a FIFO **queue** of claimed-but-not-yet-dispatched
//!   keys, and the **admission** count of session batches currently in
//!   flight. The backend call itself runs with the state lock
//!   *released*: a session "checks the backend out" of the state,
//!   evaluates a bounded *chunk* from the front of the queue, and
//!   parks it back.
//!
//! A session batch flows through three steps:
//!
//! 1. **resolve** (lock held) — cache hits are answered immediately; a
//!    key that is already *in flight* is never claimed again: the
//!    session registers as a waiter on its slot and the repeat request
//!    is counted as a cross-session hit ([`EvalStats::inflight_hits`]
//!    tallies this mid-flight subset) — overlapping sessions can never
//!    duplicate an in-progress evaluation;
//! 2. **admit + claim** (lock held) — a batch that needs fresh backend
//!    work waits until fewer than `inflight_limit` batches are in
//!    flight (`--broker-inflight N`, clamped to the backend's
//!    [`Evaluator::capacity`] hint; `local` advertises 1, so the serial
//!    path is untouched), then claims its unresolved keys: one
//!    in-flight slot and one queue entry each. Keys that become cached
//!    or in-flight *while queueing for admission* resolve without a
//!    slot — a batch never waits out admission it no longer needs;
//! 3. **dispatch or wait** (lock released around the backend) — any
//!    session whose results are still pending takes the parked backend
//!    and evaluates at most a *chunk* (`--dispatch-chunk`, default the
//!    backend's [`Evaluator::capacity`] hint) from the **front** of
//!    the FIFO queue — its own claims and everyone else's interleaved
//!    — in one `evaluate_batch_tagged` call, then completes those
//!    slots, memoizes the cacheable results, and wakes all waiters.
//!    Batches admitted while the backend is busy therefore *coalesce*
//!    into the next dispatch, which is where the overlap pays: small
//!    per-session batches combine to fill the backend's worker pool
//!    instead of underfilling it one batch at a time
//!    (`benches/perf_broker_overlap.rs` measures exactly this). The
//!    chunk bound is what keeps tail latency flat: a session whose
//!    keys sit at the front of a long queue is completed — and woken —
//!    by the first chunk instead of waiting out one giant dispatch of
//!    everyone's work (`benches/perf_tail_latency.rs` measures the
//!    p50/p99 per-batch wait; `tests/broker_streaming.rs` pins the
//!    ordering). Sessions left pending simply dispatch the next chunk,
//!    so the queue keeps draining as long as anyone still waits.
//!
//! Failure rules: a transient transport failure (`cacheable: false`
//! from the backend) completes its slot and wakes every waiter, but is
//! never memoized and never reaches the persistent store — the
//! in-flight entry is simply removed so the next resample retries. A
//! backend that *panics* mid-dispatch can never be parked again; the
//! broker marks it lost and every blocked or future session panics
//! instead of hanging (`tests/broker_admission.rs`).
//!
//! Because every backend evaluation is a deterministic function of
//! (space, task, seed, decisions), neither coalescing nor overlap can
//! change *what* a scenario computes — each scenario stays
//! bit-identical to its standalone run for the same controller seed
//! whatever the interleaving (`tests/sweep_equivalence.rs`,
//! `tests/broker_admission.rs`).

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::search::evaluator::{EvalResult, EvalStats, Evaluator, HostEvalStats};
use crate::search::parallel::{joint_key, MemoCache};
use crate::search::store::CacheStore;

/// Cache-entry owner id reserved for entries loaded from a persistent
/// [`CacheStore`]: hits on them are warm-start hits
/// ([`EvalStats::persisted_hits`]), not cross-session ones. Session
/// ids count up from 0 and can never collide with it.
const PERSISTED_OWNER: u64 = u64::MAX;

/// Default capacity of the cross-search cache: sized for a whole sweep
/// (several searches of a few thousand samples each), not one search.
///
/// The caching backends (`ParallelSim`, `ServiceEvaluator`,
/// `ShardedEvaluator`) keep their own memo cache behind this one; under
/// a broker it sees only deduped misses and stays mostly cold. That
/// redundancy is deliberate: the backends are also used standalone
/// (tests, benches, library callers), the duplicated residency is
/// bounded, and the cluster tier still needs its own front to keep
/// failover results out of *its* cache independently of the broker.
const BROKER_CACHE_CAPACITY: usize = 64 * 1024;

/// Panic message when the state mutex itself was poisoned (a panic in
/// broker code while holding the lock — never expected).
const POISONED: &str = "evaluation broker state poisoned";

/// Panic message propagated to every session once the backend panicked
/// mid-dispatch and can never be parked again.
const BACKEND_LOST: &str = "evaluation broker poisoned by a panicked backend";

/// One in-progress (or queued) backend evaluation. Created by the
/// session that *claims* the key (it pays for the eval in its stats);
/// completed exactly once by whichever session dispatches it; read by
/// every session waiting on the key.
struct InflightSlot {
    /// Session that claimed the key — the cache entry's owner tag, and
    /// what tells an in-batch duplicate ("my own claim") apart from a
    /// genuine cross-session mid-flight hit.
    owner: u64,
    /// `None` until dispatched; then the result and its cacheable
    /// marker. Only ever accessed with the broker state lock held, but
    /// waiters hold `Arc`s to slots across lock releases, so the field
    /// needs its own interior mutability.
    outcome: Mutex<Option<(EvalResult, bool)>>,
}

impl InflightSlot {
    fn outcome(&self) -> Option<(EvalResult, bool)> {
        *self.outcome.lock().expect(POISONED)
    }

    fn complete(&self, r: EvalResult, cacheable: bool) {
        *self.outcome.lock().expect(POISONED) = Some((r, cacheable));
    }
}

/// A claimed key parked in the dispatch queue, waiting for a session
/// to drive it (and whatever else is queued) through the backend.
struct QueuedEval {
    nas_d: Vec<usize>,
    has_d: Vec<usize>,
    key: Vec<usize>,
    slot: Arc<InflightSlot>,
}

/// Lock-held tier: the cross-search memo cache, the persistent spill
/// store, and the broker-global counters. Nothing here is ever touched
/// without the state lock.
struct CacheTier {
    memo: MemoCache<(EvalResult, u64)>,
    /// Cross-run persistence: pre-loaded into `memo` at open (owner
    /// [`PERSISTED_OWNER`]), appended to on every cacheable fresh
    /// evaluation, flushed when the broker drops.
    store: Option<CacheStore>,
    /// Entries the store loaded at open (the warm-start inventory).
    persisted_loaded: usize,
    requests: usize,
    evals: usize,
    invalid: usize,
    cross_session_hits: usize,
    persisted_hits: usize,
    inflight_hits: usize,
    /// Per-session counter deltas, keyed by session id. Updated in the
    /// same lock acquisition as the broker-global counters above, so an
    /// [`EvalBroker::snapshot`] always sees the two in exact agreement
    /// (per-session fields sum to the broker-wide ones).
    sessions: BTreeMap<u64, SessionCounters>,
}

/// Dispatch tier: everything between the cache and the backend. The
/// *state* lives under the same lock as [`CacheTier`], but the backend
/// call itself always runs with the lock released — the backend is
/// checked out (`backend.take()`), driven, and parked back.
struct DispatchTier {
    /// The one evaluation backend; `None` while a session has it
    /// checked out for a dispatch.
    backend: Option<Box<dyn Evaluator + Send>>,
    /// The backend panicked mid-dispatch and will never come home;
    /// every session propagates [`BACKEND_LOST`] instead of waiting.
    backend_lost: bool,
    /// Joint key → slot for every claimed-but-unfinished evaluation.
    /// Entries are removed the moment their slot completes, so a later
    /// request for a key whose eval *failed* misses here and retries.
    inflight: HashMap<Vec<usize>, Arc<InflightSlot>>,
    /// Claimed keys not yet handed to the backend, in claim order. A
    /// dispatch takes at most `chunk_limit` entries from the front, so
    /// batches from different sessions coalesce into one backend call
    /// while a long queue still drains in bounded, FIFO slices.
    queue: Vec<QueuedEval>,
    /// Session batches currently admitted (claimed keys and not yet
    /// fully resolved). Admission blocks while `admitted >=
    /// inflight_limit`.
    admitted: usize,
    /// Effective admission limit: `--broker-inflight` clamped to
    /// `capacity`.
    inflight_limit: usize,
    /// The backend's [`Evaluator::capacity`] hint, frozen at build.
    capacity: usize,
    /// Most keys a single dispatch may take off the queue
    /// (`--dispatch-chunk`, default `capacity`). Unlike the admission
    /// limit this may exceed capacity — `usize::MAX` restores the
    /// drain-all behavior for A/B measurement.
    chunk_limit: usize,
    dispatches: usize,
    coalesced_dispatches: usize,
    /// Dispatches that left work behind: the queue was deeper than the
    /// chunk limit, so streaming actually kicked in.
    chunked_dispatches: usize,
    /// Deepest the queue has ever been at the moment a dispatch pulled
    /// its chunk — the head-of-line pressure the chunk bound relieves.
    peak_queue_depth: usize,
    peak_admitted: usize,
}

/// What the one state mutex guards: both tiers.
struct BrokerState {
    cache: CacheTier,
    dispatch: DispatchTier,
}

/// How one key resolved against the cache and in-flight table.
enum Resolution {
    /// Memoized: the result and its owner tag.
    Hit(EvalResult, u64),
    /// Claimed by some batch already; wait on its slot.
    Wait(Arc<InflightSlot>),
    /// Unknown: the caller may claim it (after admission).
    Miss,
}

impl BrokerState {
    fn resolve(&mut self, key: &[usize]) -> Resolution {
        if let Some((r, owner)) = self.cache.memo.get(key) {
            return Resolution::Hit(r, owner);
        }
        if let Some(slot) = self.dispatch.inflight.get(key) {
            return Resolution::Wait(slot.clone());
        }
        Resolution::Miss
    }
}

/// The shared immutable shell: state mutex + the condvar every wait in
/// the broker (admission, backend checkout, slot completion) goes
/// through.
struct BrokerCore {
    state: Mutex<BrokerState>,
    progress: Condvar,
}

impl BrokerCore {
    fn lock_state(&self) -> MutexGuard<'_, BrokerState> {
        self.state.lock().expect(POISONED)
    }
}

/// Marks the backend lost if a dispatch unwinds (backend panic), so
/// blocked sessions panic loudly instead of waiting forever for a
/// backend that will never be parked again.
struct DispatchGuard<'a> {
    core: &'a BrokerCore,
    defused: bool,
}

impl Drop for DispatchGuard<'_> {
    fn drop(&mut self) {
        if self.defused {
            return;
        }
        // Never panic in Drop during an unwind: tolerate poisoning.
        let mut st = match self.core.state.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        st.dispatch.backend_lost = true;
        self.core.progress.notify_all();
    }
}

/// Take the parked backend, evaluate at most a chunk-limit-sized slice
/// off the *front* of the dispatch queue in one call with the state
/// lock released, then park it back, complete the slots, memoize/spill
/// the cacheable results, and wake everyone. Leftover queue entries
/// wait for the next dispatch — their claiming sessions are still in
/// their dispatch-or-wait loops, so the queue keeps draining.
fn dispatch_chunk<'a>(
    core: &'a BrokerCore,
    mut st: MutexGuard<'a, BrokerState>,
) -> MutexGuard<'a, BrokerState> {
    let mut backend = st.dispatch.backend.take().expect("dispatch requires a parked backend");
    let depth = st.dispatch.queue.len();
    st.dispatch.peak_queue_depth = st.dispatch.peak_queue_depth.max(depth);
    let take = st.dispatch.chunk_limit.min(depth);
    let chunk: Vec<QueuedEval> = st.dispatch.queue.drain(..take).collect();
    st.dispatch.dispatches += 1;
    if depth > take {
        st.dispatch.chunked_dispatches += 1;
    }
    let mut owners: Vec<u64> = chunk.iter().map(|q| q.slot.owner).collect();
    owners.sort_unstable();
    owners.dedup();
    if owners.len() > 1 {
        st.dispatch.coalesced_dispatches += 1;
    }
    drop(st);

    let misses: Vec<(Vec<usize>, Vec<usize>)> =
        chunk.iter().map(|q| (q.nas_d.clone(), q.has_d.clone())).collect();
    let fresh = {
        let mut guard = DispatchGuard { core, defused: false };
        let fresh = backend.evaluate_batch_tagged(&misses);
        // Check while the guard is still armed: a length-lying backend
        // must mark itself lost, not strand every waiter.
        assert_eq!(fresh.len(), chunk.len(), "backend must preserve batch length");
        guard.defused = true;
        fresh
    };

    let mut st = core.lock_state();
    for (q, (r, cacheable)) in chunk.into_iter().zip(fresh) {
        st.dispatch.inflight.remove(&q.key);
        q.slot.complete(r, cacheable);
        // A transient transport failure must not be memoized — and, a
        // fortiori, must never reach the persistent store: a later
        // resample (from any session, or a whole later run) has to
        // retry it. Its waiters still wake with the invalid result.
        if cacheable {
            if let Some(store) = &mut st.cache.store {
                store.append(&q.key, &r);
            }
            let owner = q.slot.owner;
            st.cache.memo.insert(q.key, (r, owner));
        }
    }
    st.dispatch.backend = Some(backend);
    core.progress.notify_all();
    st
}

/// Per-batch resolution bookkeeping: the partially filled results,
/// the slots the batch waits on (own claims and foreign waits), and
/// the hit counters by kind. One place owns the counting rules, so
/// the resolve pass and the post-admission re-resolve can never
/// account a hit differently.
struct BatchTally {
    results: Vec<Option<EvalResult>>,
    waited: Vec<(usize, Arc<InflightSlot>)>,
    cross: usize,
    persisted: usize,
    inflight_hits: usize,
}

impl BatchTally {
    fn new(len: usize) -> Self {
        BatchTally {
            results: vec![None; len],
            waited: Vec::new(),
            cross: 0,
            persisted: 0,
            inflight_hits: 0,
        }
    }

    /// Absorb a cache hit or in-flight wait for batch slot `i`,
    /// counting it against the right bucket given who paid for it
    /// (`me` being this session's id). `false` for a miss — the
    /// caller claims it (once admitted).
    fn absorb(&mut self, i: usize, res: Resolution, me: u64) -> bool {
        match res {
            Resolution::Hit(r, owner) => {
                if owner == PERSISTED_OWNER {
                    self.persisted += 1;
                } else if owner != me {
                    self.cross += 1;
                }
                self.results[i] = Some(r);
                true
            }
            Resolution::Wait(slot) => {
                // Mid-flight dedup: the key is already being evaluated
                // (on another session's dime unless it is this batch's
                // own earlier claim) — wait for that instead of
                // dispatching it a second time.
                if slot.owner != me {
                    self.cross += 1;
                    self.inflight_hits += 1;
                }
                self.waited.push((i, slot));
                true
            }
            Resolution::Miss => false,
        }
    }
}

/// Overlap telemetry of one broker: how much concurrent admission
/// actually happened ([`EvalBroker::overlap_stats`], printed by `nahas
/// sweep`).
#[derive(Clone, Debug)]
pub struct BrokerOverlapStats {
    /// Effective admission limit (`--broker-inflight` clamped to the
    /// backend capacity).
    pub inflight_limit: usize,
    /// The backend's [`Evaluator::capacity`] hint.
    pub capacity: usize,
    /// Backend `evaluate_batch_tagged` calls made.
    pub dispatches: usize,
    /// Dispatches whose chunk combined claims from more than one
    /// session — the overlap actually paying off.
    pub coalesced_dispatches: usize,
    /// Most session batches ever in flight at once.
    pub peak_admitted: usize,
    /// Most keys a single dispatch may take (`--dispatch-chunk`,
    /// default the backend capacity; `usize::MAX` means drain-all).
    pub chunk_limit: usize,
    /// Dispatches that hit the chunk bound with work left over — the
    /// streaming path actually engaging.
    pub chunked_dispatches: usize,
    /// Deepest the queue has ever been when a dispatch pulled its
    /// chunk.
    pub peak_queue_depth: usize,
}

/// One session's cumulative counter deltas as kept in the broker's
/// registry ([`BrokerSnapshot::sessions`]). The registry is written in
/// the same lock acquisition as the broker-global counters, at batch
/// granularity, so at any snapshot the per-session fields sum exactly
/// to the broker-wide ones.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionCounters {
    /// Session id, in [`EvalBroker::session`] creation order from 0.
    pub id: u64,
    pub requests: usize,
    pub evals: usize,
    pub invalid: usize,
    pub cross_session_hits: usize,
    pub persisted_hits: usize,
    pub inflight_hits: usize,
    /// Backend dispatches this session drove.
    pub dispatched_chunks: usize,
}

/// The backend tier's own counters as seen by a snapshot — present
/// only when the backend happened to be parked (not checked out for a
/// dispatch) at that instant.
#[derive(Clone, Debug, Default)]
pub struct BackendSnapshot {
    /// Requests the backend has served — equals the broker's deduped
    /// misses ([`BrokerSnapshot::evals`]) when quiescent.
    pub requests: usize,
    /// Hosts currently marked down (cluster tier; 0 elsewhere).
    pub hosts_down: usize,
    /// Per-host attribution when the backend is the cluster tier.
    pub per_host: Vec<HostEvalStats>,
    /// Cumulative bytes written to the wire (remote tiers; 0 locally).
    pub wire_tx: u64,
    /// Cumulative bytes read from the wire.
    pub wire_rx: u64,
}

/// One non-blocking observation of the whole broker
/// ([`EvalBroker::snapshot`]): cache-tier counters, the dispatch
/// tier's live queue/admission gauges, the per-session registry, and —
/// when the backend happens to be parked — the backend's own counters
/// and wire totals. This is what [`crate::metrics::MetricsSink`] rows
/// are built from.
#[derive(Clone, Debug, Default)]
pub struct BrokerSnapshot {
    pub requests: usize,
    pub evals: usize,
    pub invalid: usize,
    pub cross_session_hits: usize,
    pub persisted_hits: usize,
    pub inflight_hits: usize,
    /// Entries pre-loaded from the persistent store at open.
    pub persisted_loaded: usize,
    /// Claimed keys parked in the dispatch queue right now (gauge).
    pub queue_depth: usize,
    /// Session batches currently admitted (gauge).
    pub admitted: usize,
    /// Claimed-but-unfinished keys in the in-flight table (gauge).
    pub inflight_keys: usize,
    pub dispatches: usize,
    pub coalesced_dispatches: usize,
    pub chunked_dispatches: usize,
    pub peak_queue_depth: usize,
    pub peak_admitted: usize,
    pub inflight_limit: usize,
    pub capacity: usize,
    pub chunk_limit: usize,
    /// Per-session cumulative deltas, ascending session id. Counter
    /// fields sum exactly to the broker-wide ones above.
    pub sessions: Vec<SessionCounters>,
    /// The backend's own view, if it was parked at snapshot time;
    /// `None` means a dispatch was in flight — the consumer carries
    /// the last known values forward.
    pub backend: Option<BackendSnapshot>,
}

/// Shared handle to one evaluation backend. Cheap to clone; create one
/// [`BrokerSession`] per concurrent search with [`EvalBroker::session`].
///
/// # Examples
///
/// ```
/// use nahas::has::HasSpace;
/// use nahas::nas::{NasSpace, NasSpaceId};
/// use nahas::search::{EvalBroker, Evaluator, SurrogateSim};
///
/// let space = NasSpace::new(NasSpaceId::EfficientNet);
/// let nas_d = vec![0; space.num_decisions()];
/// let broker = EvalBroker::new(Box::new(SurrogateSim::new(space, 3)));
/// let mut session = broker.session(); // one per concurrent search
/// let r = session.evaluate(&nas_d, &HasSpace::new().baseline_decisions());
/// assert!(r.valid);
/// assert_eq!(broker.stats().evals, 1);
/// ```
#[derive(Clone)]
pub struct EvalBroker {
    core: Arc<BrokerCore>,
    next_session: Arc<AtomicU64>,
}

impl EvalBroker {
    /// Wrap a backend. Any [`Evaluator`] tier works — `SurrogateSim`
    /// (local), `ParallelSim`, `ServiceEvaluator`, `ShardedEvaluator` —
    /// as long as it evaluates a sample as a pure function of its
    /// decisions, which is the contract every tier already pins in
    /// `tests/parallel_equivalence.rs`. The admission limit defaults to
    /// the backend's [`Evaluator::capacity`] hint (1 for the local
    /// tier, so single-backend runs stay strictly serial).
    pub fn new(backend: Box<dyn Evaluator + Send>) -> Self {
        Self::build(backend, None)
    }

    /// Wrap a backend with a persistent [`CacheStore`] behind the
    /// cross-search cache (`--cache-dir`): entries the store loaded
    /// are served as [`EvalStats::persisted_hits`]; every cacheable
    /// fresh evaluation is appended back, and the file is flushed when
    /// the broker drops. The store must have been opened with the
    /// fingerprint of this broker's evaluation context
    /// ([`crate::search::store::eval_fingerprint`]) — the fingerprint,
    /// not the caller, is what makes replaying an entry sound.
    pub fn with_store(backend: Box<dyn Evaluator + Send>, store: CacheStore) -> Self {
        Self::build(backend, Some(store))
    }

    fn build(backend: Box<dyn Evaluator + Send>, mut store: Option<CacheStore>) -> Self {
        let loaded = store.as_mut().map(|s| s.take_loaded()).unwrap_or_default();
        let persisted_loaded = loaded.len();
        // The whole warm inventory must be resident: "a fully-warm run
        // performs zero backend evals" only holds if no persisted entry
        // is evicted before it is re-requested, so a file that outgrew
        // the default capacity sizes the cache up to fit it.
        let mut memo = MemoCache::new(BROKER_CACHE_CAPACITY.max(persisted_loaded));
        for (key, r) in loaded {
            memo.insert(key, (r, PERSISTED_OWNER));
        }
        let capacity = backend.capacity().max(1);
        EvalBroker {
            core: Arc::new(BrokerCore {
                state: Mutex::new(BrokerState {
                    cache: CacheTier {
                        memo,
                        store,
                        persisted_loaded,
                        requests: 0,
                        evals: 0,
                        invalid: 0,
                        cross_session_hits: 0,
                        persisted_hits: 0,
                        inflight_hits: 0,
                        sessions: BTreeMap::new(),
                    },
                    dispatch: DispatchTier {
                        backend: Some(backend),
                        backend_lost: false,
                        inflight: HashMap::new(),
                        queue: Vec::new(),
                        admitted: 0,
                        inflight_limit: capacity,
                        capacity,
                        chunk_limit: capacity,
                        dispatches: 0,
                        coalesced_dispatches: 0,
                        chunked_dispatches: 0,
                        peak_queue_depth: 0,
                        peak_admitted: 0,
                    },
                }),
                progress: Condvar::new(),
            }),
            next_session: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Set the admission limit (CLI `--broker-inflight N`): how many
    /// session batches may be in flight concurrently. Clamped to
    /// `1..=capacity`, where capacity is the backend's
    /// [`Evaluator::capacity`] hint — a backend that can only serve
    /// one caller (the local tier) is never over-admitted, so the
    /// serial path is untouched whatever the flag says. `1` restores
    /// the pre-admission behavior: strictly one session batch at a
    /// time.
    pub fn with_inflight_limit(self, limit: usize) -> Self {
        {
            let mut st = self.core.lock_state();
            let cap = st.dispatch.capacity;
            st.dispatch.inflight_limit = limit.clamp(1, cap);
        }
        self
    }

    /// Set the dispatch chunk bound (CLI `--dispatch-chunk N`): the
    /// most keys one backend call may take off the front of the queue.
    /// Defaults to the backend's [`Evaluator::capacity`] hint — one
    /// dispatch fills the worker pool exactly, and a queue deeper than
    /// the pool streams out in capacity-sized slices instead of one
    /// giant head-of-line-blocking call. Unlike the admission limit
    /// this is *not* clamped above: `usize::MAX` restores the PR 5
    /// drain-all behavior (what `benches/perf_tail_latency.rs` A/B
    /// compares against). Clamped below to 1.
    pub fn with_dispatch_chunk(self, chunk: usize) -> Self {
        {
            let mut st = self.core.lock_state();
            st.dispatch.chunk_limit = chunk.max(1);
        }
        self
    }

    /// Entries pre-loaded from the persistent store (0 without one) —
    /// the warm-start inventory this broker started with.
    pub fn persisted_loaded(&self) -> usize {
        self.core.lock_state().cache.persisted_loaded
    }

    /// Push buffered store appends to disk now (they are also flushed
    /// when the broker drops). No-op without a store.
    pub fn flush_store(&self) {
        if let Some(store) = &mut self.core.lock_state().cache.store {
            store.flush();
        }
    }

    /// Every resident `(key, result)` pair in the memo cache — the
    /// warm inventory a cluster membership join carves its handoff
    /// slice from. Only the state lock is taken (never the backend),
    /// so this is safe to call from *inside* a backend's
    /// `evaluate_batch` — the broker checks its backend out of the
    /// state before dispatching.
    pub fn warm_entries(&self) -> Vec<(Vec<usize>, EvalResult)> {
        let st = self.core.lock_state();
        st.cache.memo.entries().map(|(k, (r, _owner))| (k.to_vec(), *r)).collect()
    }

    /// Open a new search session. Sessions are independent
    /// [`Evaluator`]s with their own zero-based counters; hand each
    /// concurrent search (or search phase) its own.
    pub fn session(&self) -> BrokerSession {
        BrokerSession {
            core: self.core.clone(),
            id: self.next_session.fetch_add(1, Ordering::Relaxed),
            requests: 0,
            evals: 0,
            invalid: 0,
            cross_session_hits: 0,
            persisted_hits: 0,
            inflight_hits: 0,
            dispatched_chunks: 0,
        }
    }

    /// Whole-broker counters (the sum of every session's delta), plus
    /// the backend's pool view (`hosts_down`, `per_host`) so operators
    /// keep per-host attribution when the backend is the cluster tier.
    /// Waits out any dispatch in progress.
    pub fn stats(&self) -> EvalStats {
        let st = self.lock_with_backend();
        let backend = st.dispatch.backend.as_ref().expect("backend parked").stats();
        EvalStats {
            requests: st.cache.requests,
            evals: st.cache.evals,
            cache_hits: st.cache.requests - st.cache.evals,
            invalid: st.cache.invalid,
            cross_session_hits: st.cache.cross_session_hits,
            persisted_hits: st.cache.persisted_hits,
            inflight_hits: st.cache.inflight_hits,
            dispatched_chunks: st.dispatch.dispatches,
            hosts_down: backend.hosts_down,
            per_host: backend.per_host,
        }
    }

    /// The backend's own counters. `backend_stats().requests` equals
    /// `stats().evals`: the backend sees exactly the broker's deduped
    /// misses, nothing else. Waits out any dispatch in progress.
    pub fn backend_stats(&self) -> EvalStats {
        self.lock_with_backend().dispatch.backend.as_ref().expect("backend parked").stats()
    }

    /// How much admission overlap this broker has seen so far.
    pub fn overlap_stats(&self) -> BrokerOverlapStats {
        let st = self.core.lock_state();
        BrokerOverlapStats {
            inflight_limit: st.dispatch.inflight_limit,
            capacity: st.dispatch.capacity,
            dispatches: st.dispatch.dispatches,
            coalesced_dispatches: st.dispatch.coalesced_dispatches,
            peak_admitted: st.dispatch.peak_admitted,
            chunk_limit: st.dispatch.chunk_limit,
            chunked_dispatches: st.dispatch.chunked_dispatches,
            peak_queue_depth: st.dispatch.peak_queue_depth,
        }
    }

    /// One non-blocking observation of the whole broker, for the live
    /// metrics stream. Unlike [`EvalBroker::stats`] this never waits
    /// out an in-flight dispatch: it takes the plain state lock (which
    /// is only ever held for bounded bookkeeping, never across a
    /// backend call) and reads the backend's own counters only if the
    /// backend happens to be parked — [`BrokerSnapshot::backend`] is
    /// `None` mid-dispatch, and the consumer carries the last known
    /// values forward.
    pub fn snapshot(&self) -> BrokerSnapshot {
        let st = self.core.lock_state();
        let backend = st.dispatch.backend.as_ref().map(|b| {
            let stats = b.stats();
            let (wire_tx, wire_rx) = b.wire_bytes();
            BackendSnapshot {
                requests: stats.requests,
                hosts_down: stats.hosts_down,
                per_host: stats.per_host,
                wire_tx,
                wire_rx,
            }
        });
        BrokerSnapshot {
            requests: st.cache.requests,
            evals: st.cache.evals,
            invalid: st.cache.invalid,
            cross_session_hits: st.cache.cross_session_hits,
            persisted_hits: st.cache.persisted_hits,
            inflight_hits: st.cache.inflight_hits,
            persisted_loaded: st.cache.persisted_loaded,
            queue_depth: st.dispatch.queue.len(),
            admitted: st.dispatch.admitted,
            inflight_keys: st.dispatch.inflight.len(),
            dispatches: st.dispatch.dispatches,
            coalesced_dispatches: st.dispatch.coalesced_dispatches,
            chunked_dispatches: st.dispatch.chunked_dispatches,
            peak_queue_depth: st.dispatch.peak_queue_depth,
            peak_admitted: st.dispatch.peak_admitted,
            inflight_limit: st.dispatch.inflight_limit,
            capacity: st.dispatch.capacity,
            chunk_limit: st.dispatch.chunk_limit,
            sessions: st.cache.sessions.values().copied().collect(),
            backend,
        }
    }

    /// Lock the state with the backend parked, waiting out any
    /// dispatch in progress, so the caller can read the backend's own
    /// counters.
    fn lock_with_backend(&self) -> MutexGuard<'_, BrokerState> {
        let mut st = self.core.lock_state();
        while st.dispatch.backend.is_none() {
            if st.dispatch.backend_lost {
                panic!("{BACKEND_LOST}");
            }
            st = self.core.progress.wait(st).expect(POISONED);
        }
        st
    }
}

/// One search's handle onto a shared [`EvalBroker`]. Implements
/// [`Evaluator`], so the batch-structured drivers use it like any
/// other tier; `stats()` reports this session's delta only.
pub struct BrokerSession {
    core: Arc<BrokerCore>,
    id: u64,
    requests: usize,
    evals: usize,
    invalid: usize,
    cross_session_hits: usize,
    persisted_hits: usize,
    inflight_hits: usize,
    /// Backend dispatches this session drove (each dispatch is driven
    /// by exactly one session, so deltas sum to the broker's
    /// `dispatches`).
    dispatched_chunks: usize,
}

impl Evaluator for BrokerSession {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        self.evaluate_batch(&[(nas_d.to_vec(), has_d.to_vec())])[0]
    }

    fn evaluate_batch(&mut self, batch: &[(Vec<usize>, Vec<usize>)]) -> Vec<EvalResult> {
        if batch.is_empty() {
            return Vec::new();
        }
        let core = self.core.clone();
        let keys: Vec<Vec<usize>> = batch.iter().map(|(n, h)| joint_key(n, h)).collect();
        let mut tally = BatchTally::new(batch.len());
        let mut claimed = 0usize;
        let mut admitted_here = false;

        // Step 1 — resolve against the cache tier and in-flight table.
        let mut st = core.lock_state();
        let mut fresh: Vec<usize> = Vec::new();
        for (i, key) in keys.iter().enumerate() {
            let res = st.resolve(key);
            if !tally.absorb(i, res, self.id) {
                fresh.push(i);
            }
        }

        // Step 2 — only *genuinely unknown* keys need an admission
        // slot before they may be claimed; keys that become cached or
        // in-flight while we queue for one are absorbed without it, so
        // a batch never holds out for admission it no longer needs.
        while !fresh.is_empty() {
            if st.dispatch.backend_lost {
                panic!("{BACKEND_LOST}");
            }
            if st.dispatch.admitted >= st.dispatch.inflight_limit {
                st = core.progress.wait(st).expect(POISONED);
                // The world moved while we waited: anything another
                // batch claimed or finished meanwhile resolves here —
                // possibly emptying `fresh` and skipping admission
                // entirely.
                fresh.retain(|&i| {
                    let res = st.resolve(&keys[i]);
                    !tally.absorb(i, res, self.id)
                });
                continue;
            }
            // Admitted: claim everything still unknown, re-resolving
            // as we go (earlier claims of this very batch put
            // in-flight entries in front of duplicate keys).
            for i in std::mem::take(&mut fresh) {
                let res = st.resolve(&keys[i]);
                if tally.absorb(i, res, self.id) {
                    continue;
                }
                if !admitted_here {
                    admitted_here = true;
                    st.dispatch.admitted += 1;
                    st.dispatch.peak_admitted =
                        st.dispatch.peak_admitted.max(st.dispatch.admitted);
                }
                claimed += 1;
                let slot = Arc::new(InflightSlot { owner: self.id, outcome: Mutex::new(None) });
                st.dispatch.inflight.insert(keys[i].clone(), slot.clone());
                st.dispatch.queue.push(QueuedEval {
                    nas_d: batch[i].0.clone(),
                    has_d: batch[i].1.clone(),
                    key: keys[i].clone(),
                    slot: slot.clone(),
                });
                tally.waited.push((i, slot));
            }
        }

        // Step 3 — dispatch or wait until every slot has an outcome.
        // Any session may drive the backend: the queue holds claims
        // from every admitted batch, so whoever dispatches next
        // coalesces them into one backend call — at most a chunk
        // at a time, so early-queued batches complete (and wake)
        // before the whole backlog is through.
        let mut drove = 0usize;
        loop {
            let mut pending = false;
            for (i, slot) in &tally.waited {
                if tally.results[*i].is_none() {
                    match slot.outcome() {
                        Some((r, _cacheable)) => tally.results[*i] = Some(r),
                        None => pending = true,
                    }
                }
            }
            if !pending {
                break;
            }
            if st.dispatch.backend_lost {
                panic!("{BACKEND_LOST}");
            }
            if st.dispatch.backend.is_some() && !st.dispatch.queue.is_empty() {
                drove += 1;
                st = dispatch_chunk(&core, st);
            } else {
                st = core.progress.wait(st).expect(POISONED);
            }
        }

        let results: Vec<EvalResult> =
            tally.results.into_iter().map(|r| r.expect("all batch slots resolved")).collect();
        let invalid = results.iter().filter(|r| !r.valid).count();
        st.cache.requests += batch.len();
        st.cache.evals += claimed;
        st.cache.invalid += invalid;
        st.cache.cross_session_hits += tally.cross;
        st.cache.persisted_hits += tally.persisted;
        st.cache.inflight_hits += tally.inflight_hits;
        // Mirror the same deltas into this session's registry slot
        // under the same lock acquisition, so any snapshot sees the
        // per-session and broker-wide counters in exact agreement.
        let sc = st
            .cache
            .sessions
            .entry(self.id)
            .or_insert_with(|| SessionCounters { id: self.id, ..Default::default() });
        sc.requests += batch.len();
        sc.evals += claimed;
        sc.invalid += invalid;
        sc.cross_session_hits += tally.cross;
        sc.persisted_hits += tally.persisted;
        sc.inflight_hits += tally.inflight_hits;
        sc.dispatched_chunks += drove;
        if admitted_here {
            st.dispatch.admitted -= 1;
        }
        drop(st);
        core.progress.notify_all();

        self.requests += batch.len();
        self.evals += claimed;
        self.invalid += invalid;
        self.cross_session_hits += tally.cross;
        self.persisted_hits += tally.persisted;
        self.inflight_hits += tally.inflight_hits;
        self.dispatched_chunks += drove;
        results
    }

    fn stats(&self) -> EvalStats {
        EvalStats {
            requests: self.requests,
            evals: self.evals,
            cache_hits: self.requests - self.evals,
            invalid: self.invalid,
            cross_session_hits: self.cross_session_hits,
            persisted_hits: self.persisted_hits,
            inflight_hits: self.inflight_hits,
            dispatched_chunks: self.dispatched_chunks,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::has::HasSpace;
    use crate::nas::{NasSpace, NasSpaceId};
    use crate::search::{ParallelSim, SurrogateSim};
    use crate::util::Rng;

    fn random_batch(n: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let has = HasSpace::new();
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect()
    }

    fn sim_backend() -> Box<dyn Evaluator + Send> {
        Box::new(SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3))
    }

    #[test]
    fn sessions_share_the_cross_search_cache() {
        let batch = random_batch(12, 5);
        let broker = EvalBroker::new(sim_backend());
        let mut a = broker.session();
        let mut b = broker.session();
        let ra = a.evaluate_batch(&batch);
        let rb = b.evaluate_batch(&batch);
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.acc.to_bits(), y.acc.to_bits());
            assert_eq!(x.latency_ms.to_bits(), y.latency_ms.to_bits());
        }
        // Session A paid for every key; B rode its cache entries.
        let (sa, sb) = (a.stats(), b.stats());
        assert_eq!(sa.evals, 12);
        assert_eq!(sa.cross_session_hits, 0);
        assert_eq!(sb.evals, 0);
        assert_eq!(sb.cache_hits, 12);
        assert_eq!(sb.cross_session_hits, 12);
        assert_eq!(sb.inflight_hits, 0, "sequential sessions never overlap mid-flight");
        // Against a serial reference: broker values are bit-identical.
        let mut serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
        for ((n, h), r) in batch.iter().zip(&ra) {
            let w = serial.evaluate(n, h);
            assert_eq!(w.acc.to_bits(), r.acc.to_bits());
            assert_eq!(w.latency_ms.to_bits(), r.latency_ms.to_bits());
        }
    }

    #[test]
    fn session_deltas_sum_to_broker_and_backend_counters() {
        // The stats double-counting guard: per-session deltas, merged
        // with `EvalStats::merged`, must equal the broker's global
        // counters, and the broker's misses must equal the backend's
        // requests — one eval is counted exactly once at every layer.
        let backend = ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3, 2);
        let broker = EvalBroker::new(Box::new(backend));
        let mut a = broker.session();
        let mut b = broker.session();
        let shared = random_batch(10, 1);
        let only_b = random_batch(6, 2);
        a.evaluate_batch(&shared);
        b.evaluate_batch(&shared); // all cross-session hits
        b.evaluate_batch(&only_b);
        b.evaluate_batch(&only_b); // all own-session hits

        let merged = a.stats().merged(&b.stats());
        let global = broker.stats();
        assert_eq!(merged.requests, 32);
        assert_eq!(merged.requests, global.requests);
        assert_eq!(merged.evals, global.evals);
        assert_eq!(merged.cache_hits, global.cache_hits);
        assert_eq!(merged.invalid, global.invalid);
        assert_eq!(merged.cross_session_hits, global.cross_session_hits);
        assert_eq!(merged.inflight_hits, global.inflight_hits);
        assert_eq!(merged.evals, 16, "10 + 6 unique keys");
        assert_eq!(merged.cross_session_hits, 10, "only B's replay of A's keys is cross");
        // The backend saw exactly the broker's deduped misses.
        assert_eq!(broker.backend_stats().requests, global.evals);
    }

    #[test]
    fn concurrent_sessions_evaluate_each_unique_key_once() {
        let batch = random_batch(16, 9);
        let broker = EvalBroker::new(sim_backend());
        let results: Vec<Vec<EvalResult>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let mut session = broker.session();
                    let batch = &batch;
                    s.spawn(move || session.evaluate_batch(batch))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("session thread panicked")).collect()
        });
        for r in &results[1..] {
            for (x, y) in results[0].iter().zip(r) {
                assert_eq!(x.acc.to_bits(), y.acc.to_bits());
            }
        }
        let g = broker.stats();
        assert_eq!(g.requests, 64);
        assert_eq!(g.evals, 16, "each unique key evaluated exactly once");
        // Whichever session won the race paid; the other three hit —
        // via the cache or by waiting on the keys mid-flight.
        assert_eq!(g.cross_session_hits, 48);
        assert!(g.inflight_hits <= g.cross_session_hits);
        assert_eq!(broker.backend_stats().requests, 16);
    }

    #[test]
    fn inflight_limit_clamps_to_backend_capacity() {
        // parallel advertises its worker count; the flag can narrow
        // but never exceed it.
        let backend = ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3, 4);
        let broker = EvalBroker::new(Box::new(backend));
        assert_eq!(broker.overlap_stats().capacity, 4);
        assert_eq!(broker.overlap_stats().inflight_limit, 4, "defaults to capacity");
        let broker = broker.with_inflight_limit(64);
        assert_eq!(broker.overlap_stats().inflight_limit, 4, "clamped to capacity");
        let broker = broker.with_inflight_limit(2);
        assert_eq!(broker.overlap_stats().inflight_limit, 2);
        // local advertises 1: the serial path is untouched whatever
        // the flag says.
        let serial = EvalBroker::new(sim_backend()).with_inflight_limit(16);
        assert_eq!(serial.overlap_stats().capacity, 1);
        assert_eq!(serial.overlap_stats().inflight_limit, 1);
    }

    #[test]
    fn dispatch_chunk_defaults_to_capacity_and_streams_long_queues() {
        let backend = ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3, 4);
        let broker = EvalBroker::new(Box::new(backend));
        assert_eq!(broker.overlap_stats().chunk_limit, 4, "defaults to capacity");
        let broker = broker.with_dispatch_chunk(0);
        assert_eq!(broker.overlap_stats().chunk_limit, 1, "clamped below to 1");
        let broker = broker.with_dispatch_chunk(usize::MAX);
        assert_eq!(
            broker.overlap_stats().chunk_limit,
            usize::MAX,
            "drain-all stays available for A/B runs"
        );

        // A 12-key batch over a chunk-2 broker streams out in 6 FIFO
        // dispatches, bit-identical to the serial reference.
        let batch = random_batch(12, 11);
        let broker = EvalBroker::new(sim_backend()).with_dispatch_chunk(2);
        let mut s = broker.session();
        let got = s.evaluate_batch(&batch);
        let ov = broker.overlap_stats();
        assert_eq!(ov.dispatches, 6);
        assert_eq!(ov.chunked_dispatches, 5, "every dispatch but the last left work behind");
        assert_eq!(ov.peak_queue_depth, 12);
        assert_eq!(s.stats().dispatched_chunks, 6, "the lone session drove every chunk");
        assert_eq!(broker.stats().dispatched_chunks, 6);
        let serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
        for ((n, h), r) in batch.iter().zip(&got) {
            let w = serial.evaluate_pure(n, h);
            assert_eq!(w.acc.to_bits(), r.acc.to_bits());
            assert_eq!(w.latency_ms.to_bits(), r.latency_ms.to_bits());
        }
    }

    /// Backend that fails the first call to every key (uncacheable
    /// invalid), succeeding afterwards — a restartable transport.
    struct Flaky {
        seen: std::collections::HashSet<Vec<usize>>,
    }

    impl Evaluator for Flaky {
        fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
            if self.seen.insert(joint_key(nas_d, has_d)) {
                EvalResult::invalid()
            } else {
                EvalResult { acc: 0.7, valid: true, ..Default::default() }
            }
        }

        fn evaluate_batch_tagged(
            &mut self,
            batch: &[(Vec<usize>, Vec<usize>)],
        ) -> Vec<(EvalResult, bool)> {
            batch
                .iter()
                .map(|(n, h)| {
                    let r = self.evaluate(n, h);
                    (r, r.valid)
                })
                .collect()
        }
    }

    #[test]
    fn store_backed_broker_warm_starts_and_spills() {
        let path = std::env::temp_dir()
            .join(format!("nahas-broker-warm-{}.cache", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let fp = "eval/unit-test-fp";
        let batch = random_batch(10, 3);

        // Cold run: every key is a backend eval, spilled to the store.
        {
            let store = CacheStore::open(&path, fp).unwrap();
            let broker = EvalBroker::with_store(sim_backend(), store);
            assert_eq!(broker.persisted_loaded(), 0);
            let mut s = broker.session();
            s.evaluate_batch(&batch);
            let g = broker.stats();
            assert_eq!((g.evals, g.persisted_hits), (10, 0));
        } // Broker drop flushes the store.

        // Warm run: fresh backend, fresh broker, same file — every
        // request is a persisted hit, the backend is never touched,
        // and the values are bit-identical to a serial reference.
        let store = CacheStore::open(&path, fp).unwrap();
        let broker = EvalBroker::with_store(sim_backend(), store);
        assert_eq!(broker.persisted_loaded(), 10);
        let mut s = broker.session();
        let got = s.evaluate_batch(&batch);
        let g = broker.stats();
        assert_eq!(g.evals, 0, "fully warm: no backend evals");
        assert_eq!(g.persisted_hits, 10);
        assert_eq!(g.cross_session_hits, 0, "warm hits are not cross-session hits");
        assert_eq!(broker.backend_stats().requests, 0);
        let serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
        for ((n, h), r) in batch.iter().zip(&got) {
            let w = serial.evaluate_pure(n, h);
            assert_eq!(w.acc.to_bits(), r.acc.to_bits());
            assert_eq!(w.latency_ms.to_bits(), r.latency_ms.to_bits());
        }
        // A re-served persisted key is not appended again.
        drop(s);
        drop(broker);
        let mut reopened: CacheStore = CacheStore::open(&path, fp).unwrap();
        assert_eq!(reopened.take_loaded().len(), 10);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn transport_failures_are_not_memoized_across_sessions() {
        let broker =
            EvalBroker::new(Box::new(Flaky { seen: std::collections::HashSet::new() }));
        let mut a = broker.session();
        let mut b = broker.session();
        let batch = vec![(vec![1, 2], vec![3, 4])];
        assert!(!a.evaluate_batch(&batch)[0].valid, "first attempt fails");
        // The failure was not cached — and its in-flight entry is
        // gone: B's request retries the backend and succeeds; only
        // now is the key memoized.
        assert!(b.evaluate_batch(&batch)[0].valid, "retry reaches the backend");
        assert!(a.evaluate_batch(&batch)[0].valid, "success is memoized");
        let g = broker.stats();
        assert_eq!(g.evals, 2, "failed attempt + retry; third request was a hit");
        assert_eq!(g.cross_session_hits, 1, "A re-read B's memoized success");
    }
}
