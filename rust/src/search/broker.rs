//! [`EvalBroker`] — the shared, concurrency-safe evaluation seam.
//!
//! PR 1/PR 2 built four evaluator tiers (local, parallel, service,
//! cluster), but every search driver *exclusively borrowed* its
//! evaluator (`&mut dyn Evaluator`), so a multi-target sweep — the
//! paper's headline figures are built from sweeps of searches — ran
//! serially and could not share the worker pool, service farm, or memo
//! cache between scenarios. The broker removes that restriction:
//!
//! * [`EvalBroker`] wraps **one** backend (`Box<dyn Evaluator + Send>`)
//!   behind an `Arc<Mutex<..>>` and hands out any number of
//!   [`BrokerSession`] handles;
//! * each session implements [`Evaluator`], so every existing driver
//!   ([`crate::search::joint_search`],
//!   [`crate::search::phase::phase_search`]) runs unchanged on its own
//!   thread — N concurrent searches multiplex onto the one backend;
//! * a **cross-search memo cache** keyed on the joint decision vector
//!   sits in front of the backend: a (alpha, h) point discovered by one
//!   scenario is evaluated once, ever — later scenarios hit the cache
//!   (counted as [`EvalStats::cross_session_hits`]);
//! * sessions keep **per-session counter deltas**, and the broker keeps
//!   the global sum, so a sweep can report both per-scenario and
//!   whole-run throughput without double counting (the invariant
//!   "session deltas sum to the broker, broker misses equal backend
//!   requests" is pinned by tests below);
//! * optionally a persistent [`CacheStore`] backs the cache
//!   ([`EvalBroker::with_store`], CLI `--cache-dir`): entries spilled
//!   by an earlier run pre-load at open (hits on them count as
//!   [`EvalStats::persisted_hits`]) and every cacheable fresh
//!   evaluation is appended back, so repeated runs and sweeps
//!   warm-start across processes (`tests/cache_persistence.rs`).
//!
//! Concurrency model: one mutex guards the backend + cache + global
//! counters, and a session's whole `evaluate_batch` (cache resolve →
//! backend fan-out → cache fill) runs under it. Batches from
//! concurrent sessions therefore *interleave* rather than overlap —
//! which is deliberate: the parallelism lives inside the backend's own
//! `evaluate_batch` fan-out (worker threads, service connections,
//! cluster shards), and admitting one batch at a time is what makes
//! "every unique key is evaluated exactly once" a hard guarantee
//! instead of a race. Because every backend evaluation is a
//! deterministic function of (space, task, seed, decisions), sharing a
//! broker can never change *what* a scenario computes — each scenario
//! stays bit-identical to its standalone run for the same controller
//! seed (`tests/sweep_equivalence.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::search::evaluator::{EvalResult, EvalStats, Evaluator};
use crate::search::parallel::{joint_key, MemoCache};
use crate::search::store::CacheStore;

/// Cache-entry owner id reserved for entries loaded from a persistent
/// [`CacheStore`]: hits on them are warm-start hits
/// ([`EvalStats::persisted_hits`]), not cross-session ones. Session
/// ids count up from 0 and can never collide with it.
const PERSISTED_OWNER: u64 = u64::MAX;

/// Default capacity of the cross-search cache: sized for a whole sweep
/// (several searches of a few thousand samples each), not one search.
///
/// The caching backends (`ParallelSim`, `ServiceEvaluator`,
/// `ShardedEvaluator`) keep their own memo cache behind this one; under
/// a broker it sees only deduped misses and stays mostly cold. That
/// redundancy is deliberate: the backends are also used standalone
/// (tests, benches, library callers), the duplicated residency is
/// bounded, and the cluster tier still needs its own front to keep
/// failover results out of *its* cache independently of the broker.
const BROKER_CACHE_CAPACITY: usize = 64 * 1024;

/// Everything the broker mutex guards: the backend, the cross-search
/// cache (values carry the id of the session that paid for them, so
/// cross-session hits can be told apart from a session re-hitting its
/// own keys), and the global counters.
struct BrokerCore {
    backend: Box<dyn Evaluator + Send>,
    cache: MemoCache<(EvalResult, u64)>,
    /// Cross-run persistence: pre-loaded into `cache` at open (owner
    /// [`PERSISTED_OWNER`]), appended to on every cacheable fresh
    /// evaluation, flushed when the broker drops.
    store: Option<CacheStore>,
    /// Entries the store loaded at open (the warm-start inventory).
    persisted_loaded: usize,
    requests: usize,
    evals: usize,
    invalid: usize,
    cross_session_hits: usize,
    persisted_hits: usize,
}

/// What one admitted batch did, for the session's own bookkeeping.
struct BatchReceipt {
    results: Vec<EvalResult>,
    evals: usize,
    invalid: usize,
    cross_session_hits: usize,
    persisted_hits: usize,
}

impl BrokerCore {
    /// Admit one session batch: resolve cross-search cache hits, dedup
    /// the misses (first-seen order, exactly like the per-evaluator
    /// `BatchPlan`), evaluate them in one backend call, memoize the
    /// cacheable results, and reassemble in batch order.
    fn run(&mut self, session: u64, batch: &[(Vec<usize>, Vec<usize>)]) -> BatchReceipt {
        self.requests += batch.len();
        let mut results: Vec<Option<EvalResult>> = vec![None; batch.len()];
        let mut cross = 0usize;
        let mut persisted = 0usize;
        // Deduped misses: (first batch slot, joint key), first-seen order.
        let mut pending: Vec<(usize, Vec<usize>)> = Vec::new();
        let mut waiting: HashMap<Vec<usize>, Vec<usize>> = HashMap::new();
        for (i, (nas_d, has_d)) in batch.iter().enumerate() {
            let key = joint_key(nas_d, has_d);
            if let Some((r, owner)) = self.cache.get(&key) {
                if owner == PERSISTED_OWNER {
                    persisted += 1;
                } else if owner != session {
                    cross += 1;
                }
                results[i] = Some(r);
            } else {
                let slots = waiting.entry(key.clone()).or_default();
                if slots.is_empty() {
                    pending.push((i, key));
                }
                slots.push(i);
            }
        }
        let evals = pending.len();
        if evals > 0 {
            let misses: Vec<(Vec<usize>, Vec<usize>)> =
                pending.iter().map(|(i, _)| batch[*i].clone()).collect();
            let fresh = self.backend.evaluate_batch_tagged(&misses);
            assert_eq!(fresh.len(), evals, "backend must preserve batch length");
            for ((_, key), (r, cacheable)) in pending.into_iter().zip(fresh) {
                for &slot in &waiting[&key] {
                    results[slot] = Some(r);
                }
                // A transient transport failure must not be memoized —
                // and, a fortiori, must never reach the persistent
                // store: a later resample (from any session, or a
                // whole later run) has to retry it.
                if cacheable {
                    if let Some(store) = &mut self.store {
                        store.append(&key, &r);
                    }
                    self.cache.insert(key, (r, session));
                }
            }
        }
        let results: Vec<EvalResult> =
            results.into_iter().map(|r| r.expect("all batch slots resolved")).collect();
        let invalid = results.iter().filter(|r| !r.valid).count();
        self.evals += evals;
        self.invalid += invalid;
        self.cross_session_hits += cross;
        self.persisted_hits += persisted;
        BatchReceipt {
            results,
            evals,
            invalid,
            cross_session_hits: cross,
            persisted_hits: persisted,
        }
    }

    fn stats(&self) -> EvalStats {
        let backend = self.backend.stats();
        EvalStats {
            requests: self.requests,
            evals: self.evals,
            cache_hits: self.requests - self.evals,
            invalid: self.invalid,
            cross_session_hits: self.cross_session_hits,
            persisted_hits: self.persisted_hits,
            hosts_down: backend.hosts_down,
            per_host: backend.per_host,
        }
    }
}

/// Shared handle to one evaluation backend. Cheap to clone; create one
/// [`BrokerSession`] per concurrent search with [`EvalBroker::session`].
#[derive(Clone)]
pub struct EvalBroker {
    core: Arc<Mutex<BrokerCore>>,
    next_session: Arc<AtomicU64>,
}

impl EvalBroker {
    /// Wrap a backend. Any [`Evaluator`] tier works — `SurrogateSim`
    /// (local), `ParallelSim`, `ServiceEvaluator`, `ShardedEvaluator` —
    /// as long as it evaluates a sample as a pure function of its
    /// decisions, which is the contract every tier already pins in
    /// `tests/parallel_equivalence.rs`.
    pub fn new(backend: Box<dyn Evaluator + Send>) -> Self {
        Self::build(backend, None)
    }

    /// Wrap a backend with a persistent [`CacheStore`] behind the
    /// cross-search cache (`--cache-dir`): entries the store loaded
    /// are served as [`EvalStats::persisted_hits`]; every cacheable
    /// fresh evaluation is appended back, and the file is flushed when
    /// the broker drops. The store must have been opened with the
    /// fingerprint of this broker's evaluation context
    /// ([`crate::search::store::eval_fingerprint`]) — the fingerprint,
    /// not the caller, is what makes replaying an entry sound.
    pub fn with_store(backend: Box<dyn Evaluator + Send>, store: CacheStore) -> Self {
        Self::build(backend, Some(store))
    }

    fn build(backend: Box<dyn Evaluator + Send>, mut store: Option<CacheStore>) -> Self {
        let loaded = store.as_mut().map(|s| s.take_loaded()).unwrap_or_default();
        let persisted_loaded = loaded.len();
        // The whole warm inventory must be resident: "a fully-warm run
        // performs zero backend evals" only holds if no persisted entry
        // is evicted before it is re-requested, so a file that outgrew
        // the default capacity sizes the cache up to fit it.
        let mut cache = MemoCache::new(BROKER_CACHE_CAPACITY.max(persisted_loaded));
        for (key, r) in loaded {
            cache.insert(key, (r, PERSISTED_OWNER));
        }
        EvalBroker {
            core: Arc::new(Mutex::new(BrokerCore {
                backend,
                cache,
                store,
                persisted_loaded,
                requests: 0,
                evals: 0,
                invalid: 0,
                cross_session_hits: 0,
                persisted_hits: 0,
            })),
            next_session: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Entries pre-loaded from the persistent store (0 without one) —
    /// the warm-start inventory this broker started with.
    pub fn persisted_loaded(&self) -> usize {
        self.lock().persisted_loaded
    }

    /// Push buffered store appends to disk now (they are also flushed
    /// when the broker drops). No-op without a store.
    pub fn flush_store(&self) {
        if let Some(store) = &mut self.lock().store {
            store.flush();
        }
    }

    /// Open a new search session. Sessions are independent
    /// [`Evaluator`]s with their own zero-based counters; hand each
    /// concurrent search (or search phase) its own.
    pub fn session(&self) -> BrokerSession {
        BrokerSession {
            core: self.core.clone(),
            id: self.next_session.fetch_add(1, Ordering::Relaxed),
            requests: 0,
            evals: 0,
            invalid: 0,
            cross_session_hits: 0,
            persisted_hits: 0,
        }
    }

    /// Whole-broker counters (the sum of every session's delta), plus
    /// the backend's pool view (`hosts_down`, `per_host`) so operators
    /// keep per-host attribution when the backend is the cluster tier.
    pub fn stats(&self) -> EvalStats {
        self.lock().stats()
    }

    /// The backend's own counters. `backend_stats().requests` equals
    /// `stats().evals`: the backend sees exactly the broker's deduped
    /// misses, nothing else.
    pub fn backend_stats(&self) -> EvalStats {
        self.lock().backend.stats()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BrokerCore> {
        // A poisoned lock means a backend panicked mid-batch; there is
        // no sane way to continue the sweep, so propagate.
        self.core.lock().expect("evaluation broker poisoned by a panicked backend")
    }
}

/// One search's handle onto a shared [`EvalBroker`]. Implements
/// [`Evaluator`], so the batch-structured drivers use it like any
/// other tier; `stats()` reports this session's delta only.
pub struct BrokerSession {
    core: Arc<Mutex<BrokerCore>>,
    id: u64,
    requests: usize,
    evals: usize,
    invalid: usize,
    cross_session_hits: usize,
    persisted_hits: usize,
}

impl Evaluator for BrokerSession {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        self.evaluate_batch(&[(nas_d.to_vec(), has_d.to_vec())])[0]
    }

    fn evaluate_batch(&mut self, batch: &[(Vec<usize>, Vec<usize>)]) -> Vec<EvalResult> {
        if batch.is_empty() {
            return Vec::new();
        }
        let receipt = self
            .core
            .lock()
            .expect("evaluation broker poisoned by a panicked backend")
            .run(self.id, batch);
        self.requests += batch.len();
        self.evals += receipt.evals;
        self.invalid += receipt.invalid;
        self.cross_session_hits += receipt.cross_session_hits;
        self.persisted_hits += receipt.persisted_hits;
        receipt.results
    }

    fn stats(&self) -> EvalStats {
        EvalStats {
            requests: self.requests,
            evals: self.evals,
            cache_hits: self.requests - self.evals,
            invalid: self.invalid,
            cross_session_hits: self.cross_session_hits,
            persisted_hits: self.persisted_hits,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::has::HasSpace;
    use crate::nas::{NasSpace, NasSpaceId};
    use crate::search::{ParallelSim, SurrogateSim};
    use crate::util::Rng;

    fn random_batch(n: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let has = HasSpace::new();
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect()
    }

    fn sim_backend() -> Box<dyn Evaluator + Send> {
        Box::new(SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3))
    }

    #[test]
    fn sessions_share_the_cross_search_cache() {
        let batch = random_batch(12, 5);
        let broker = EvalBroker::new(sim_backend());
        let mut a = broker.session();
        let mut b = broker.session();
        let ra = a.evaluate_batch(&batch);
        let rb = b.evaluate_batch(&batch);
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.acc.to_bits(), y.acc.to_bits());
            assert_eq!(x.latency_ms.to_bits(), y.latency_ms.to_bits());
        }
        // Session A paid for every key; B rode its cache entries.
        let (sa, sb) = (a.stats(), b.stats());
        assert_eq!(sa.evals, 12);
        assert_eq!(sa.cross_session_hits, 0);
        assert_eq!(sb.evals, 0);
        assert_eq!(sb.cache_hits, 12);
        assert_eq!(sb.cross_session_hits, 12);
        // Against a serial reference: broker values are bit-identical.
        let mut serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
        for ((n, h), r) in batch.iter().zip(&ra) {
            let w = serial.evaluate(n, h);
            assert_eq!(w.acc.to_bits(), r.acc.to_bits());
            assert_eq!(w.latency_ms.to_bits(), r.latency_ms.to_bits());
        }
    }

    #[test]
    fn session_deltas_sum_to_broker_and_backend_counters() {
        // The stats double-counting guard: per-session deltas, merged
        // with `EvalStats::merged`, must equal the broker's global
        // counters, and the broker's misses must equal the backend's
        // requests — one eval is counted exactly once at every layer.
        let backend = ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3, 2);
        let broker = EvalBroker::new(Box::new(backend));
        let mut a = broker.session();
        let mut b = broker.session();
        let shared = random_batch(10, 1);
        let only_b = random_batch(6, 2);
        a.evaluate_batch(&shared);
        b.evaluate_batch(&shared); // all cross-session hits
        b.evaluate_batch(&only_b);
        b.evaluate_batch(&only_b); // all own-session hits

        let merged = a.stats().merged(&b.stats());
        let global = broker.stats();
        assert_eq!(merged.requests, 32);
        assert_eq!(merged.requests, global.requests);
        assert_eq!(merged.evals, global.evals);
        assert_eq!(merged.cache_hits, global.cache_hits);
        assert_eq!(merged.invalid, global.invalid);
        assert_eq!(merged.cross_session_hits, global.cross_session_hits);
        assert_eq!(merged.evals, 16, "10 + 6 unique keys");
        assert_eq!(merged.cross_session_hits, 10, "only B's replay of A's keys is cross");
        // The backend saw exactly the broker's deduped misses.
        assert_eq!(broker.backend_stats().requests, global.evals);
    }

    #[test]
    fn concurrent_sessions_evaluate_each_unique_key_once() {
        let batch = random_batch(16, 9);
        let broker = EvalBroker::new(sim_backend());
        let results: Vec<Vec<EvalResult>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let mut session = broker.session();
                    let batch = &batch;
                    s.spawn(move || session.evaluate_batch(batch))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("session thread panicked")).collect()
        });
        for r in &results[1..] {
            for (x, y) in results[0].iter().zip(r) {
                assert_eq!(x.acc.to_bits(), y.acc.to_bits());
            }
        }
        let g = broker.stats();
        assert_eq!(g.requests, 64);
        assert_eq!(g.evals, 16, "each unique key evaluated exactly once");
        // Whichever session won the race paid; the other three hit.
        assert_eq!(g.cross_session_hits, 48);
        assert_eq!(broker.backend_stats().requests, 16);
    }

    /// Backend that fails the first call to every key (uncacheable
    /// invalid), succeeding afterwards — a restartable transport.
    struct Flaky {
        seen: std::collections::HashSet<Vec<usize>>,
    }

    impl Evaluator for Flaky {
        fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
            if self.seen.insert(joint_key(nas_d, has_d)) {
                EvalResult::invalid()
            } else {
                EvalResult { acc: 0.7, valid: true, ..Default::default() }
            }
        }

        fn evaluate_batch_tagged(
            &mut self,
            batch: &[(Vec<usize>, Vec<usize>)],
        ) -> Vec<(EvalResult, bool)> {
            batch
                .iter()
                .map(|(n, h)| {
                    let r = self.evaluate(n, h);
                    (r, r.valid)
                })
                .collect()
        }
    }

    #[test]
    fn store_backed_broker_warm_starts_and_spills() {
        let path = std::env::temp_dir()
            .join(format!("nahas-broker-warm-{}.cache", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let fp = "eval/unit-test-fp";
        let batch = random_batch(10, 3);

        // Cold run: every key is a backend eval, spilled to the store.
        {
            let store = CacheStore::open(&path, fp).unwrap();
            let broker = EvalBroker::with_store(sim_backend(), store);
            assert_eq!(broker.persisted_loaded(), 0);
            let mut s = broker.session();
            s.evaluate_batch(&batch);
            let g = broker.stats();
            assert_eq!((g.evals, g.persisted_hits), (10, 0));
        } // Broker drop flushes the store.

        // Warm run: fresh backend, fresh broker, same file — every
        // request is a persisted hit, the backend is never touched,
        // and the values are bit-identical to a serial reference.
        let store = CacheStore::open(&path, fp).unwrap();
        let broker = EvalBroker::with_store(sim_backend(), store);
        assert_eq!(broker.persisted_loaded(), 10);
        let mut s = broker.session();
        let got = s.evaluate_batch(&batch);
        let g = broker.stats();
        assert_eq!(g.evals, 0, "fully warm: no backend evals");
        assert_eq!(g.persisted_hits, 10);
        assert_eq!(g.cross_session_hits, 0, "warm hits are not cross-session hits");
        assert_eq!(broker.backend_stats().requests, 0);
        let serial = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
        for ((n, h), r) in batch.iter().zip(&got) {
            let w = serial.evaluate_pure(n, h);
            assert_eq!(w.acc.to_bits(), r.acc.to_bits());
            assert_eq!(w.latency_ms.to_bits(), r.latency_ms.to_bits());
        }
        // A re-served persisted key is not appended again.
        drop(s);
        drop(broker);
        let mut reopened: CacheStore = CacheStore::open(&path, fp).unwrap();
        assert_eq!(reopened.take_loaded().len(), 10);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn transport_failures_are_not_memoized_across_sessions() {
        let broker =
            EvalBroker::new(Box::new(Flaky { seen: std::collections::HashSet::new() }));
        let mut a = broker.session();
        let mut b = broker.session();
        let batch = vec![(vec![1, 2], vec![3, 4])];
        assert!(!a.evaluate_batch(&batch)[0].valid, "first attempt fails");
        // The failure was not cached: B's request retries the backend
        // and succeeds; only now is the key memoized.
        assert!(b.evaluate_batch(&batch)[0].valid, "retry reaches the backend");
        assert!(a.evaluate_batch(&batch)[0].valid, "success is memoized");
        let g = broker.stats();
        assert_eq!(g.evals, 2, "failed attempt + retry; third request was a hit");
        assert_eq!(g.cross_session_hits, 1, "A re-read B's memoized success");
    }
}
