//! Multi-task co-design: one accelerator serving several model/task
//! pairs (the paper's third observation — "different use cases lead to
//! very different search outcomes" — taken to its logical end: a
//! single hardware configuration jointly scored across use cases).
//!
//! The controller still samples one joint NAS ++ HAS vector per trial.
//! The shared backbone architecture and the shared hardware half are
//! then evaluated once *per task* — the broker sees task-tagged keys
//! `[task_idx] ++ nas_d`, so per-task results memoize independently —
//! and the per-task rewards fold into one scalar (the mean) for the
//! controller. Per-task results are kept so the sweep can report one
//! Pareto frontier per task next to the folded scenario frontier.

use std::time::Instant;

use crate::nas::{NasSpace, NasSpaceId};
use crate::search::evaluator::{EvalResult, EvalStats, Evaluator, SurrogateSim, Task};
use crate::search::joint::{JointLayout, Sample, SearchCfg, SearchOutcome};
use crate::search::parallel::ParallelSim;
use crate::search::reward::RewardCfg;
use crate::search::Controller;
use crate::util::Rng;

/// One task inside a multi-task scenario: a name for reporting, the
/// evaluation task (which network variant the simulator scores), and
/// the per-task reward/constraint configuration.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: String,
    pub task: Task,
    pub reward: RewardCfg,
}

impl TaskSpec {
    pub fn new(name: impl Into<String>, task: Task, reward: RewardCfg) -> Self {
        TaskSpec { name: name.into(), task, reward }
    }
}

/// Evaluator backend for multi-task scenarios: one inner evaluator per
/// task, dispatched on a task-index prefix.
///
/// Keys are `[task_idx] ++ nas_d` with the hardware half unchanged, so
/// a multi-task key can never collide with a single-task key of the
/// same space (lengths differ by one) and the broker's memo / in-flight
/// dedup / persisted-cache machinery work per (task, architecture,
/// hardware) triple with no changes.
pub struct MultiTaskEval {
    inners: Vec<Box<dyn Evaluator + Send>>,
}

impl MultiTaskEval {
    pub fn new(inners: Vec<Box<dyn Evaluator + Send>>) -> Self {
        assert!(!inners.is_empty(), "MultiTaskEval needs at least one task evaluator");
        MultiTaskEval { inners }
    }

    /// Surrogate-simulator backend for `tasks`: per task, a
    /// [`ParallelSim`] when `workers > 1` (else a [`SurrogateSim`]),
    /// switched to the segmentation network variant where the task
    /// asks for it. All inners share `eval_seed` so each task's
    /// accuracy surrogate is the same function a single-task run of
    /// that task would see.
    pub fn surrogate(tasks: &[TaskSpec], space: NasSpaceId, eval_seed: u64, workers: usize) -> Self {
        let inners = tasks
            .iter()
            .map(|t| {
                let inner: Box<dyn Evaluator + Send> = if workers > 1 {
                    let mut sim = ParallelSim::new(NasSpace::new(space), eval_seed, workers);
                    if t.task == Task::Segmentation {
                        sim = sim.segmentation();
                    }
                    Box::new(sim)
                } else {
                    let mut sim = SurrogateSim::new(NasSpace::new(space), eval_seed);
                    if t.task == Task::Segmentation {
                        sim = sim.segmentation();
                    }
                    Box::new(sim)
                };
                inner
            })
            .collect();
        MultiTaskEval::new(inners)
    }

    pub fn num_tasks(&self) -> usize {
        self.inners.len()
    }

    fn task_of(&self, nas_d: &[usize]) -> usize {
        assert!(
            !nas_d.is_empty() && nas_d[0] < self.inners.len(),
            "multi-task key must start with a task index < {}",
            self.inners.len()
        );
        nas_d[0]
    }
}

impl Evaluator for MultiTaskEval {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        let t = self.task_of(nas_d);
        self.inners[t].evaluate(&nas_d[1..], has_d)
    }

    fn evaluate_batch(&mut self, batch: &[(Vec<usize>, Vec<usize>)]) -> Vec<EvalResult> {
        // Partition by task so each inner evaluator sees one batch (and
        // a parallel inner fans it out), then scatter back in order.
        let mut per_task: Vec<Vec<(Vec<usize>, Vec<usize>)>> =
            vec![Vec::new(); self.inners.len()];
        let mut slots: Vec<Vec<usize>> = vec![Vec::new(); self.inners.len()];
        for (i, (nas_d, has_d)) in batch.iter().enumerate() {
            let t = self.task_of(nas_d);
            per_task[t].push((nas_d[1..].to_vec(), has_d.clone()));
            slots[t].push(i);
        }
        let mut out = vec![EvalResult::invalid(); batch.len()];
        for (t, chunk) in per_task.into_iter().enumerate() {
            if chunk.is_empty() {
                continue;
            }
            let results = self.inners[t].evaluate_batch(&chunk);
            assert_eq!(results.len(), chunk.len(), "inner evaluate_batch must preserve length");
            for (slot, r) in slots[t].iter().zip(results) {
                out[*slot] = r;
            }
        }
        out
    }

    fn stats(&self) -> EvalStats {
        self.inners.iter().fold(EvalStats::default(), |acc, e| acc.merged(&e.stats()))
    }

    fn capacity(&self) -> usize {
        self.inners.iter().map(|e| e.capacity()).max().unwrap_or(1)
    }
}

/// A finished multi-task search: the folded trajectory plus the
/// per-task raw results behind it.
#[derive(Debug, Default)]
pub struct MultiTaskOutcome {
    /// Folded trajectory: each [`Sample`]'s result averages the
    /// per-task metrics (shared-hardware area is common to all tasks)
    /// and its reward is the mean of the per-task rewards.
    pub search: SearchOutcome,
    /// Per task (input order): every *valid* per-task evaluation as
    /// (sample index, result) — the raw material for per-task
    /// frontiers.
    pub per_task: Vec<Vec<(usize, EvalResult)>>,
}

/// Run a multi-trial multi-task joint search: one controller over the
/// full NAS ++ HAS vector, each sample expanded into one task-tagged
/// evaluation per task. Batch-structured exactly like
/// [`crate::search::joint::joint_search`] (sample the whole batch from
/// the current policy, evaluate in one `evaluate_batch` call, reward
/// and update in sample order), so trajectories are bit-identical for
/// a given seed whatever the evaluator tier or cache state.
pub fn multi_task_search(
    evaluator: &mut dyn Evaluator,
    controller: &mut dyn Controller,
    layout: &JointLayout,
    tasks: &[TaskSpec],
    cfg: &SearchCfg,
) -> MultiTaskOutcome {
    assert!(!tasks.is_empty(), "multi-task search needs at least one task");
    let t0 = Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let mut outcome = MultiTaskOutcome {
        search: SearchOutcome::default(),
        per_task: vec![Vec::new(); tasks.len()],
    };
    let n_tasks = tasks.len();
    let batch_size = cfg.batch.max(1);
    let stats_at_start = evaluator.stats();

    let mut index = 0;
    while index < cfg.samples {
        let n = batch_size.min(cfg.samples - index);
        // 1. Sample the whole batch from the current policy.
        let mut frees: Vec<Vec<usize>> = Vec::with_capacity(n);
        let mut pairs: Vec<(Vec<usize>, Vec<usize>)> = Vec::with_capacity(n * n_tasks);
        for _ in 0..n {
            let free = controller.sample(&mut rng);
            let (nas_d, has_d) = layout.split(&free);
            for t in 0..n_tasks {
                let mut key = Vec::with_capacity(nas_d.len() + 1);
                key.push(t);
                key.extend_from_slice(nas_d);
                pairs.push((key, has_d.to_vec()));
            }
            frees.push(free);
        }
        // 2. One evaluate_batch over all (sample x task) pairs.
        let results = evaluator.evaluate_batch(&pairs);
        assert_eq!(results.len(), n * n_tasks, "evaluate_batch must preserve batch length");
        // 3. Fold per-task rewards, record, one controller update.
        let mut batch: Vec<(Vec<usize>, f64)> = Vec::with_capacity(n);
        for i in 0..n {
            let free = std::mem::take(&mut frees[i]);
            let task_results = &results[i * n_tasks..(i + 1) * n_tasks];
            let (nas_d, has_d) = layout.split(&free);
            let mut reward_sum = 0.0;
            let mut acc = 0.0;
            let mut lat = 0.0;
            let mut energy = 0.0;
            let mut area = 0.0;
            let mut valid = true;
            for (t, r) in task_results.iter().enumerate() {
                reward_sum += tasks[t].reward.reward(r);
                acc += r.acc;
                lat += r.latency_ms;
                energy += r.energy_mj;
                area = area.max(r.area_mm2);
                valid &= r.valid;
                if r.valid {
                    outcome.per_task[t].push((index + i, *r));
                }
            }
            let k = n_tasks as f64;
            let reward = reward_sum / k;
            let folded = if valid {
                EvalResult {
                    acc: acc / k,
                    latency_ms: lat / k,
                    energy_mj: energy / k,
                    area_mm2: area,
                    valid: true,
                }
            } else {
                EvalResult::invalid()
            };
            let feasible = valid
                && task_results.iter().zip(tasks).all(|(r, t)| t.reward.feasible(r));
            let sample = Sample {
                index: index + i,
                nas_d: nas_d.to_vec(),
                has_d: has_d.to_vec(),
                result: folded,
                reward,
            };
            if !sample.result.valid {
                outcome.search.num_invalid += 1;
            }
            if outcome.search.best.as_ref().map(|b| reward > b.reward).unwrap_or(true) {
                outcome.search.best = Some(sample.clone());
            }
            if feasible
                && outcome
                    .search
                    .best_feasible
                    .as_ref()
                    .map(|b| sample.result.acc > b.result.acc)
                    .unwrap_or(true)
            {
                outcome.search.best_feasible = Some(sample.clone());
            }
            if cfg.keep_history {
                outcome.search.history.push(sample);
            }
            batch.push((free, reward));
        }
        controller.update(&batch);
        index += n;
    }
    outcome.search.eval_stats = evaluator.stats().since(&stats_at_start);
    outcome.search.elapsed_s = t0.elapsed().as_secs_f64();
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::has::HasSpace;
    use crate::search::broker::EvalBroker;
    use crate::search::RandomController;

    fn cls_seg_tasks(t_ms: f64) -> Vec<TaskSpec> {
        vec![
            TaskSpec::new("cls", Task::Classification, RewardCfg::latency(t_ms)),
            TaskSpec::new("seg", Task::Segmentation, RewardCfg::latency(t_ms * 10.0)),
        ]
    }

    #[test]
    fn multi_task_eval_dispatches_on_the_task_prefix() {
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let has = HasSpace::new();
        let mut rng = Rng::new(3);
        let nas_d = space.random(&mut rng);
        let hw = has.baseline_decisions();
        let tasks = cls_seg_tasks(0.5);
        let mut mt = MultiTaskEval::surrogate(&tasks, NasSpaceId::EfficientNet, 3, 1);

        let cls_ref = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3)
            .evaluate_pure(&nas_d, &hw);
        let seg_ref = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3)
            .segmentation()
            .evaluate_pure(&nas_d, &hw);

        let mut key0 = vec![0];
        key0.extend_from_slice(&nas_d);
        let mut key1 = vec![1];
        key1.extend_from_slice(&nas_d);
        let got = mt.evaluate_batch(&[(key1.clone(), hw.clone()), (key0.clone(), hw.clone())]);
        assert_eq!(got[1].latency_ms.to_bits(), cls_ref.latency_ms.to_bits());
        assert_eq!(got[0].latency_ms.to_bits(), seg_ref.latency_ms.to_bits());
        // Table 4 scale: dense prediction is roughly an order of
        // magnitude slower than classification on the same hardware.
        assert!(got[0].latency_ms > 3.0 * got[1].latency_ms);
    }

    #[test]
    fn multi_task_search_folds_rewards_and_keeps_per_task_results() {
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let has = HasSpace::new();
        let (cards, layout) = JointLayout::cards(&space, &has);
        let tasks = cls_seg_tasks(2.0);
        let broker = EvalBroker::new(Box::new(MultiTaskEval::surrogate(
            &tasks,
            NasSpaceId::EfficientNet,
            5,
            1,
        )));
        let cfg = SearchCfg::new(60, RewardCfg::latency(2.0), 5);
        let mut ctl = RandomController::new(cards.clone());
        let mut session = broker.session();
        let out = multi_task_search(&mut session, &mut ctl, &layout, &tasks, &cfg);
        assert_eq!(out.search.history.len(), 60);
        assert_eq!(out.per_task.len(), 2);
        // One broker request per (sample x task) pair.
        assert_eq!(out.search.eval_stats.requests, 120);
        for s in &out.search.history {
            assert_eq!(s.nas_d.len(), layout.nas_len);
            assert_eq!(s.has_d.len(), layout.has_len);
        }
        // Determinism: the same seed replays bit for bit.
        let broker2 = EvalBroker::new(Box::new(MultiTaskEval::surrogate(
            &tasks,
            NasSpaceId::EfficientNet,
            5,
            1,
        )));
        let mut ctl2 = RandomController::new(cards);
        let mut session2 = broker2.session();
        let out2 = multi_task_search(&mut session2, &mut ctl2, &layout, &tasks, &cfg);
        assert_eq!(out.search.history.len(), out2.search.history.len());
        for (a, b) in out.search.history.iter().zip(&out2.search.history) {
            assert_eq!(a.nas_d, b.nas_d);
            assert_eq!(a.has_d, b.has_d);
            assert_eq!(a.reward.to_bits(), b.reward.to_bits());
            assert_eq!(a.result.acc.to_bits(), b.result.acc.to_bits());
        }
    }
}
