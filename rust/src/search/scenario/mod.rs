//! Scenario substrate registry — pluggable (search space × task ×
//! objective) workloads.
//!
//! The sweep orchestrator runs whatever [`Scenario`]s it is handed; a
//! *substrate* is where a whole family of scenarios — a use case in
//! the paper's sense — is declared once and compiled on demand. Each
//! [`ScenarioSubstrate`] names itself, declares its task set and its
//! objective vector, and compiles a [`SubstrateParams`] (space, budget,
//! seed, targets) down to plain [`Scenario`]s, so everything downstream
//! — `run_sweep`, the broker, the equivalence suites — is unchanged:
//! a substrate that reproduces an existing grid is bit-identical to
//! the hand-built grid (`tests/sweep_equivalence.rs` pins this).
//!
//! Registering a new substrate is three steps (see
//! `docs/ARCHITECTURE.md`, "Scenario substrate"):
//!
//! 1. implement [`ScenarioSubstrate`] for a (usually unit) struct,
//! 2. push it into the vector your code seeds from
//!    [`builtin_registry`],
//! 3. compile it by name via [`compile_substrates`] — the CLI's
//!    `nahas scenarios` / `nahas sweep --scenario NAME` do exactly
//!    this against the built-in registry.
//!
//! Built-ins: the two classic grids (`latency-grid`, `energy-grid`),
//! multi-task co-design (`multitask-cls-seg`, one shared accelerator
//! jointly scored across classification and segmentation), an
//! area-constrained family (`area-constrained`, 60% of the baseline
//! silicon budget), and a 3-objective family (`tri-objective`,
//! latency+energy+area N-dim frontier reporting).

pub mod multitask;

use anyhow::{bail, Result};

use crate::accel::area::baseline_area_mm2;
use crate::nas::NasSpaceId;
use crate::search::evaluator::Task;
use crate::search::reward::{CostObjective, RewardCfg};
use crate::search::sweep::{scenario_grid, Scenario, SweepDriver};
use multitask::TaskSpec;

/// Everything a substrate needs to compile concrete scenarios: which
/// space/backend the sweep runs on, the per-scenario budget, the
/// shared controller seed, and (optionally) cost targets in the
/// substrate's own objective unit.
#[derive(Clone, Debug)]
pub struct SubstrateParams {
    pub space: NasSpaceId,
    pub samples: usize,
    pub batch: usize,
    pub seed: u64,
    /// Cost targets; empty = the substrate's documented defaults.
    pub targets: Vec<f64>,
}

impl SubstrateParams {
    pub fn new(space: NasSpaceId, samples: usize, batch: usize, seed: u64) -> Self {
        SubstrateParams { space, samples, batch, seed, targets: Vec::new() }
    }

    pub fn targets(mut self, targets: Vec<f64>) -> Self {
        self.targets = targets;
        self
    }

    fn targets_or<'a>(&'a self, default: &'a [f64]) -> &'a [f64] {
        if self.targets.is_empty() {
            default
        } else {
            &self.targets
        }
    }
}

/// A named, registered family of scenarios. Implementations must be
/// pure: `compile` may depend only on its parameters, so a compiled
/// scenario replays bit-identically wherever it runs.
pub trait ScenarioSubstrate: Send + Sync {
    /// Registry key (`nahas sweep --scenario NAME`).
    fn name(&self) -> &str;
    /// One-line description for `nahas scenarios`.
    fn summary(&self) -> &str;
    /// The task set every compiled scenario evaluates. The sweep
    /// backend (and the eval-cache fingerprint) must match this.
    fn tasks(&self) -> Vec<Task>;
    /// The cost axes this substrate's scenarios optimize/report.
    fn objectives(&self) -> Vec<CostObjective>;
    /// Compile to concrete scenarios for `run_sweep`.
    fn compile(&self, p: &SubstrateParams) -> Vec<Scenario>;
}

/// The classic latency grid, as a substrate: compiles to exactly what
/// `scenario_grid(targets, [Latency], [Joint], ...)` builds by hand.
struct LatencyGrid;

impl ScenarioSubstrate for LatencyGrid {
    fn name(&self) -> &str {
        "latency-grid"
    }

    fn summary(&self) -> &str {
        "latency-target grid (joint driver), the classic single-task sweep"
    }

    fn tasks(&self) -> Vec<Task> {
        vec![Task::Classification]
    }

    fn objectives(&self) -> Vec<CostObjective> {
        vec![CostObjective::Latency]
    }

    fn compile(&self, p: &SubstrateParams) -> Vec<Scenario> {
        scenario_grid(
            p.targets_or(&[0.35, 0.5]),
            &[CostObjective::Latency],
            &[SweepDriver::Joint],
            p.space,
            p.samples,
            p.batch,
            p.seed,
        )
    }
}

/// The classic energy grid (targets in mJ).
struct EnergyGrid;

impl ScenarioSubstrate for EnergyGrid {
    fn name(&self) -> &str {
        "energy-grid"
    }

    fn summary(&self) -> &str {
        "energy-target grid (joint driver), the energy-driven single-task sweep"
    }

    fn tasks(&self) -> Vec<Task> {
        vec![Task::Classification]
    }

    fn objectives(&self) -> Vec<CostObjective> {
        vec![CostObjective::Energy]
    }

    fn compile(&self, p: &SubstrateParams) -> Vec<Scenario> {
        scenario_grid(
            p.targets_or(&[0.5, 1.0]),
            &[CostObjective::Energy],
            &[SweepDriver::Joint],
            p.space,
            p.samples,
            p.batch,
            p.seed,
        )
    }
}

/// Multi-task co-design: one shared accelerator + one shared backbone
/// jointly scored on classification and segmentation. The segmentation
/// latency target is 10x the classification one (Table 4's scale:
/// dense prediction at 640px vs classification at 224px).
struct MultiTaskClsSeg;

impl MultiTaskClsSeg {
    fn task_specs(t_ms: f64) -> Vec<TaskSpec> {
        vec![
            TaskSpec::new("cls", Task::Classification, RewardCfg::latency(t_ms)),
            TaskSpec::new("seg", Task::Segmentation, RewardCfg::latency(t_ms * 10.0)),
        ]
    }
}

impl ScenarioSubstrate for MultiTaskClsSeg {
    fn name(&self) -> &str {
        "multitask-cls-seg"
    }

    fn summary(&self) -> &str {
        "one accelerator serving classification + segmentation, folded reward, per-task frontiers"
    }

    fn tasks(&self) -> Vec<Task> {
        vec![Task::Classification, Task::Segmentation]
    }

    fn objectives(&self) -> Vec<CostObjective> {
        vec![CostObjective::Latency]
    }

    fn compile(&self, p: &SubstrateParams) -> Vec<Scenario> {
        p.targets_or(&[0.5])
            .iter()
            .map(|&t| {
                Scenario::new(
                    format!("multitask-cls-seg-lat{t}ms"),
                    p.space,
                    RewardCfg::latency(t),
                    p.seed,
                )
                .samples(p.samples)
                .batch(p.batch)
                .tasks(Self::task_specs(t))
            })
            .collect()
    }
}

/// Area-constrained co-design: the latency objective under a tight
/// silicon budget (60% of the baseline accelerator's area) — the
/// paper's area-vs-accuracy tradeoff pushed into the constraint.
struct AreaConstrained;

impl ScenarioSubstrate for AreaConstrained {
    fn name(&self) -> &str {
        "area-constrained"
    }

    fn summary(&self) -> &str {
        "latency targets under a 60%-of-baseline chip-area constraint"
    }

    fn tasks(&self) -> Vec<Task> {
        vec![Task::Classification]
    }

    fn objectives(&self) -> Vec<CostObjective> {
        vec![CostObjective::Latency, CostObjective::Area]
    }

    fn compile(&self, p: &SubstrateParams) -> Vec<Scenario> {
        let t_area = baseline_area_mm2() * 0.6;
        p.targets_or(&[0.35, 0.5])
            .iter()
            .map(|&t| {
                Scenario::new(
                    format!("area60-lat{t}ms"),
                    p.space,
                    RewardCfg::latency(t).with_t_area(t_area),
                    p.seed,
                )
                .samples(p.samples)
                .batch(p.batch)
                .frontier_objectives(vec![CostObjective::Latency, CostObjective::Area])
            })
            .collect()
    }
}

/// 3-objective scenarios: the search optimizes the latency reward, and
/// every valid sample is also reported on a latency+energy+area N-dim
/// Pareto frontier (the 2-axis trajectory is untouched — the N-dim
/// frontier is a reporting layer).
struct TriObjective;

impl ScenarioSubstrate for TriObjective {
    fn name(&self) -> &str {
        "tri-objective"
    }

    fn summary(&self) -> &str {
        "latency-driven search reported on a latency+energy+area 3-D frontier"
    }

    fn tasks(&self) -> Vec<Task> {
        vec![Task::Classification]
    }

    fn objectives(&self) -> Vec<CostObjective> {
        vec![CostObjective::Latency, CostObjective::Energy, CostObjective::Area]
    }

    fn compile(&self, p: &SubstrateParams) -> Vec<Scenario> {
        p.targets_or(&[0.5])
            .iter()
            .map(|&t| {
                Scenario::new(format!("tri-lat{t}ms"), p.space, RewardCfg::latency(t), p.seed)
                    .samples(p.samples)
                    .batch(p.batch)
                    .frontier_objectives(vec![
                        CostObjective::Latency,
                        CostObjective::Energy,
                        CostObjective::Area,
                    ])
            })
            .collect()
    }
}

/// The built-in substrates, in listing order. Callers own the vector:
/// push further [`ScenarioSubstrate`] implementations to register them
/// alongside the built-ins.
pub fn builtin_registry() -> Vec<Box<dyn ScenarioSubstrate>> {
    vec![
        Box::new(LatencyGrid),
        Box::new(EnergyGrid),
        Box::new(MultiTaskClsSeg),
        Box::new(AreaConstrained),
        Box::new(TriObjective),
    ]
}

/// Look a substrate up by its registry key.
pub fn find_substrate<'a>(
    registry: &'a [Box<dyn ScenarioSubstrate>],
    name: &str,
) -> Option<&'a dyn ScenarioSubstrate> {
    registry.iter().find(|s| s.name() == name).map(|b| b.as_ref())
}

/// Compile the named substrates into one scenario list for `run_sweep`.
/// All named substrates must agree on their task set (one sweep shares
/// one broker backend); an unknown name is an error listing the
/// registered keys.
pub fn compile_substrates(
    registry: &[Box<dyn ScenarioSubstrate>],
    names: &[String],
    p: &SubstrateParams,
) -> Result<Vec<Scenario>> {
    let mut out: Vec<Scenario> = Vec::new();
    let mut task_set: Option<Vec<Task>> = None;
    for name in names {
        let Some(sub) = find_substrate(registry, name) else {
            let known: Vec<&str> = registry.iter().map(|s| s.name()).collect();
            bail!("unknown scenario substrate {name:?}; registered: {}", known.join(", "));
        };
        match &task_set {
            None => task_set = Some(sub.tasks()),
            Some(t) if *t == sub.tasks() => {}
            Some(t) => bail!(
                "substrate {:?} evaluates tasks {:?}, but this sweep's backend serves {:?}: \
                 one sweep shares one broker backend, so all --scenario substrates must \
                 agree on their task set",
                name,
                sub.tasks(),
                t
            ),
        }
        out.extend(sub.compile(p));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> SubstrateParams {
        SubstrateParams::new(NasSpaceId::EfficientNet, 96, 16, 7)
    }

    #[test]
    fn registry_lists_all_builtin_families() {
        let reg = builtin_registry();
        let names: Vec<&str> = reg.iter().map(|s| s.name()).collect();
        for expect in
            ["latency-grid", "energy-grid", "multitask-cls-seg", "area-constrained", "tri-objective"]
        {
            assert!(names.contains(&expect), "{expect} missing from registry: {names:?}");
        }
        // Keys are unique.
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), names.len());
    }

    #[test]
    fn latency_grid_compiles_to_the_hand_built_grid() {
        let reg = builtin_registry();
        let sub = find_substrate(&reg, "latency-grid").unwrap();
        let got = sub.compile(&params().targets(vec![0.35, 0.5]));
        let want = scenario_grid(
            &[0.35, 0.5],
            &[CostObjective::Latency],
            &[SweepDriver::Joint],
            NasSpaceId::EfficientNet,
            96,
            16,
            7,
        );
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.name, w.name);
            assert_eq!(g.space, w.space);
            assert_eq!(g.seed, w.seed);
            assert_eq!(g.samples, w.samples);
            assert_eq!(g.batch, w.batch);
            assert_eq!(g.reward.t_cost.to_bits(), w.reward.t_cost.to_bits());
            assert_eq!(g.reward.objective, w.reward.objective);
            assert!(g.tasks.is_none());
        }
    }

    #[test]
    fn multitask_substrate_declares_two_tasks() {
        let reg = builtin_registry();
        let sub = find_substrate(&reg, "multitask-cls-seg").unwrap();
        assert_eq!(sub.tasks(), vec![Task::Classification, Task::Segmentation]);
        let scs = sub.compile(&params());
        assert_eq!(scs.len(), 1);
        let tasks = scs[0].tasks.as_ref().expect("multi-task scenario carries its task specs");
        assert_eq!(tasks.len(), 2);
        assert!(tasks[1].reward.t_cost > tasks[0].reward.t_cost, "seg target is looser");
    }

    #[test]
    fn area_constrained_tightens_t_area() {
        let reg = builtin_registry();
        let sub = find_substrate(&reg, "area-constrained").unwrap();
        let scs = sub.compile(&params().targets(vec![0.5]));
        assert_eq!(scs.len(), 1);
        assert!(scs[0].reward.t_area < baseline_area_mm2());
        assert_eq!(
            scs[0].frontier_objectives,
            vec![CostObjective::Latency, CostObjective::Area]
        );
    }

    #[test]
    fn compile_substrates_rejects_unknown_and_mixed_task_sets() {
        let reg = builtin_registry();
        let p = params();
        let err = compile_substrates(&reg, &["no-such-substrate".into()], &p).unwrap_err();
        assert!(err.to_string().contains("registered:"), "{err}");
        let err =
            compile_substrates(&reg, &["latency-grid".into(), "multitask-cls-seg".into()], &p)
                .unwrap_err();
        assert!(err.to_string().contains("task set"), "{err}");
        // Homogeneous task sets compose.
        let ok =
            compile_substrates(&reg, &["latency-grid".into(), "energy-grid".into()], &p).unwrap();
        assert_eq!(ok.len(), 4);
    }
}
