//! Evaluators: how a sampled (alpha, h) pair becomes metrics.
//!
//! The paper deploys its simulator "as a service where multiple NAHAS
//! clients can send parallel requests"; locally the same interface is a
//! trait. Implementations:
//!
//! * [`SurrogateSim`] — real simulator for latency/energy/area +
//!   calibrated accuracy surrogate (the large-sweep fidelity);
//! * [`TrainedEval`] — real proxy-task training through the AOT supernet
//!   for accuracy (the end-to-end fidelity, proxy space only);
//! * [`CostModelEval`] — learned MLP for latency/area (the oneshot inner
//!   loop, paper §3.5.2) + surrogate accuracy; energy falls back to the
//!   simulator for reporting.

use crate::accel::simulate_network;
use crate::costmodel::{featurize, CostModel, FEATURE_DIM};
use crate::has::{validate, HasSpace};
use crate::model::{Layer, NetworkIr};
use crate::nas::{NasSpace, NasSpaceId};
use crate::runtime::Runtime;
use crate::trainer::surrogate;
use crate::trainer::ProxyTrainer;

/// Metrics of one evaluated sample. `acc` is a fraction in [0, 1]
/// (ImageNet top-1 / 100, proxy accuracy, or mIOU / 100).
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub acc: f64,
    pub latency_ms: f64,
    pub energy_mj: f64,
    pub area_mm2: f64,
    pub valid: bool,
}

impl EvalResult {
    pub fn invalid() -> Self {
        EvalResult { valid: false, ..Default::default() }
    }
}

/// Which downstream task the accuracy metric refers to (paper §4.5 runs
/// the same search on Cityscapes segmentation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Classification,
    Segmentation,
}

/// Per-host routing counters of the cluster tier
/// ([`crate::cluster::ShardedEvaluator`]): how many samples this host
/// served (`requests` — evaluated misses plus the cache-hit repeats
/// its key range absorbs) and how many service roundtrips it actually
/// answered (`evals`); the gap is traffic the memo cache kept off the
/// wire thanks to affinity routing.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HostEvalStats {
    pub host: String,
    pub requests: usize,
    pub evals: usize,
    /// Host is currently marked down (failed probe or transport).
    pub down: bool,
}

impl HostEvalStats {
    /// Routed samples served without a roundtrip to this host.
    pub fn cache_hits(&self) -> usize {
        self.requests.saturating_sub(self.evals)
    }
}

/// Throughput counters an evaluator can expose (reported in
/// `SearchOutcome` and by the CLI). `requests` counts samples asked
/// for, `evals` the evaluations actually performed — the gap is
/// `cache_hits` (deduped repeat samples from the controller). The
/// broker tier ([`crate::search::EvalBroker`]) splits out
/// `cross_session_hits`: hits on keys first evaluated by a *different*
/// search session — the work a concurrent sweep saved by sharing one
/// broker (`inflight_hits` further isolates the requests that were
/// deduplicated *mid-flight*, i.e. served by waiting on an evaluation
/// another session had already dispatched). The cluster tier
/// additionally reports its host pool: `hosts_down` and one
/// [`HostEvalStats`] per configured host.
#[derive(Clone, Debug, Default)]
pub struct EvalStats {
    pub requests: usize,
    pub evals: usize,
    pub cache_hits: usize,
    pub invalid: usize,
    /// Of `cache_hits`, hits on keys another session evaluated first
    /// (broker tier only; 0 elsewhere).
    pub cross_session_hits: usize,
    /// Of `cache_hits`, hits on keys loaded from a persistent cache
    /// file spilled by an earlier run (broker tier with a
    /// [`crate::search::store::CacheStore`] attached only; 0
    /// elsewhere) — the warm-start savings of `--cache-dir`.
    pub persisted_hits: usize,
    /// Of `cross_session_hits`, requests that arrived while their key
    /// was *in flight* — already claimed by another session's batch
    /// but not yet finished — and were served by waiting on that
    /// evaluation instead of dispatching it a second time (broker
    /// tier with admission overlap only; 0 elsewhere). The in-flight
    /// dedup savings of `--broker-inflight`.
    pub inflight_hits: usize,
    /// Backend dispatch calls made (broker tier only; 0 elsewhere).
    /// For a session this counts the chunks *that session drove*; each
    /// dispatch is driven by exactly one session, so session deltas
    /// sum to the broker global, which equals
    /// [`crate::search::BrokerOverlapStats::dispatches`]. With
    /// `--dispatch-chunk` below the queue depth one batch streams out
    /// over several of these.
    pub dispatched_chunks: usize,
    /// Hosts currently marked down (cluster tier only; 0 elsewhere).
    pub hosts_down: usize,
    /// Per-host counters (cluster tier only; empty elsewhere).
    pub per_host: Vec<HostEvalStats>,
}

impl EvalStats {
    /// Fraction of requests served from the memo cache.
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.requests as f64
        }
    }

    /// Counter delta `self - earlier`. [`Evaluator::stats`] counters
    /// are cumulative since construction, so per-search reporting over
    /// a shared evaluator (e.g. the two phases of
    /// [`crate::search::phase::phase_search`]) subtracts a snapshot
    /// taken when the search started. Host up/down state is not a
    /// counter: the later snapshot's state is carried through.
    pub fn since(&self, earlier: &EvalStats) -> EvalStats {
        let per_host = self
            .per_host
            .iter()
            .map(|h| {
                let e = earlier.per_host.iter().find(|p| p.host == h.host);
                HostEvalStats {
                    host: h.host.clone(),
                    requests: h.requests.saturating_sub(e.map_or(0, |p| p.requests)),
                    evals: h.evals.saturating_sub(e.map_or(0, |p| p.evals)),
                    down: h.down,
                }
            })
            .collect();
        EvalStats {
            requests: self.requests.saturating_sub(earlier.requests),
            evals: self.evals.saturating_sub(earlier.evals),
            cache_hits: self.cache_hits.saturating_sub(earlier.cache_hits),
            invalid: self.invalid.saturating_sub(earlier.invalid),
            cross_session_hits: self
                .cross_session_hits
                .saturating_sub(earlier.cross_session_hits),
            persisted_hits: self.persisted_hits.saturating_sub(earlier.persisted_hits),
            inflight_hits: self.inflight_hits.saturating_sub(earlier.inflight_hits),
            dispatched_chunks: self
                .dispatched_chunks
                .saturating_sub(earlier.dispatched_chunks),
            hosts_down: self.hosts_down,
            per_host,
        }
    }

    /// Counter sum `self + other`, for aggregating deltas of searches
    /// that shared one evaluator (e.g. the HAS and NAS phases of a
    /// phase-based run). Per-host counters merge by host address; a
    /// host down in either snapshot is down in the merge, and
    /// `hosts_down` is re-derived from the merged flags so the two can
    /// never disagree.
    pub fn merged(&self, other: &EvalStats) -> EvalStats {
        let mut per_host = self.per_host.clone();
        for h in &other.per_host {
            match per_host.iter_mut().find(|p| p.host == h.host) {
                Some(p) => {
                    p.requests += h.requests;
                    p.evals += h.evals;
                    p.down |= h.down;
                }
                None => per_host.push(h.clone()),
            }
        }
        let hosts_down = if per_host.is_empty() {
            self.hosts_down.max(other.hosts_down)
        } else {
            per_host.iter().filter(|h| h.down).count()
        };
        EvalStats {
            requests: self.requests + other.requests,
            evals: self.evals + other.evals,
            cache_hits: self.cache_hits + other.cache_hits,
            invalid: self.invalid + other.invalid,
            cross_session_hits: self.cross_session_hits + other.cross_session_hits,
            persisted_hits: self.persisted_hits + other.persisted_hits,
            inflight_hits: self.inflight_hits + other.inflight_hits,
            dispatched_chunks: self.dispatched_chunks + other.dispatched_chunks,
            hosts_down,
            per_host,
        }
    }
}

/// Shared request/eval/invalid bookkeeping for the caching evaluators
/// ([`crate::search::ParallelSim`], [`crate::service::ServiceEvaluator`]);
/// `cache_hits` is derived, keeping the two tiers' accounting identical
/// by construction.
#[derive(Debug, Default)]
pub(crate) struct EvalCounters {
    pub(crate) requests: usize,
    pub(crate) evals: usize,
    pub(crate) invalid: usize,
}

impl EvalCounters {
    pub(crate) fn stats(&self) -> EvalStats {
        EvalStats {
            requests: self.requests,
            evals: self.evals,
            cache_hits: self.requests - self.evals,
            invalid: self.invalid,
            ..Default::default()
        }
    }
}

pub trait Evaluator {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult;

    /// Evaluate a whole controller batch. The default is the serial
    /// loop (result order == batch order); implementations like
    /// [`crate::search::ParallelSim`] and
    /// [`crate::service::ServiceEvaluator`] fan the batch out over
    /// worker threads / parallel service requests. Every
    /// implementation must return results **bit-identical** to the
    /// serial path: the search drivers rely on that for seed-stable
    /// replays.
    fn evaluate_batch(&mut self, batch: &[(Vec<usize>, Vec<usize>)]) -> Vec<EvalResult> {
        batch.iter().map(|(nas_d, has_d)| self.evaluate(nas_d, has_d)).collect()
    }

    /// Like [`Evaluator::evaluate_batch`], but every result carries a
    /// *cacheable* marker: `true` for a deterministic outcome that may
    /// be memoized forever (including deterministic `valid: false`
    /// rejections), `false` for a transient transport failure whose
    /// invalid result must not be memoized — the next resample has to
    /// retry it. The default wraps `evaluate_batch` (purely local
    /// evaluation cannot fail transiently); the remote tiers override
    /// it to propagate their per-sample transport verdicts. The shared
    /// [`crate::search::EvalBroker`] calls this instead of
    /// `evaluate_batch` so its cross-search cache cannot be poisoned
    /// by a flaky transport, whatever the backend.
    fn evaluate_batch_tagged(
        &mut self,
        batch: &[(Vec<usize>, Vec<usize>)],
    ) -> Vec<(EvalResult, bool)> {
        self.evaluate_batch(batch).into_iter().map(|r| (r, true)).collect()
    }

    /// Counters for throughput/cache reporting (zeroes by default).
    fn stats(&self) -> EvalStats {
        EvalStats::default()
    }

    /// Concurrency-capacity hint for the broker's admission control
    /// ([`crate::search::EvalBroker`], CLI `--broker-inflight`): how
    /// many samples this evaluator can usefully work on at once. The
    /// broker admits up to `min(--broker-inflight, capacity)` session
    /// batches concurrently, coalescing their misses into shared
    /// backend calls, so a hint of `1` (the default — a strictly
    /// serial evaluator) keeps the dispatch path exactly
    /// one-batch-at-a-time. Parallel tiers advertise their fan-out:
    /// worker threads ([`crate::search::ParallelSim`]), service
    /// connections ([`crate::service::ServiceEvaluator`]), or the
    /// pooled cluster connections
    /// ([`crate::cluster::ShardedEvaluator`]). A hint, not a contract:
    /// over- or under-advertising only changes scheduling, never any
    /// result.
    fn capacity(&self) -> usize {
        1
    }

    /// Cumulative `(tx, rx)` wire bytes this evaluator has moved, for
    /// the live metrics rows ([`crate::metrics::MetricsSink`]). Local
    /// tiers put nothing on a wire and keep the `(0, 0)` default; the
    /// remote tiers ([`crate::service::ServiceEvaluator`],
    /// [`crate::cluster::ShardedEvaluator`]) sum their per-connection
    /// counters. Purely observational: never affects results.
    fn wire_bytes(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// Simulator + surrogate-accuracy evaluator.
pub struct SurrogateSim {
    pub space: NasSpace,
    pub has: HasSpace,
    pub task: Task,
    pub seed: u64,
    /// Count of samples that failed validity/simulation (Fig. 7's red
    /// points).
    pub invalid_count: usize,
    pub eval_count: usize,
}

impl SurrogateSim {
    pub fn new(space: NasSpace, seed: u64) -> Self {
        SurrogateSim {
            space,
            has: HasSpace::new(),
            task: Task::Classification,
            seed,
            invalid_count: 0,
            eval_count: 0,
        }
    }

    pub fn segmentation(mut self) -> Self {
        self.task = Task::Segmentation;
        self
    }

    fn network(&self, nas_d: &[usize]) -> NetworkIr {
        match self.task {
            Task::Classification => self.space.decode(nas_d),
            Task::Segmentation => segmentation_variant(&self.space.decode(nas_d)),
        }
    }

    fn accuracy(&self, net: &NetworkIr) -> f64 {
        match (self.task, self.space.id) {
            (Task::Segmentation, _) => surrogate::segmentation_miou(net, self.seed) / 100.0,
            (_, NasSpaceId::Proxy) => surrogate::proxy_accuracy(net, self.seed),
            _ => surrogate::imagenet_accuracy(net, self.seed) / 100.0,
        }
    }

    /// The accuracy half of an evaluation (decode + task dispatch,
    /// including the segmentation variant). The remote tiers get
    /// hardware metrics from the simulator service but fill accuracy
    /// through this exact method, so local and remote accuracy can
    /// never diverge.
    pub fn accuracy_of(&self, nas_d: &[usize]) -> f64 {
        self.accuracy(&self.network(nas_d))
    }

    /// The pure (`&self`, counter-free) evaluation: everything here is
    /// a deterministic function of (space, task, seed, nas_d, has_d),
    /// which is what lets [`crate::search::ParallelSim`] call it from
    /// scoped worker threads and still match the serial path bit for
    /// bit. Allocates fresh decode buffers per call — the reference
    /// path; batch loops use [`SurrogateSim::evaluate_pure_in`].
    pub fn evaluate_pure(&self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        self.evaluate_pure_in(nas_d, has_d, &mut SimScratch::default())
    }

    /// [`SurrogateSim::evaluate_pure`] with caller-owned decode
    /// buffers: the batch hot path decodes every sample into one
    /// reused [`SimScratch`] instead of allocating a `NetworkIr` (and,
    /// for segmentation, a second one) per evaluation. Bit-identical
    /// to `evaluate_pure` — it *is* its body.
    pub fn evaluate_pure_in(
        &self,
        nas_d: &[usize],
        has_d: &[usize],
        scratch: &mut SimScratch,
    ) -> EvalResult {
        let cfg = self.has.decode(has_d);
        if validate(&cfg).is_err() {
            return EvalResult::invalid();
        }
        let net = self.network_in(nas_d, scratch);
        match simulate_network(&cfg, net) {
            Err(_) => EvalResult::invalid(),
            Ok(rep) => EvalResult {
                acc: self.accuracy(net),
                latency_ms: rep.latency_ms,
                energy_mj: rep.energy_mj,
                area_mm2: rep.area_mm2,
                valid: true,
            },
        }
    }

    /// [`SurrogateSim::network`] into the scratch buffers; returns the
    /// IR the simulator and surrogate should read (the segmentation
    /// variant when that is the task).
    fn network_in<'s>(&self, nas_d: &[usize], scratch: &'s mut SimScratch) -> &'s NetworkIr {
        self.space.decode_into(nas_d, &mut scratch.net);
        match self.task {
            Task::Classification => &scratch.net,
            Task::Segmentation => {
                segmentation_variant_into(&scratch.net, &mut scratch.seg);
                &scratch.seg
            }
        }
    }
}

/// Reusable decode buffers for [`SurrogateSim::evaluate_pure_in`]: the
/// decoded backbone plus (for segmentation) its dense-prediction
/// variant. One per worker/batch loop; never shared across threads.
#[derive(Default)]
pub struct SimScratch {
    net: NetworkIr,
    seg: NetworkIr,
}

impl Evaluator for SurrogateSim {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        self.eval_count += 1;
        let r = self.evaluate_pure(nas_d, has_d);
        if !r.valid {
            self.invalid_count += 1;
        }
        r
    }

    /// Serial like the default, but the whole batch shares one decode
    /// scratch instead of allocating per sample.
    fn evaluate_batch(&mut self, batch: &[(Vec<usize>, Vec<usize>)]) -> Vec<EvalResult> {
        let mut scratch = SimScratch::default();
        batch
            .iter()
            .map(|(nas_d, has_d)| {
                self.eval_count += 1;
                let r = self.evaluate_pure_in(nas_d, has_d, &mut scratch);
                if !r.valid {
                    self.invalid_count += 1;
                }
                r
            })
            .collect()
    }

    fn stats(&self) -> EvalStats {
        EvalStats {
            requests: self.eval_count,
            evals: self.eval_count,
            invalid: self.invalid_count,
            ..Default::default()
        }
    }
}

/// Rebuild a classification backbone as a dense-prediction network:
/// ~2.9x input resolution (Cityscapes 640-crop vs ImageNet 224) and an
/// FCN-style decoder head instead of pool+classifier. Reproduces the
/// ~10x latency scale of the paper's Table 4.
pub fn segmentation_variant(net: &NetworkIr) -> NetworkIr {
    let mut seg = NetworkIr::default();
    segmentation_variant_into(net, &mut seg);
    seg
}

/// [`segmentation_variant`] into a caller-owned buffer, reusing its
/// allocations (the batch hot path). Bit-identical to the allocating
/// wrapper — it *is* its body.
pub fn segmentation_variant_into(net: &NetworkIr, seg: &mut NetworkIr) {
    seg.reset(&net.name, 640, 640, net.input_c);
    seg.name.push_str("-seg");
    for li in &net.layers {
        match li.op {
            // Strip the classification head.
            Layer::GlobalPool { .. } | Layer::Dense { .. } => break,
            op => seg.push(op),
        }
    }
    let c = seg.cur_c();
    // FCN decoder: 3x3 fuse + 1x1 to 19 Cityscapes classes.
    seg.push(Layer::Conv2d { kh: 3, kw: 3, cin: c, cout: 256, stride: 1, groups: 1 });
    seg.push(Layer::Conv2d { kh: 1, kw: 1, cin: 256, cout: 19, stride: 1, groups: 1 });
}

/// Real-proxy-training evaluator (Proxy space only): accuracy from the
/// AOT supernet child training, latency/energy/area from the simulator.
pub struct TrainedEval {
    pub trainer: ProxyTrainer,
    pub has: HasSpace,
    pub seed: i32,
    trial: i32,
}

impl TrainedEval {
    pub fn new(trainer: ProxyTrainer, seed: i32) -> Self {
        TrainedEval { trainer, has: HasSpace::new(), seed, trial: 0 }
    }
}

impl Evaluator for TrainedEval {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        let cfg = self.has.decode(has_d);
        if validate(&cfg).is_err() {
            return EvalResult::invalid();
        }
        let net = self.trainer.space().decode(nas_d);
        let Ok(rep) = simulate_network(&cfg, &net) else {
            return EvalResult::invalid();
        };
        self.trial += 1;
        let seed = self.seed.wrapping_add(self.trial);
        match self.trainer.train_child(nas_d, seed) {
            Err(_) => EvalResult::invalid(),
            Ok(acc) => EvalResult {
                acc: acc as f64,
                latency_ms: rep.latency_ms,
                energy_mj: rep.energy_mj,
                area_mm2: rep.area_mm2,
                valid: true,
            },
        }
    }
}

/// Cost-model evaluator: latency/area from the learned MLP (the oneshot
/// inner loop the paper builds "because the query to the accelerator
/// performance simulator becomes the new bottleneck"); accuracy from the
/// surrogate; energy estimated from predicted latency x simulator-free
/// power proxy (reported fully only after final re-simulation).
pub struct CostModelEval<'rt> {
    pub rt: &'rt mut Runtime,
    pub cm: CostModel,
    pub space: NasSpace,
    pub has: HasSpace,
    pub seed: u64,
    feat: Vec<f32>,
}

impl<'rt> CostModelEval<'rt> {
    pub fn new(rt: &'rt mut Runtime, cm: CostModel, space: NasSpace, seed: u64) -> Self {
        CostModelEval { rt, cm, space, has: HasSpace::new(), seed, feat: vec![0.0; FEATURE_DIM] }
    }
}

impl Evaluator for CostModelEval<'_> {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        let cfg = self.has.decode(has_d);
        if validate(&cfg).is_err() {
            return EvalResult::invalid();
        }
        featurize(&self.space, nas_d, has_d, &mut self.feat);
        let Ok((lat, area)) = self.cm.predict_one(self.rt, &self.feat) else {
            return EvalResult::invalid();
        };
        let net = self.space.decode(nas_d);
        let acc = match self.space.id {
            NasSpaceId::Proxy => surrogate::proxy_accuracy(&net, self.seed),
            _ => surrogate::imagenet_accuracy(&net, self.seed) / 100.0,
        };
        // Energy proxy: predicted latency x a 2.5 W edge-power nominal
        // (exact energy is re-simulated for reported candidates).
        EvalResult { acc, latency_ms: lat, energy_mj: lat * 2.5, area_mm2: area, valid: true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn surrogate_sim_evaluates_baseline_hw() {
        let mut ev = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
        let has = HasSpace::new();
        let mut rng = Rng::new(1);
        let nas_d = ev.space.random(&mut rng);
        let r = ev.evaluate(&nas_d, &has.baseline_decisions());
        assert!(r.valid);
        assert!((0.5..0.9).contains(&r.acc), "{r:?}");
        assert!(r.latency_ms > 0.05 && r.latency_ms < 5.0);
    }

    #[test]
    fn invalid_hw_counted() {
        let mut ev = SurrogateSim::new(NasSpace::new(NasSpaceId::MobileNetV2), 3);
        // 8x8 PEs at 5 GB/s violates the starvation rule.
        let bad = vec![4, 4, 0, 0, 0, 0, 0];
        let mut rng = Rng::new(2);
        let nas_d = ev.space.random(&mut rng);
        let r = ev.evaluate(&nas_d, &bad);
        assert!(!r.valid);
        assert_eq!(ev.invalid_count, 1);
    }

    #[test]
    fn segmentation_variant_scales_latency() {
        use crate::accel::AcceleratorConfig;
        let net = crate::nas::baselines::efficientnet(0, false);
        let seg = segmentation_variant(&net);
        let cfg = AcceleratorConfig::baseline();
        let rc = simulate_network(&cfg, &net).unwrap();
        let rs = simulate_network(&cfg, &seg).unwrap();
        // Paper Table 4: ~3.3 ms vs 0.35 ms classification (~10x).
        let ratio = rs.latency_ms / rc.latency_ms;
        assert!((3.5..25.0).contains(&ratio), "seg/cls latency ratio {ratio}");
    }

    #[test]
    fn merged_and_since_carry_cross_session_hits() {
        let a = EvalStats {
            requests: 10,
            evals: 6,
            cache_hits: 4,
            invalid: 1,
            cross_session_hits: 3,
            persisted_hits: 1,
            inflight_hits: 2,
            dispatched_chunks: 4,
            ..Default::default()
        };
        let b = EvalStats {
            requests: 5,
            evals: 5,
            cache_hits: 0,
            invalid: 0,
            cross_session_hits: 0,
            ..Default::default()
        };
        let m = a.merged(&b);
        assert_eq!(m.requests, 15);
        assert_eq!(m.cross_session_hits, 3);
        assert_eq!(m.persisted_hits, 1);
        assert_eq!(m.inflight_hits, 2);
        assert_eq!(m.dispatched_chunks, 4);
        let d = m.since(&b);
        assert_eq!(d.requests, 10);
        assert_eq!(d.cross_session_hits, 3);
        assert_eq!(d.persisted_hits, 1);
        assert_eq!(d.inflight_hits, 2);
        assert_eq!(d.dispatched_chunks, 4);
    }

    #[test]
    fn segmentation_task_reports_miou() {
        let mut ev =
            SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3).segmentation();
        let has = HasSpace::new();
        let mut rng = Rng::new(3);
        let nas_d = ev.space.random(&mut rng);
        let r = ev.evaluate(&nas_d, &has.baseline_decisions());
        assert!(r.valid);
        assert!((0.5..0.8).contains(&r.acc), "mIOU fraction {r:?}");
    }
}
