//! Concurrent multi-scenario sweep orchestrator.
//!
//! The paper's third observation is that different *use cases* —
//! latency targets, latency- vs energy-driven objectives, joint vs
//! phase-based search — lead to very different search outcomes, and
//! its headline results (Fig. 2, Fig. 8) are built from sweeps of
//! searches, each the same machinery under a different constraint.
//! This module runs such a sweep as N concurrent search sessions over
//! one shared [`EvalBroker`]:
//!
//! * every [`Scenario`] runs on its own thread with its own controller
//!   and broker session, so the scenarios *overlap* their evaluation
//!   batches on the shared backend instead of queueing whole searches
//!   behind each other — up to the broker's admission limit
//!   (`--broker-inflight`, clamped to the backend's capacity hint),
//!   concurrent batches coalesce into shared backend dispatches;
//! * the broker's cross-search memo cache means a joint decision
//!   discovered by one scenario is never re-evaluated by another —
//!   sweeps over a common seed (common random numbers, the controlled-
//!   comparison default of [`scenario_grid`]) share their entire
//!   opening batches;
//! * each scenario is **bit-identical** to the same scenario run
//!   standalone with the same seed (`tests/sweep_equivalence.rs`):
//!   evaluation is a pure function of the decisions, so sharing the
//!   substrate can change how often a point is computed, never what a
//!   search sees;
//! * the per-scenario winners merge into a union Pareto frontier
//!   ([`crate::pareto::union_frontier`]) — Fig. 2's "joint search
//!   extends the Pareto frontier by joining multiple frontiers", here
//!   across *use cases* rather than accelerators;
//! * with a persistent cache behind the broker
//!   ([`EvalBroker::with_store`], CLI `--cache-dir`), the whole sweep
//!   also warm-starts from every evaluation an *earlier run* spilled:
//!   per-scenario [`EvalStats::persisted_hits`] deltas merge into the
//!   sweep totals exactly like the cross-session counters
//!   (`tests/cache_persistence.rs` pins a fully-warm re-sweep at zero
//!   backend evaluations).
//!
//! Long sweeps additionally survive being killed: a
//! [`SweepCheckpoint`] persists every completed scenario's full
//! outcome — history, frontiers, stats — as one checksummed segment
//! block ([`crate::util::codec`]), keyed by the evaluation fingerprint
//! and a per-scenario config digest. A rerun pointed at the same
//! checkpoint directory ([`run_sweep_resumable`], CLI `--checkpoint
//! DIR`) replays the recorded outcomes bit-for-bit and only runs the
//! scenarios the killed run never finished: zero re-evaluations of
//! completed scenarios, by construction rather than by cache warmth.
//!
//! CLI: `nahas sweep --targets 0.3,0.5,0.7 --objectives latency,energy
//! --drivers joint,phase --evaluator parallel|cluster ...`.

use std::collections::{HashMap, VecDeque};
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::has::HasSpace;
use crate::nas::{NasSpace, NasSpaceId};
use crate::pareto::{frontier, frontier_nd, union_frontier, MultiPoint, Point};
use crate::search::broker::EvalBroker;
use crate::search::evaluator::{EvalResult, EvalStats, HostEvalStats, Task};
use crate::search::evolution::EvolutionController;
use crate::search::joint::{joint_search, JointLayout, Sample, SearchCfg, SearchOutcome};
use crate::search::phase::phase_search;
use crate::search::ppo::PpoController;
use crate::search::reinforce::ReinforceController;
use crate::search::reward::{CostObjective, RewardCfg};
use crate::search::scenario::multitask::{multi_task_search, TaskSpec};
use crate::search::store::CacheValue;
use crate::search::{Controller, RandomController};
use crate::util::codec::{self, ByteReader, ReadPolicy};

/// Which search driver a scenario runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepDriver {
    /// Multi-trial joint NAS x HAS ([`joint_search`]).
    Joint,
    /// HAS-then-NAS ([`phase_search`], the Fig. 9 ablation).
    Phase,
}

/// Which controller proposes decisions for a scenario.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ControllerKind {
    Ppo,
    Random,
    Evolution,
    Reinforce,
}

/// One search configuration inside a sweep — a "use case" in the
/// paper's sense. `space` must match the broker backend's search
/// space: the backend decodes the same decision vectors this scenario
/// samples (the CLI builds both from `--space`, so they cannot
/// diverge there).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub space: NasSpaceId,
    pub driver: SweepDriver,
    pub controller: ControllerKind,
    pub reward: RewardCfg,
    /// Pin the hardware half: a platform-aware-NAS scenario (Fig. 2's
    /// per-accelerator frontiers). `Joint` driver only.
    pub fixed_hw: Option<Vec<usize>>,
    pub samples: usize,
    pub batch: usize,
    pub seed: u64,
    /// Multi-task co-design: one shared backbone + one shared hardware
    /// half jointly scored across these tasks
    /// ([`crate::search::scenario::multitask`]). `None` is the classic
    /// single-task path — bit-identical to before this field existed.
    pub tasks: Option<Vec<TaskSpec>>,
    /// Extra reporting axes: when non-empty, the scenario also reports
    /// its valid samples on an N-dim Pareto frontier over these
    /// objectives ([`ScenarioOutcome::frontier_nd`]). Reporting only —
    /// the search trajectory never depends on it.
    pub frontier_objectives: Vec<CostObjective>,
}

impl Scenario {
    pub fn new(
        name: impl Into<String>,
        space: NasSpaceId,
        reward: RewardCfg,
        seed: u64,
    ) -> Self {
        Scenario {
            name: name.into(),
            space,
            driver: SweepDriver::Joint,
            controller: ControllerKind::Ppo,
            reward,
            fixed_hw: None,
            samples: 500,
            batch: 16,
            seed,
            tasks: None,
            frontier_objectives: Vec::new(),
        }
    }

    pub fn samples(mut self, samples: usize) -> Self {
        self.samples = samples;
        self
    }

    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch.max(1);
        self
    }

    pub fn driver(mut self, driver: SweepDriver) -> Self {
        self.driver = driver;
        self
    }

    pub fn controller(mut self, controller: ControllerKind) -> Self {
        self.controller = controller;
        self
    }

    pub fn fixed_hw(mut self, hw: Vec<usize>) -> Self {
        self.fixed_hw = Some(hw);
        self
    }

    /// Make this a multi-task scenario (`Joint` driver, free hardware).
    pub fn tasks(mut self, tasks: Vec<TaskSpec>) -> Self {
        assert!(!tasks.is_empty(), "a multi-task scenario needs at least one task");
        self.tasks = Some(tasks);
        self
    }

    /// Also report an N-dim frontier over these cost axes.
    pub fn frontier_objectives(mut self, objectives: Vec<CostObjective>) -> Self {
        self.frontier_objectives = objectives;
        self
    }

    /// The evaluation-task list this scenario's broker backend must
    /// serve: empty for the classic single-task path (the backend's
    /// own task, whatever it is), the ordered task kinds otherwise.
    /// Scenarios sharing a sweep must agree on this — and it is part
    /// of the eval-cache fingerprint
    /// ([`crate::search::store::eval_fingerprint_tasks`]), so a
    /// multi-task cache file never warm-starts a single-task run.
    pub fn tasks_key(&self) -> Vec<Task> {
        self.tasks.as_ref().map(|ts| ts.iter().map(|t| t.task).collect()).unwrap_or_default()
    }

    /// The cost axis of this scenario's Pareto points (ms, mJ or mm2).
    fn cost_of(&self, r: &crate::search::EvalResult) -> f64 {
        self.reward.objective.cost_of(r)
    }
}

/// Build the full grid: targets x objectives x drivers, every scenario
/// on the same controller seed. Sharing the seed is deliberate: it is
/// the common-random-numbers design for comparing use cases, and it
/// maximizes cross-scenario cache hits (all same-shape scenarios draw
/// identical opening batches from identical initial policies). The
/// target value is interpreted in the objective's unit — ms for
/// latency, mJ for energy.
pub fn scenario_grid(
    targets: &[f64],
    objectives: &[CostObjective],
    drivers: &[SweepDriver],
    space: NasSpaceId,
    samples: usize,
    batch: usize,
    seed: u64,
) -> Vec<Scenario> {
    let mut out = Vec::new();
    for &driver in drivers {
        for &objective in objectives {
            for &target in targets {
                let (reward, tag) = match objective {
                    CostObjective::Latency => {
                        (RewardCfg::latency(target), format!("lat{target}ms"))
                    }
                    CostObjective::Energy => {
                        (RewardCfg::energy(target), format!("energy{target}mJ"))
                    }
                    CostObjective::Area => (RewardCfg::area(target), format!("area{target}mm2")),
                };
                let dname = match driver {
                    SweepDriver::Joint => "joint",
                    SweepDriver::Phase => "phase",
                };
                let name = format!("{tag}-{dname}");
                // Repeated targets/objectives/drivers would generate
                // the same scenario twice under the same name — and
                // `run_sweep` rejects duplicate names. Keep the first.
                if out.iter().any(|s: &Scenario| s.name == name) {
                    continue;
                }
                out.push(
                    Scenario::new(name, space, reward, seed)
                        .samples(samples)
                        .batch(batch)
                        .driver(driver),
                );
            }
        }
    }
    out
}

/// One finished scenario.
#[derive(Debug)]
pub struct ScenarioOutcome {
    pub scenario: Scenario,
    /// The (final, for `Phase`) search phase's outcome.
    pub search: SearchOutcome,
    /// The accelerator phase 1 selected (`Phase` driver only).
    pub selected_hw: Option<Vec<usize>>,
    /// This scenario's broker-session delta (both phases for `Phase`).
    pub eval_stats: EvalStats,
    /// Non-dominated (accuracy%, cost) points from the search history.
    pub frontier: Vec<Point>,
    /// Multi-task scenarios only: one (task name, frontier) per task,
    /// in task order, points tagged `"scenario@task"`. Empty otherwise.
    pub task_frontiers: Vec<(String, Vec<Point>)>,
    /// `frontier_objectives` scenarios only: the N-dim frontier of the
    /// valid samples over those axes. Empty otherwise.
    pub frontier_nd: Vec<MultiPoint>,
    pub elapsed_s: f64,
}

/// A finished sweep: per-scenario outcomes (input order), one union
/// Pareto frontier per cost objective (latency and energy are
/// different axes — unioning across them would compare ms to mJ), and
/// the merged evaluation stats (whose `cross_session_hits` is the work
/// sharing the broker saved).
#[derive(Debug)]
pub struct SweepOutcome {
    pub outcomes: Vec<ScenarioOutcome>,
    pub union: Vec<(CostObjective, Vec<Point>)>,
    /// Per-task frontiers from multi-task scenarios, keyed
    /// `"scenario@task"`, in outcome-then-task order.
    pub task_frontiers: Vec<(String, Vec<Point>)>,
    /// One union N-dim frontier per distinct `frontier_objectives`
    /// axis vector among the scenarios (axes must match to union).
    pub union_nd: Vec<(Vec<CostObjective>, Vec<MultiPoint>)>,
    pub eval_stats: EvalStats,
    pub elapsed_s: f64,
}

/// Run one scenario over (a new session of) the shared broker. This is
/// also the standalone entry: a scenario run here with a fresh broker
/// is the reference its in-sweep run must replay bit for bit.
pub fn run_scenario(broker: &EvalBroker, sc: &Scenario) -> ScenarioOutcome {
    let t0 = Instant::now();
    let space = NasSpace::new(sc.space);
    let has = HasSpace::new();
    let mut cfg = SearchCfg::new(sc.samples, sc.reward, sc.seed);
    cfg.batch = sc.batch.max(1);
    let (search, selected_hw, eval_stats, task_frontiers) = match sc.driver {
        SweepDriver::Joint if sc.tasks.is_some() => {
            let tasks = sc.tasks.as_ref().unwrap();
            assert!(
                sc.fixed_hw.is_none(),
                "scenario {}: fixed_hw is not supported for multi-task scenarios \
                 (the shared hardware half is what the search co-designs)",
                sc.name
            );
            let (cards, layout) = JointLayout::cards(&space, &has);
            let mut ctl: Box<dyn Controller> = match sc.controller {
                ControllerKind::Ppo => Box::new(PpoController::new(&cards)),
                ControllerKind::Random => Box::new(RandomController::new(cards)),
                ControllerKind::Evolution => Box::new(EvolutionController::new(cards)),
                ControllerKind::Reinforce => Box::new(ReinforceController::new(&cards)),
            };
            let mut session = broker.session();
            let out = multi_task_search(&mut session, ctl.as_mut(), &layout, tasks, &cfg);
            let stats = out.search.eval_stats.clone();
            let tf: Vec<(String, Vec<Point>)> = tasks
                .iter()
                .zip(&out.per_task)
                .map(|(t, rs)| {
                    let pts: Vec<Point> = rs
                        .iter()
                        .map(|(_, r)| {
                            Point::new(
                                r.acc * 100.0,
                                t.reward.objective.cost_of(r),
                                format!("{}@{}", sc.name, t.name),
                            )
                        })
                        .collect();
                    (t.name.clone(), frontier(&pts))
                })
                .collect();
            (out.search, None, stats, tf)
        }
        SweepDriver::Joint => {
            let (cards, layout) = JointLayout::cards(&space, &has);
            let free_cards =
                if sc.fixed_hw.is_some() { cards[..layout.nas_len].to_vec() } else { cards };
            let mut ctl: Box<dyn Controller> = match sc.controller {
                ControllerKind::Ppo => Box::new(PpoController::new(&free_cards)),
                ControllerKind::Random => Box::new(RandomController::new(free_cards)),
                ControllerKind::Evolution => Box::new(EvolutionController::new(free_cards)),
                ControllerKind::Reinforce => Box::new(ReinforceController::new(&free_cards)),
            };
            let mut session = broker.session();
            let out = joint_search(
                &mut session,
                ctl.as_mut(),
                &layout,
                sc.fixed_hw.as_deref(),
                None,
                &cfg,
            );
            let stats = out.eval_stats.clone();
            (out, None, stats, Vec::new())
        }
        SweepDriver::Phase => {
            // The phase driver has no knobs for these: surface the
            // misconfiguration instead of silently ignoring it.
            assert!(
                sc.fixed_hw.is_none(),
                "scenario {}: fixed_hw is Joint-driver only (phase 1 searches the hardware)",
                sc.name
            );
            assert!(
                sc.tasks.is_none(),
                "scenario {}: multi-task scenarios are Joint-driver only",
                sc.name
            );
            assert!(
                sc.controller == ControllerKind::Ppo,
                "scenario {}: the phase driver always runs PPO in both phases",
                sc.name
            );
            // Fixed initial architecture for phase 1, as in `nahas
            // phase` (the minimal point of the space).
            let initial = vec![0; space.num_decisions()];
            let out = phase_search(broker, &space, &initial, &cfg);
            let stats = out.eval_stats.clone();
            (out.nas_phase, Some(out.selected_hw), stats, Vec::new())
        }
    };
    let points: Vec<Point> = search
        .history
        .iter()
        .filter(|s| s.result.valid)
        .map(|s| Point::new(s.result.acc * 100.0, sc.cost_of(&s.result), sc.name.clone()))
        .collect();
    let nd_points: Vec<MultiPoint> = if sc.frontier_objectives.is_empty() {
        Vec::new()
    } else {
        search
            .history
            .iter()
            .filter(|s| s.result.valid)
            .map(|s| {
                MultiPoint::new(
                    s.result.acc * 100.0,
                    sc.frontier_objectives.iter().map(|o| o.cost_of(&s.result)).collect(),
                    sc.name.clone(),
                )
            })
            .collect()
    };
    ScenarioOutcome {
        scenario: sc.clone(),
        frontier: frontier(&points),
        task_frontiers,
        frontier_nd: frontier_nd(&nd_points),
        search,
        selected_hw,
        eval_stats,
        elapsed_s: t0.elapsed().as_secs_f64(),
    }
}

/// Run every scenario concurrently over the shared broker (one thread
/// and one broker session each) and merge the results. Outcomes come
/// back in input order whatever the interleaving.
///
/// # Examples
///
/// ```no_run
/// use nahas::nas::{NasSpace, NasSpaceId};
/// use nahas::search::{
///     run_sweep, scenario_grid, CostObjective, EvalBroker, ParallelSim, SweepDriver,
/// };
///
/// let scenarios = scenario_grid(
///     &[0.35, 0.5],
///     &[CostObjective::Latency],
///     &[SweepDriver::Joint],
///     NasSpaceId::EfficientNet,
///     200, // samples per scenario
///     16,  // controller batch
///     7,   // shared controller seed (common random numbers)
/// );
/// let backend = ParallelSim::new(NasSpace::new(NasSpaceId::EfficientNet), 7, 4);
/// let broker = EvalBroker::new(Box::new(backend));
/// let sweep = run_sweep(&broker, &scenarios);
/// for (objective, frontier) in &sweep.union {
///     println!("{objective:?}: {} non-dominated points", frontier.len());
/// }
/// println!("{} cross-scenario hits", sweep.eval_stats.cross_session_hits);
/// ```
pub fn run_sweep(broker: &EvalBroker, scenarios: &[Scenario]) -> SweepOutcome {
    run_sweep_resumable(broker, scenarios, None, scenarios.len())
}

/// Live completion gauge of a sweep, shared with an observer thread
/// (the [`crate::metrics::MetricsStreamer`] progress line). Workers
/// bump `completed` the moment a scenario outcome is published
/// (checkpoint-resumed scenarios count immediately, before any worker
/// starts), so an observer reads monotone progress without touching
/// any of the sweep's locks.
#[derive(Debug, Default)]
pub struct SweepProgress {
    completed: AtomicUsize,
    total: AtomicUsize,
}

impl SweepProgress {
    pub fn new() -> Self {
        Self::default()
    }

    /// Scenarios finished so far (checkpoint-resumed ones included).
    pub fn completed(&self) -> usize {
        self.completed.load(Ordering::Relaxed)
    }

    /// Total scenarios of the observed sweep (0 until it starts).
    pub fn total(&self) -> usize {
        self.total.load(Ordering::Relaxed)
    }

    fn set_total(&self, n: usize) {
        self.total.store(n, Ordering::Relaxed);
    }

    fn mark_done(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
    }
}

/// [`run_sweep`] with checkpointing and a worker cap. Scenarios with a
/// matching record in `ckpt` (same name, same config digest, same
/// fingerprint via [`SweepCheckpoint::open`]) are *replayed* from the
/// checkpoint — their recorded outcomes are returned bit-for-bit with
/// zero evaluations — and every freshly completed scenario is recorded
/// (and flushed) the moment it finishes, so a kill at any point loses
/// at most the scenarios still in flight. `threads` bounds how many
/// scenarios run concurrently (`run_sweep` uses one thread per
/// scenario); pending scenarios drain from a shared queue in input
/// order, and outcomes still come back in input order regardless.
pub fn run_sweep_resumable(
    broker: &EvalBroker,
    scenarios: &[Scenario],
    ckpt: Option<&mut SweepCheckpoint>,
    threads: usize,
) -> SweepOutcome {
    run_sweep_observed(broker, scenarios, ckpt, threads, None)
}

/// [`run_sweep_resumable`] with an optional [`SweepProgress`] gauge for
/// a live observer (`nahas sweep --metrics`). The gauge is written
/// from the worker threads with relaxed atomics only — attaching one
/// changes nothing about what the sweep computes.
pub fn run_sweep_observed(
    broker: &EvalBroker,
    scenarios: &[Scenario],
    mut ckpt: Option<&mut SweepCheckpoint>,
    threads: usize,
    progress: Option<&SweepProgress>,
) -> SweepOutcome {
    let t0 = Instant::now();
    // One broker backend decodes one search space; scenarios from a
    // different space would get silently wrong metrics memoized into
    // the shared cache. (Sweep several spaces with one broker each, as
    // the fig8 bench does.)
    assert!(
        scenarios.iter().all(|s| s.space == scenarios[0].space),
        "all scenarios of one sweep must share the broker backend's search space"
    );
    // One broker backend serves one task set: a multi-task backend
    // decodes task-prefixed keys a single-task backend would misread
    // (and vice versa), so mixing them in one sweep is a hard error.
    assert!(
        scenarios.iter().all(|s| s.tasks_key() == scenarios[0].tasks_key()),
        "all scenarios of one sweep must share the broker backend's task set \
         (single- and multi-task scenarios cannot share a broker)"
    );
    // Duplicate names would make per-scenario outcomes and union-
    // frontier attribution ambiguous — every point is tagged by name.
    let mut seen: std::collections::BTreeSet<&str> = std::collections::BTreeSet::new();
    for sc in scenarios {
        assert!(
            seen.insert(sc.name.as_str()),
            "duplicate scenario name {:?} in sweep: outcomes and union-frontier \
             attribution would be ambiguous (scenario names must be unique)",
            sc.name
        );
    }
    if let Some(p) = progress {
        p.set_total(scenarios.len());
    }
    let mut slots: Vec<Option<ScenarioOutcome>> = Vec::with_capacity(scenarios.len());
    let mut pending: VecDeque<usize> = VecDeque::new();
    for (i, sc) in scenarios.iter().enumerate() {
        match ckpt.as_mut().and_then(|c| c.take(sc)) {
            Some(out) => {
                slots.push(Some(out));
                if let Some(p) = progress {
                    p.mark_done();
                }
            }
            None => {
                slots.push(None);
                pending.push_back(i);
            }
        }
    }
    let workers = threads.max(1).min(pending.len().max(1));
    let queue = Mutex::new(pending);
    let slots = Mutex::new(slots);
    let sink = Mutex::new(ckpt);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = match queue.lock().unwrap().pop_front() {
                    Some(i) => i,
                    None => break,
                };
                let out = run_scenario(broker, &scenarios[i]);
                // Record before publishing: a kill between the two
                // can only lose the slot, never a checkpoint entry
                // for an outcome the caller saw.
                if let Some(c) = sink.lock().unwrap().as_deref_mut() {
                    c.record(&out);
                }
                slots.lock().unwrap()[i] = Some(out);
                if let Some(p) = progress {
                    p.mark_done();
                }
            });
        }
    });
    let outcomes: Vec<ScenarioOutcome> = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("every sweep scenario either resumed or ran"))
        .collect();
    merge_outcomes(outcomes, t0)
}

/// Fold per-scenario outcomes (input order) into the sweep-level
/// unions and merged stats. Pure and deterministic, so a sweep resumed
/// from a checkpoint merges to bit-identical frontiers.
fn merge_outcomes(outcomes: Vec<ScenarioOutcome>, t0: Instant) -> SweepOutcome {
    let eval_stats =
        outcomes.iter().fold(EvalStats::default(), |acc, o| acc.merged(&o.eval_stats));
    let mut union = Vec::new();
    for objective in [CostObjective::Latency, CostObjective::Energy, CostObjective::Area] {
        let fronts: Vec<Vec<Point>> = outcomes
            .iter()
            .filter(|o| o.scenario.reward.objective == objective)
            .map(|o| o.frontier.clone())
            .collect();
        if !fronts.is_empty() {
            union.push((objective, union_frontier(&fronts)));
        }
    }
    let task_frontiers: Vec<(String, Vec<Point>)> = outcomes
        .iter()
        .flat_map(|o| {
            o.task_frontiers
                .iter()
                .map(|(task, front)| (format!("{}@{}", o.scenario.name, task), front.clone()))
        })
        .collect();
    let mut union_nd: Vec<(Vec<CostObjective>, Vec<MultiPoint>)> = Vec::new();
    for o in &outcomes {
        if o.scenario.frontier_objectives.is_empty() {
            continue;
        }
        match union_nd.iter_mut().find(|(axes, _)| *axes == o.scenario.frontier_objectives) {
            Some((_, pts)) => pts.extend(o.frontier_nd.iter().cloned()),
            None => union_nd.push((o.scenario.frontier_objectives.clone(), o.frontier_nd.clone())),
        }
    }
    for (_, pts) in &mut union_nd {
        *pts = frontier_nd(pts);
    }
    SweepOutcome {
        outcomes,
        union,
        task_frontiers,
        union_nd,
        eval_stats,
        elapsed_s: t0.elapsed().as_secs_f64(),
    }
}

// ---------------------------------------------------------------------------
// Sweep checkpoints
// ---------------------------------------------------------------------------

/// On-disk format tag of a sweep checkpoint file; bump on any
/// incompatible record-layout change.
pub const SWEEP_CKPT_FORMAT: &str = "nahas-sweep-ckpt v1";

/// A completed scenario's outcome minus the `Scenario` itself (which
/// [`SweepCheckpoint::take`] reattaches from the live sweep after the
/// config digest matched).
struct StoredOutcome {
    search: SearchOutcome,
    selected_hw: Option<Vec<usize>>,
    eval_stats: EvalStats,
    frontier: Vec<Point>,
    task_frontiers: Vec<(String, Vec<Point>)>,
    frontier_nd: Vec<MultiPoint>,
    elapsed_s: f64,
}

impl StoredOutcome {
    fn into_outcome(self, scenario: Scenario) -> ScenarioOutcome {
        ScenarioOutcome {
            scenario,
            search: self.search,
            selected_hw: self.selected_hw,
            eval_stats: self.eval_stats,
            frontier: self.frontier,
            task_frontiers: self.task_frontiers,
            frontier_nd: self.frontier_nd,
            elapsed_s: self.elapsed_s,
        }
    }
}

/// Everything result-visible about a scenario's configuration, as one
/// comparable string. A record only replays when this matches exactly:
/// rename a scenario, change its samples, reward, controller, tasks or
/// frontier axes, and it re-runs instead of replaying a stale outcome.
fn config_digest(sc: &Scenario) -> String {
    format!("{sc:?}")
}

/// Loaded checkpoint records: scenario name -> (config digest, outcome).
type CkptRecords = HashMap<String, (String, StoredOutcome)>;

/// Persisted sweep progress: one checksummed, block-compressed segment
/// per completed scenario under a text header carrying the evaluation
/// fingerprint. Records are appended and flushed the moment a scenario
/// finishes, and read back with
/// [`ReadPolicy::Salvage`] — a kill mid-write
/// loses at most the in-flight record, never the scenarios already
/// completed. A stale fingerprint or corrupt record discards the whole
/// checkpoint (cold start, with the reason reported), mirroring the
/// eval-cache discipline.
///
/// The checkpoint stores *outcomes*, not inputs: a resumed scenario is
/// the recorded [`ScenarioOutcome`] replayed bit-for-bit, so
/// resumption can never diverge from what the killed run computed.
pub struct SweepCheckpoint {
    path: PathBuf,
    writer: BufWriter<File>,
    loaded: CkptRecords,
    discarded: Option<String>,
    resumed: usize,
    recorded: usize,
    write_failed: bool,
}

impl SweepCheckpoint {
    /// Open (or create) `DIR/sweep.ckpt` for the given evaluation
    /// fingerprint (the eval-cache fingerprint of the sweep's backend:
    /// [`crate::search::store::eval_fingerprint_tasks`]). Existing
    /// records load only under a matching fingerprint; otherwise the
    /// file restarts empty and [`SweepCheckpoint::discarded`] says why.
    pub fn open(dir: impl Into<PathBuf>, fingerprint: &str) -> Result<SweepCheckpoint> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let path = dir.join("sweep.ckpt");
        let header = format!("{SWEEP_CKPT_FORMAT} {fingerprint}");
        let mut loaded = HashMap::new();
        let mut discarded = None;
        let mut preserve = false;
        match fs::read(&path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            // Possibly-transient read failure: keep the file (it may
            // hold real progress we merely failed to read) and run
            // with checkpointing disabled.
            Err(e) => {
                discarded = Some(format!("unreadable ({e}); file kept, checkpointing off"));
                preserve = true;
            }
            Ok(bytes) => match Self::parse(&bytes, &header) {
                Ok(records) => loaded = records,
                Err(why) => discarded = Some(why),
            },
        }
        let warm = discarded.is_none() && !loaded.is_empty();
        if !warm && !preserve {
            // Restart atomically (temp file renamed into place), same
            // discipline as the cache store.
            let tmp = path.with_file_name(format!("sweep.ckpt.tmp{}", std::process::id()));
            fs::write(&tmp, format!("{header}\n"))
                .with_context(|| format!("writing checkpoint header to {}", tmp.display()))?;
            fs::rename(&tmp, &path)
                .with_context(|| format!("installing checkpoint file {}", path.display()))?;
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening checkpoint file {}", path.display()))?;
        Ok(SweepCheckpoint {
            path,
            writer: BufWriter::new(file),
            loaded,
            discarded,
            resumed: 0,
            recorded: 0,
            write_failed: preserve,
        })
    }

    fn parse(bytes: &[u8], header: &str) -> Result<CkptRecords, String> {
        if bytes.is_empty() {
            return Err("empty file".to_string());
        }
        let nl = match bytes.iter().position(|&b| b == b'\n') {
            Some(i) => i,
            None => return Err("truncated header line".to_string()),
        };
        match std::str::from_utf8(&bytes[..nl]) {
            Ok(h) if h == header => {}
            Ok(h) => return Err(format!("fingerprint mismatch (found '{h}')")),
            Err(_) => return Err("unreadable: non-UTF-8 header line".to_string()),
        }
        // Salvage: a torn trailing segment (killed mid-record) drops
        // silently; every segment that survives has a verified
        // checksum, so a record that then fails to *decode* is format
        // skew, not damage — reject the whole file.
        let segs = codec::read_segments(&bytes[nl + 1..], ReadPolicy::Salvage)?;
        let mut out = HashMap::new();
        for seg in &segs {
            match decode_record(&seg.payload) {
                // Later records win: a re-run scenario (config digest
                // changed, then changed back) appends a fresh record.
                Some((name, digest, stored)) => {
                    out.insert(name, (digest, stored));
                }
                None => return Err("corrupt checkpoint record".to_string()),
            }
        }
        Ok(out)
    }

    /// The checkpoint file this instance reads and appends.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Why pre-existing contents were discarded at open, if they were.
    pub fn discarded(&self) -> Option<&str> {
        self.discarded.as_deref()
    }

    /// Records loaded at open and not yet claimed by `take`.
    pub fn loaded_len(&self) -> usize {
        self.loaded.len()
    }

    /// Scenarios replayed from this checkpoint so far.
    pub fn resumed(&self) -> usize {
        self.resumed
    }

    /// Scenarios recorded into this checkpoint so far.
    pub fn recorded(&self) -> usize {
        self.recorded
    }

    /// Claim the recorded outcome for `sc`, if one exists under its
    /// name *and* its exact config digest. A name match with a
    /// different digest stays untouched: the scenario re-runs, and its
    /// fresh record supersedes the stale one (later records win).
    pub fn take(&mut self, sc: &Scenario) -> Option<ScenarioOutcome> {
        match self.loaded.get(&sc.name) {
            Some((digest, _)) if *digest == config_digest(sc) => {
                let (_, stored) = self.loaded.remove(&sc.name).unwrap();
                self.resumed += 1;
                Some(stored.into_outcome(sc.clone()))
            }
            _ => None,
        }
    }

    /// Append one completed scenario as a compressed segment, flushed
    /// immediately so the record survives a kill right after. Failures
    /// disable checkpointing for the run but never fail the sweep.
    pub fn record(&mut self, outcome: &ScenarioOutcome) {
        if self.write_failed {
            return;
        }
        let payload = encode_record(outcome);
        let mut block = Vec::new();
        codec::write_segment(&mut block, &payload, 1, true);
        if self.writer.write_all(&block).is_err() || self.writer.flush().is_err() {
            eprintln!(
                "sweep checkpoint {}: write failed; checkpointing disabled for this run",
                self.path.display()
            );
            self.write_failed = true;
            return;
        }
        self.recorded += 1;
    }
}

fn put_usize(out: &mut Vec<u8>, v: usize) {
    codec::put_varint(out, v as u64);
}

fn put_sample(out: &mut Vec<u8>, s: &Sample) {
    put_usize(out, s.index);
    codec::put_usize_slice(out, &s.nas_d);
    codec::put_usize_slice(out, &s.has_d);
    s.result.encode_bin(out);
    codec::put_f64_bits(out, s.reward);
}

fn read_sample(r: &mut ByteReader) -> Option<Sample> {
    Some(Sample {
        index: r.varint_usize()?,
        nas_d: r.usize_slice()?,
        has_d: r.usize_slice()?,
        result: EvalResult::decode_bin(r)?,
        reward: r.f64_bits()?,
    })
}

fn put_opt_sample(out: &mut Vec<u8>, s: &Option<Sample>) {
    match s {
        Some(s) => {
            out.push(1);
            put_sample(out, s);
        }
        None => out.push(0),
    }
}

fn read_opt_sample(r: &mut ByteReader) -> Option<Option<Sample>> {
    match r.u8()? {
        0 => Some(None),
        1 => Some(Some(read_sample(r)?)),
        _ => None,
    }
}

fn put_stats(out: &mut Vec<u8>, st: &EvalStats) {
    for v in [
        st.requests,
        st.evals,
        st.cache_hits,
        st.invalid,
        st.cross_session_hits,
        st.persisted_hits,
        st.inflight_hits,
        st.dispatched_chunks,
        st.hosts_down,
    ] {
        put_usize(out, v);
    }
    put_usize(out, st.per_host.len());
    for h in &st.per_host {
        codec::put_str(out, &h.host);
        put_usize(out, h.requests);
        put_usize(out, h.evals);
        out.push(h.down as u8);
    }
}

fn read_stats(r: &mut ByteReader) -> Option<EvalStats> {
    let mut c = [0usize; 9];
    for v in &mut c {
        *v = r.varint_usize()?;
    }
    let n = r.varint_usize()?;
    if n > r.remaining() {
        return None;
    }
    let mut per_host = Vec::with_capacity(n);
    for _ in 0..n {
        let host = r.str()?;
        let requests = r.varint_usize()?;
        let evals = r.varint_usize()?;
        let down = match r.u8()? {
            0 => false,
            1 => true,
            _ => return None,
        };
        per_host.push(HostEvalStats { host, requests, evals, down });
    }
    Some(EvalStats {
        requests: c[0],
        evals: c[1],
        cache_hits: c[2],
        invalid: c[3],
        cross_session_hits: c[4],
        persisted_hits: c[5],
        inflight_hits: c[6],
        dispatched_chunks: c[7],
        hosts_down: c[8],
        per_host,
    })
}

fn put_points(out: &mut Vec<u8>, pts: &[Point]) {
    put_usize(out, pts.len());
    for p in pts {
        codec::put_f64_bits(out, p.acc);
        codec::put_f64_bits(out, p.cost);
        codec::put_str(out, &p.tag);
    }
}

fn read_points(r: &mut ByteReader) -> Option<Vec<Point>> {
    let n = r.varint_usize()?;
    if n > r.remaining() {
        return None;
    }
    let mut pts = Vec::with_capacity(n);
    for _ in 0..n {
        let acc = r.f64_bits()?;
        let cost = r.f64_bits()?;
        let tag = r.str()?;
        pts.push(Point { acc, cost, tag });
    }
    Some(pts)
}

fn put_search(out: &mut Vec<u8>, so: &SearchOutcome) {
    put_usize(out, so.history.len());
    for s in &so.history {
        put_sample(out, s);
    }
    put_opt_sample(out, &so.best);
    put_opt_sample(out, &so.best_feasible);
    put_usize(out, so.num_invalid);
    put_stats(out, &so.eval_stats);
    codec::put_f64_bits(out, so.elapsed_s);
}

fn read_search(r: &mut ByteReader) -> Option<SearchOutcome> {
    let n = r.varint_usize()?;
    if n > r.remaining() {
        return None;
    }
    let mut history = Vec::with_capacity(n);
    for _ in 0..n {
        history.push(read_sample(r)?);
    }
    Some(SearchOutcome {
        history,
        best: read_opt_sample(r)?,
        best_feasible: read_opt_sample(r)?,
        num_invalid: r.varint_usize()?,
        eval_stats: read_stats(r)?,
        elapsed_s: r.f64_bits()?,
    })
}

fn encode_record(o: &ScenarioOutcome) -> Vec<u8> {
    let mut out = Vec::new();
    codec::put_str(&mut out, &o.scenario.name);
    codec::put_str(&mut out, &config_digest(&o.scenario));
    put_search(&mut out, &o.search);
    match &o.selected_hw {
        Some(hw) => {
            out.push(1);
            codec::put_usize_slice(&mut out, hw);
        }
        None => out.push(0),
    }
    put_stats(&mut out, &o.eval_stats);
    put_points(&mut out, &o.frontier);
    put_usize(&mut out, o.task_frontiers.len());
    for (task, pts) in &o.task_frontiers {
        codec::put_str(&mut out, task);
        put_points(&mut out, pts);
    }
    put_usize(&mut out, o.frontier_nd.len());
    for p in &o.frontier_nd {
        codec::put_f64_bits(&mut out, p.acc);
        put_usize(&mut out, p.costs.len());
        for &c in &p.costs {
            codec::put_f64_bits(&mut out, c);
        }
        codec::put_str(&mut out, &p.tag);
    }
    codec::put_f64_bits(&mut out, o.elapsed_s);
    out
}

fn decode_record(payload: &[u8]) -> Option<(String, String, StoredOutcome)> {
    let mut r = ByteReader::new(payload);
    let name = r.str()?;
    let digest = r.str()?;
    let search = read_search(&mut r)?;
    let selected_hw = match r.u8()? {
        0 => None,
        1 => Some(r.usize_slice()?),
        _ => return None,
    };
    let eval_stats = read_stats(&mut r)?;
    let frontier = read_points(&mut r)?;
    let ntf = r.varint_usize()?;
    if ntf > r.remaining() {
        return None;
    }
    let mut task_frontiers = Vec::with_capacity(ntf);
    for _ in 0..ntf {
        let task = r.str()?;
        task_frontiers.push((task, read_points(&mut r)?));
    }
    let nnd = r.varint_usize()?;
    if nnd > r.remaining() {
        return None;
    }
    let mut frontier_nd = Vec::with_capacity(nnd);
    for _ in 0..nnd {
        let acc = r.f64_bits()?;
        let nc = r.varint_usize()?;
        if nc > r.remaining() {
            return None;
        }
        let mut costs = Vec::with_capacity(nc);
        for _ in 0..nc {
            costs.push(r.f64_bits()?);
        }
        let tag = r.str()?;
        frontier_nd.push(MultiPoint { acc, costs, tag });
    }
    let elapsed_s = r.f64_bits()?;
    if !r.is_empty() {
        return None;
    }
    let stored = StoredOutcome {
        search,
        selected_hw,
        eval_stats,
        frontier,
        task_frontiers,
        frontier_nd,
        elapsed_s,
    };
    Some((name, digest, stored))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pareto::hypervolume;
    use crate::search::SurrogateSim;

    fn local_broker(seed: u64) -> EvalBroker {
        let sim = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), seed);
        EvalBroker::new(Box::new(sim))
    }

    #[test]
    fn grid_crosses_targets_objectives_and_drivers() {
        let g = scenario_grid(
            &[0.3, 0.5],
            &[CostObjective::Latency, CostObjective::Energy],
            &[SweepDriver::Joint, SweepDriver::Phase],
            NasSpaceId::EfficientNet,
            100,
            16,
            7,
        );
        assert_eq!(g.len(), 8);
        let mut names: Vec<&str> = g.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 8, "scenario names must be unique");
        assert!(g.iter().all(|s| s.seed == 7 && s.samples == 100));
    }

    #[test]
    fn sweep_merges_union_frontier_that_dominates_each_scenario() {
        // Two platform-aware-NAS scenarios on contrasting accelerators
        // (the Fig. 2 construction): the union frontier's hypervolume
        // must cover each per-scenario frontier's.
        let has = HasSpace::new();
        let mk = |name: &str, hw: Vec<usize>| {
            Scenario::new(name, NasSpaceId::EfficientNet, RewardCfg::latency(2.0), 2)
                .samples(120)
                .batch(24)
                .controller(ControllerKind::Random)
                .fixed_hw(hw)
        };
        let scenarios = vec![
            mk("baseline-hw", has.baseline_decisions()),
            mk("io-starved-hw", vec![2, 2, 2, 2, 2, 2, 0]),
        ];
        let broker = local_broker(2);
        let out = run_sweep(&broker, &scenarios);
        assert_eq!(out.outcomes.len(), 2);
        assert_eq!(out.union.len(), 1, "one union frontier per objective");
        assert_eq!(out.union[0].0, CostObjective::Latency);
        let hv_union = hypervolume(&out.union[0].1, 70.0, 2.0);
        for o in &out.outcomes {
            assert_eq!(o.search.history.len(), 120);
            let hv = hypervolume(&o.frontier, 70.0, 2.0);
            assert!(hv_union >= hv, "{}: union {hv_union} < scenario {hv}", o.scenario.name);
        }
        // Bookkeeping balances across the merged sessions.
        let m = &out.eval_stats;
        assert_eq!(m.requests, 240);
        assert_eq!(m.evals + m.cache_hits, m.requests);
    }

    #[test]
    fn grid_dedupes_repeated_axis_values() {
        let g = scenario_grid(
            &[0.5, 0.5, 0.3],
            &[CostObjective::Latency, CostObjective::Latency],
            &[SweepDriver::Joint],
            NasSpaceId::EfficientNet,
            100,
            16,
            7,
        );
        let names: Vec<&str> = g.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["lat0.5ms-joint", "lat0.3ms-joint"]);
    }

    #[test]
    #[should_panic(expected = "duplicate scenario name")]
    fn sweep_rejects_duplicate_scenario_names() {
        let sc = Scenario::new("twin", NasSpaceId::EfficientNet, RewardCfg::latency(0.5), 1)
            .samples(8)
            .controller(ControllerKind::Random);
        let broker = local_broker(1);
        run_sweep(&broker, &[sc.clone(), sc]);
    }

    #[test]
    fn multi_task_scenario_reports_per_task_frontiers() {
        use crate::search::scenario::multitask::MultiTaskEval;
        let tasks = vec![
            TaskSpec::new("cls", Task::Classification, RewardCfg::latency(2.0)),
            TaskSpec::new("seg", Task::Segmentation, RewardCfg::latency(20.0)),
        ];
        let sc = Scenario::new("mt", NasSpaceId::EfficientNet, RewardCfg::latency(2.0), 4)
            .samples(48)
            .batch(16)
            .controller(ControllerKind::Random)
            .tasks(tasks.clone());
        let broker = EvalBroker::new(Box::new(MultiTaskEval::surrogate(
            &tasks,
            NasSpaceId::EfficientNet,
            4,
            1,
        )));
        let out = run_sweep(&broker, &[sc]);
        assert_eq!(out.outcomes.len(), 1);
        let o = &out.outcomes[0];
        assert_eq!(o.search.history.len(), 48);
        // 48 samples x 2 tasks through the broker session.
        assert_eq!(o.eval_stats.requests, 96);
        assert_eq!(o.task_frontiers.len(), 2);
        assert_eq!(o.task_frontiers[0].0, "cls");
        assert_eq!(o.task_frontiers[1].0, "seg");
        assert_eq!(out.task_frontiers.len(), 2);
        assert_eq!(out.task_frontiers[0].0, "mt@cls");
        let seg_front = &out.task_frontiers[1].1;
        assert!(!seg_front.is_empty(), "segmentation frontier has valid points");
        assert!(seg_front.iter().all(|p| p.tag == "mt@seg"));
    }

    #[test]
    fn tri_objective_scenario_reports_an_nd_union() {
        let sc = Scenario::new("tri", NasSpaceId::EfficientNet, RewardCfg::latency(2.0), 9)
            .samples(64)
            .batch(16)
            .controller(ControllerKind::Random)
            .frontier_objectives(vec![
                CostObjective::Latency,
                CostObjective::Energy,
                CostObjective::Area,
            ]);
        let broker = local_broker(9);
        let out = run_sweep(&broker, &[sc]);
        let o = &out.outcomes[0];
        assert!(!o.frontier_nd.is_empty());
        assert!(o.frontier_nd.iter().all(|p| p.costs.len() == 3));
        assert_eq!(out.union_nd.len(), 1);
        assert_eq!(
            out.union_nd[0].0,
            vec![CostObjective::Latency, CostObjective::Energy, CostObjective::Area]
        );
        // The 2-D latency union still exists untouched beside it.
        assert_eq!(out.union.len(), 1);
        assert_eq!(out.union[0].0, CostObjective::Latency);
    }

    fn ckpt_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("nahas-sweep-ckpt-{}-{tag}", std::process::id()))
    }

    fn assert_outcomes_bit_identical(want: &SweepOutcome, got: &SweepOutcome) {
        assert_eq!(want.outcomes.len(), got.outcomes.len());
        for (w, g) in want.outcomes.iter().zip(&got.outcomes) {
            assert_eq!(w.scenario.name, g.scenario.name);
            assert_eq!(w.search.history.len(), g.search.history.len());
            for (a, b) in w.search.history.iter().zip(&g.search.history) {
                assert_eq!(a.index, b.index);
                assert_eq!(a.nas_d, b.nas_d);
                assert_eq!(a.has_d, b.has_d);
                assert_eq!(a.reward.to_bits(), b.reward.to_bits());
                assert_eq!(a.result.acc.to_bits(), b.result.acc.to_bits());
                assert_eq!(a.result.latency_ms.to_bits(), b.result.latency_ms.to_bits());
                assert_eq!(a.result.energy_mj.to_bits(), b.result.energy_mj.to_bits());
                assert_eq!(a.result.area_mm2.to_bits(), b.result.area_mm2.to_bits());
                assert_eq!(a.result.valid, b.result.valid);
            }
            assert_eq!(w.search.num_invalid, g.search.num_invalid);
            assert_eq!(w.selected_hw, g.selected_hw);
            assert_eq!(w.eval_stats.requests, g.eval_stats.requests);
            assert_eq!(w.frontier.len(), g.frontier.len());
            for (a, b) in w.frontier.iter().zip(&g.frontier) {
                assert_eq!(a.acc.to_bits(), b.acc.to_bits());
                assert_eq!(a.cost.to_bits(), b.cost.to_bits());
                assert_eq!(a.tag, b.tag);
            }
        }
        assert_eq!(want.union.len(), got.union.len());
        for ((wo, wf), (go, gf)) in want.union.iter().zip(&got.union) {
            assert_eq!(wo, go);
            assert_eq!(wf.len(), gf.len());
            for (a, b) in wf.iter().zip(gf) {
                assert_eq!(a.acc.to_bits(), b.acc.to_bits());
                assert_eq!(a.cost.to_bits(), b.cost.to_bits());
            }
        }
    }

    #[test]
    fn checkpointed_scenarios_replay_bit_identically_with_zero_evals() {
        let dir = ckpt_dir("replay");
        let _ = fs::remove_dir_all(&dir);
        let mk = |name: &str, reward: RewardCfg| {
            Scenario::new(name, NasSpaceId::EfficientNet, reward, 3)
                .samples(48)
                .batch(16)
                .controller(ControllerKind::Random)
        };
        let scenarios =
            vec![mk("lat", RewardCfg::latency(0.5)), mk("energy", RewardCfg::energy(1.0))];
        let cold = {
            let broker = local_broker(3);
            let mut ckpt = SweepCheckpoint::open(&dir, "eval/ckpt-test-fp").unwrap();
            assert_eq!(ckpt.loaded_len(), 0);
            let out = run_sweep_resumable(&broker, &scenarios, Some(&mut ckpt), 2);
            assert_eq!(ckpt.recorded(), 2);
            out
        };
        // Resume against a FRESH broker: outcomes replay from the
        // checkpoint alone — zero requests reach the substrate.
        let broker = local_broker(3);
        let mut ckpt = SweepCheckpoint::open(&dir, "eval/ckpt-test-fp").unwrap();
        assert!(ckpt.discarded().is_none(), "{:?}", ckpt.discarded());
        assert_eq!(ckpt.loaded_len(), 2);
        let warm = run_sweep_resumable(&broker, &scenarios, Some(&mut ckpt), 2);
        assert_eq!(ckpt.resumed(), 2);
        assert_eq!(broker.stats().requests, 0, "a fully-resumed sweep must not evaluate");
        assert_outcomes_bit_identical(&cold, &warm);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_config_or_fingerprint_changes_force_a_rerun() {
        let dir = ckpt_dir("stale");
        let _ = fs::remove_dir_all(&dir);
        let sc = Scenario::new("one", NasSpaceId::EfficientNet, RewardCfg::latency(0.5), 6)
            .samples(32)
            .batch(16)
            .controller(ControllerKind::Random);
        {
            let broker = local_broker(6);
            let mut ckpt = SweepCheckpoint::open(&dir, "eval/fp-a").unwrap();
            run_sweep_resumable(&broker, std::slice::from_ref(&sc), Some(&mut ckpt), 1);
        }
        // Same fingerprint, changed scenario config: digest mismatch.
        let mut ckpt = SweepCheckpoint::open(&dir, "eval/fp-a").unwrap();
        assert_eq!(ckpt.loaded_len(), 1);
        assert!(ckpt.take(&sc.clone().samples(64)).is_none());
        assert_eq!(ckpt.resumed(), 0);
        // Same config, new fingerprint: whole checkpoint discards.
        let ckpt = SweepCheckpoint::open(&dir, "eval/fp-b").unwrap();
        assert!(ckpt.discarded().unwrap().contains("fingerprint mismatch"));
        assert_eq!(ckpt.loaded_len(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_checkpoint_tail_salvages_completed_records() {
        let dir = ckpt_dir("torn");
        let _ = fs::remove_dir_all(&dir);
        let sc = Scenario::new("one", NasSpaceId::EfficientNet, RewardCfg::latency(0.5), 8)
            .samples(32)
            .batch(16)
            .controller(ControllerKind::Random);
        {
            let broker = local_broker(8);
            let mut ckpt = SweepCheckpoint::open(&dir, "eval/fp-torn").unwrap();
            run_sweep_resumable(&broker, std::slice::from_ref(&sc), Some(&mut ckpt), 1);
        }
        // A kill mid-record leaves a torn trailing segment: the
        // completed record before it must still load.
        let path = dir.join("sweep.ckpt");
        let mut bytes = fs::read(&path).unwrap();
        bytes.extend_from_slice(&[codec::SEG_MAGIC, 0, 0xFF, 0xFF]);
        fs::write(&path, &bytes).unwrap();
        let mut ckpt = SweepCheckpoint::open(&dir, "eval/fp-torn").unwrap();
        assert!(ckpt.discarded().is_none(), "{:?}", ckpt.discarded());
        assert_eq!(ckpt.loaded_len(), 1);
        assert!(ckpt.take(&sc).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn phase_scenario_reports_selected_hw_and_both_phase_stats() {
        let reward = RewardCfg::latency(0.5);
        let sc = Scenario::new("phase-0.5ms", NasSpaceId::EfficientNet, reward, 5)
            .samples(120)
            .driver(SweepDriver::Phase);
        let broker = local_broker(5);
        let out = run_scenario(&broker, &sc);
        assert_eq!(out.selected_hw.as_ref().map(Vec::len), Some(7));
        // The scenario delta covers BOTH phases, not just the final one.
        assert_eq!(out.eval_stats.requests, 120);
        assert_eq!(out.search.history.len(), 60, "final phase gets half the budget");
    }
}
