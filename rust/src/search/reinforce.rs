//! TuNAS-style REINFORCE controller for oneshot search (paper §3.5.2 /
//! §4.1: "we utilize REINFORCE to optimize the controller following
//! TuNAS. We use Adam with a learning rate of 0.0048 ... momentum 0.95
//! for baseline", plus the *absolute reward* function and an RL warmup
//! during which only shared weights train).

use crate::search::ppo::{softmax, Adam, Policy};
use crate::search::Controller;
use crate::util::Rng;

/// TuNAS absolute reward: `quality + beta * |cost/target - 1|` with
/// `beta < 0` — unlike the soft exponent it does not reward going *under*
/// the target, which keeps the controller near the constraint boundary.
pub fn absolute_reward(quality: f64, cost: f64, target: f64, beta: f64) -> f64 {
    quality + beta * (cost / target - 1.0).abs()
}

pub struct ReinforceController {
    pub policy: Policy,
    adam: Adam,
    /// EMA baseline with the paper's 0.95 momentum.
    baseline: f64,
    baseline_init: bool,
    pub momentum: f64,
}

impl ReinforceController {
    pub fn new(cards: &[usize]) -> Self {
        ReinforceController {
            policy: Policy::new(cards),
            adam: Adam::new(cards, 0.0048),
            baseline: 0.0,
            baseline_init: false,
            momentum: 0.95,
        }
    }
}

impl Controller for ReinforceController {
    fn sample(&mut self, rng: &mut Rng) -> Vec<usize> {
        self.policy.sample(rng)
    }

    fn update(&mut self, batch: &[(Vec<usize>, f64)]) {
        for (d, r) in batch {
            if !self.baseline_init {
                self.baseline = *r;
                self.baseline_init = true;
            }
            let adv = (*r - self.baseline) as f32;
            let mut grad: Vec<Vec<f32>> =
                self.policy.logits.iter().map(|l| vec![0.0; l.len()]).collect();
            for (i, &a) in d.iter().enumerate() {
                let p = softmax(&self.policy.logits[i]);
                for j in 0..p.len() {
                    let onehot = if j == a { 1.0 } else { 0.0 };
                    grad[i][j] = adv * (onehot - p[j]);
                }
            }
            self.adam.step(&mut self.policy.logits, &mut grad, 1.0);
            self.baseline = self.momentum * self.baseline + (1.0 - self.momentum) * r;
        }
    }

    fn best(&self) -> Vec<usize> {
        self.policy.argmax()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absolute_reward_peaks_at_target() {
        let q = 0.8;
        let at = absolute_reward(q, 1.0, 1.0, -0.5);
        let under = absolute_reward(q, 0.5, 1.0, -0.5);
        let over = absolute_reward(q, 1.5, 1.0, -0.5);
        assert_eq!(at, q);
        assert!(under < at && over < at);
        assert!((under - over).abs() < 1e-12); // symmetric
    }

    #[test]
    fn reinforce_learns_planted_optimum() {
        let cards = vec![3, 3];
        let mut ctl = ReinforceController::new(&cards);
        let mut rng = Rng::new(5);
        for _ in 0..800 {
            let d = ctl.sample(&mut rng);
            let r = if d == vec![1, 2] { 1.0 } else { 0.2 };
            ctl.update(&[(d, r)]);
        }
        assert_eq!(ctl.best(), vec![1, 2]);
    }
}
