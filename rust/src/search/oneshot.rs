//! Oneshot (weight-sharing) joint search (paper §3.5.2).
//!
//! One supernet, trained once: "we use the controller decisions from the
//! NAS space to construct a super-network ... meanwhile using the
//! decisions from the HAS space to create a subgraph for computing the
//! cost. Decision points from both spaces are optimized by a RL
//! algorithm within the same graph. For each training step, we train the
//! model weights and the controller decision points in an interleaved
//! way" — with the TuNAS absolute reward and an RL warmup, and the
//! learned cost model replacing the simulator in the inner loop.

use anyhow::Result;

use crate::has::{validate, HasSpace};
use crate::nas::NasSpace;
use crate::search::broker::{BrokerSession, EvalBroker};
use crate::search::evaluator::{EvalStats, Evaluator};
use crate::search::joint::JointLayout;
use crate::search::reinforce::{absolute_reward, ReinforceController};
use crate::search::Controller;
use crate::trainer::proxy::lr_at;
use crate::trainer::{ProxyTrainer, SupernetState};
use crate::util::Rng;

/// Latency oracle for the oneshot inner loop: either the simulator
/// directly or the learned cost model (the ablation of Fig. 6 / the
/// `ablation_costmodel` bench).
pub trait LatencyOracle {
    /// (latency_ms, area_mm2), or None if the pairing is invalid.
    fn cost(&mut self, nas_d: &[usize], has_d: &[usize]) -> Option<(f64, f64)>;

    /// (total queries, queries that reached an actual evaluation).
    /// Oracles without their own bookkeeping report (0, 0).
    fn traffic(&self) -> (usize, usize) {
        (0, 0)
    }
}

/// Direct-simulator oracle.
pub struct SimOracle {
    pub space: NasSpace,
    pub has: HasSpace,
}

impl LatencyOracle for SimOracle {
    fn cost(&mut self, nas_d: &[usize], has_d: &[usize]) -> Option<(f64, f64)> {
        let cfg = self.has.decode(has_d);
        validate(&cfg).ok()?;
        let net = self.space.decode(nas_d);
        let rep = crate::accel::simulate_network(&cfg, &net).ok()?;
        Some((rep.latency_ms, rep.area_mm2))
    }
}

/// [`LatencyOracle`] adapter over a broker session — the oneshot
/// driver's seat at the shared evaluation substrate.
///
/// The oneshot inner loop cannot pre-batch its cost queries — every
/// controller sample depends on the preceding interleaved update — but
/// as the policy sharpens it resamples the same joint vector over and
/// over. Routing each query through a [`BrokerSession`] gives the loop
/// everything the other drivers already have: the cross-session memo
/// cache (a repeat sample never re-runs the simulator), persisted
/// warm-start hits from earlier runs ([`EvalBroker::with_store`]),
/// admission control, and sweep participation — every oracle request
/// shows up in the broker's [`EvalStats`]. Deterministic backends keep
/// the memoized result bit-identical to a fresh query, so the search
/// trajectory is unchanged by any cache state.
pub struct BrokerOracle {
    session: BrokerSession,
}

impl BrokerOracle {
    pub fn new(broker: &EvalBroker) -> Self {
        BrokerOracle { session: broker.session() }
    }

    /// This oracle's broker-session delta (requests, evals, memo /
    /// cross-session / persisted hits ...).
    pub fn stats(&self) -> EvalStats {
        self.session.stats()
    }
}

impl LatencyOracle for BrokerOracle {
    fn cost(&mut self, nas_d: &[usize], has_d: &[usize]) -> Option<(f64, f64)> {
        // Invalid pairings are memoized too (valid = false): repeatedly
        // sampling an unsimulable design must not re-run validation.
        let r = self.session.evaluate(nas_d, has_d);
        r.valid.then_some((r.latency_ms, r.area_mm2))
    }

    fn traffic(&self) -> (usize, usize) {
        let s = self.session.stats();
        (s.requests, s.evals)
    }
}

#[derive(Clone, Debug)]
pub struct OneshotCfg {
    /// Weight-only warmup steps (TuNAS: RL warmup).
    pub warmup_steps: usize,
    /// Interleaved steps after warmup.
    pub search_steps: usize,
    /// Latency target (ms) and area target (mm^2) for the absolute reward.
    pub t_latency_ms: f64,
    pub t_area_mm2: f64,
    /// Absolute-reward slope (TuNAS beta < 0).
    pub beta: f64,
    pub lr0: f32,
    pub seed: u64,
}

impl Default for OneshotCfg {
    fn default() -> Self {
        OneshotCfg {
            warmup_steps: 60,
            search_steps: 200,
            t_latency_ms: 0.02,
            t_area_mm2: crate::accel::area::baseline_area_mm2(),
            beta: -0.5,
            lr0: 0.08,
            seed: 0,
        }
    }
}

pub struct OneshotOutcome {
    pub best_nas: Vec<usize>,
    pub best_has: Vec<usize>,
    /// Held-out accuracy of the final subnetwork under shared weights.
    pub final_acc: f32,
    pub final_latency_ms: f64,
    pub final_area_mm2: f64,
    /// (step, reward) trace of controller updates.
    pub reward_trace: Vec<(usize, f64)>,
    /// Cost-oracle traffic per [`LatencyOracle::traffic`]: total
    /// queries vs queries that reached an actual evaluation (for a
    /// [`BrokerOracle`], the broker session's requests and evals).
    pub oracle_requests: usize,
    pub oracle_evals: usize,
}

/// Run oneshot joint search on the proxy supernet.
pub fn oneshot_search(
    trainer: &mut ProxyTrainer,
    oracle: &mut dyn LatencyOracle,
    cfg: &OneshotCfg,
) -> Result<OneshotOutcome> {
    let space = trainer.space().clone();
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(&space, &has);
    let mut ctl = ReinforceController::new(&cards);
    let mut rng = Rng::new(cfg.seed);
    let total = cfg.warmup_steps + cfg.search_steps;

    let mut st: SupernetState = trainer.init_supernet(cfg.seed as i32)?;
    let mut trace = Vec::new();
    // Best *valid* sample seen, as the fallback if the controller's
    // argmax lands on an invalid hardware pairing.
    let mut best_valid: Option<(Vec<usize>, f64)> = None;

    for step in 0..total {
        let joint = ctl.sample(&mut rng);
        let (nas_d, has_d) = layout.split(&joint);
        let lr = lr_at(step, total, cfg.lr0);
        // Weight update on the sampled subnetwork (always).
        let (_loss, train_acc) = trainer.supernet_step(&mut st, nas_d, lr)?;
        // Controller update only after warmup (TuNAS RL warmup).
        if step >= cfg.warmup_steps {
            let reward = match oracle.cost(nas_d, has_d) {
                None => 0.0,
                Some((lat, area)) => {
                    let r = absolute_reward(
                        train_acc as f64,
                        lat,
                        cfg.t_latency_ms,
                        cfg.beta,
                    );
                    // Area enters as a second absolute term.
                    let r = r + cfg.beta * 0.5 * (area / cfg.t_area_mm2 - 1.0).max(0.0);
                    if best_valid.as_ref().map(|(_, br)| r > *br).unwrap_or(true) {
                        best_valid = Some((joint.clone(), r));
                    }
                    r
                }
            };
            ctl.update(&[(joint.clone(), reward)]);
            trace.push((step, ctl_last_reward(reward)));
        }
    }

    let mut best_joint = ctl.best();
    {
        let (nas_d, has_d) = layout.split(&best_joint);
        if oracle.cost(nas_d, has_d).is_none() {
            if let Some((bv, _)) = &best_valid {
                best_joint = bv.clone();
            }
        }
    }
    let (nas_d, has_d) = layout.split(&best_joint);
    let final_acc = trainer.supernet_eval(&st, nas_d)?;
    let (final_latency_ms, final_area_mm2) =
        oracle.cost(nas_d, has_d).unwrap_or((f64::NAN, f64::NAN));
    let (oracle_requests, oracle_evals) = oracle.traffic();
    Ok(OneshotOutcome {
        best_nas: nas_d.to_vec(),
        best_has: has_d.to_vec(),
        final_acc,
        final_latency_ms,
        final_area_mm2,
        reward_trace: trace,
        oracle_requests,
        oracle_evals,
    })
}

fn ctl_last_reward(r: f64) -> f64 {
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::NasSpaceId;

    #[test]
    fn sim_oracle_costs_valid_pairs() {
        let mut o = SimOracle { space: NasSpace::new(NasSpaceId::Proxy), has: HasSpace::new() };
        let has = HasSpace::new();
        let mut rng = Rng::new(3);
        let nas_d = o.space.random(&mut rng);
        let c = o.cost(&nas_d, &has.baseline_decisions());
        let (lat, area) = c.expect("baseline hw valid");
        assert!(lat > 0.0 && area > 10.0);
    }

    #[test]
    fn sim_oracle_rejects_invalid_hw() {
        let mut o = SimOracle { space: NasSpace::new(NasSpaceId::Proxy), has: HasSpace::new() };
        let mut rng = Rng::new(4);
        let nas_d = o.space.random(&mut rng);
        assert!(o.cost(&nas_d, &[4, 4, 0, 0, 0, 0, 0]).is_none());
    }

    #[test]
    fn broker_oracle_is_transparent_and_dedups() {
        // A BrokerOracle over a SurrogateSim backend must agree with
        // the direct SimOracle (both run the same validate +
        // simulate_network), while the broker's memo cache dedups
        // repeat queries.
        let mut fresh =
            SimOracle { space: NasSpace::new(NasSpaceId::Proxy), has: HasSpace::new() };
        let space = NasSpace::new(NasSpaceId::Proxy);
        let has = HasSpace::new();
        let broker = EvalBroker::new(Box::new(crate::search::SurrogateSim::new(
            NasSpace::new(NasSpaceId::Proxy),
            6,
        )));
        let mut oracle = BrokerOracle::new(&broker);
        let mut rng = Rng::new(6);
        let pairs: Vec<(Vec<usize>, Vec<usize>)> =
            (0..12).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect();
        for _round in 0..2 {
            for (nas_d, has_d) in &pairs {
                assert_eq!(oracle.cost(nas_d, has_d), fresh.cost(nas_d, has_d));
            }
        }
        let (requests, evals) = oracle.traffic();
        assert_eq!(requests, 24);
        assert_eq!(evals, 12, "second round must be all memo hits");
        assert_eq!(oracle.stats().cache_hits, 12);
        // Every oracle request is visible broker-side.
        assert_eq!(broker.stats().requests, 24);
    }
}
