//! Oneshot (weight-sharing) joint search (paper §3.5.2).
//!
//! One supernet, trained once: "we use the controller decisions from the
//! NAS space to construct a super-network ... meanwhile using the
//! decisions from the HAS space to create a subgraph for computing the
//! cost. Decision points from both spaces are optimized by a RL
//! algorithm within the same graph. For each training step, we train the
//! model weights and the controller decision points in an interleaved
//! way" — with the TuNAS absolute reward and an RL warmup, and the
//! learned cost model replacing the simulator in the inner loop.

use anyhow::Result;

use crate::has::{validate, HasSpace};
use crate::nas::NasSpace;
use crate::search::evaluator::EvalResult;
use crate::search::joint::JointLayout;
use crate::search::parallel::{joint_key, MemoCache};
use crate::search::reinforce::{absolute_reward, ReinforceController};
use crate::search::Controller;
use crate::trainer::proxy::lr_at;
use crate::trainer::{ProxyTrainer, SupernetState};
use crate::util::Rng;

/// Latency oracle for the oneshot inner loop: either the simulator
/// directly or the learned cost model (the ablation of Fig. 6 / the
/// `ablation_costmodel` bench).
pub trait LatencyOracle {
    /// (latency_ms, area_mm2), or None if the pairing is invalid.
    fn cost(&mut self, nas_d: &[usize], has_d: &[usize]) -> Option<(f64, f64)>;
}

/// Direct-simulator oracle.
pub struct SimOracle {
    pub space: NasSpace,
    pub has: HasSpace,
}

impl LatencyOracle for SimOracle {
    fn cost(&mut self, nas_d: &[usize], has_d: &[usize]) -> Option<(f64, f64)> {
        let cfg = self.has.decode(has_d);
        validate(&cfg).ok()?;
        let net = self.space.decode(nas_d);
        let rep = crate::accel::simulate_network(&cfg, &net).ok()?;
        Some((rep.latency_ms, rep.area_mm2))
    }
}

/// Memoizing wrapper over a [`LatencyOracle`].
///
/// The oneshot inner loop cannot pre-batch its cost queries — every
/// controller sample depends on the preceding interleaved update — but
/// as the policy sharpens it resamples the same joint vector over and
/// over, and each repeat used to hit the simulator again (the very
/// bottleneck the paper's learned cost model exists to relieve,
/// §3.5.2). Deterministic oracles (simulator, trained cost model) make
/// the cached result bit-identical to a fresh query.
pub struct CachedOracle<'a> {
    inner: &'a mut dyn LatencyOracle,
    cache: MemoCache,
    /// Total queries vs queries that reached the inner oracle.
    pub requests: usize,
    pub evals: usize,
}

impl<'a> CachedOracle<'a> {
    pub fn new(inner: &'a mut dyn LatencyOracle) -> Self {
        CachedOracle { inner, cache: MemoCache::new(16 * 1024), requests: 0, evals: 0 }
    }
}

impl LatencyOracle for CachedOracle<'_> {
    fn cost(&mut self, nas_d: &[usize], has_d: &[usize]) -> Option<(f64, f64)> {
        self.requests += 1;
        let key = joint_key(nas_d, has_d);
        if let Some(r) = self.cache.get(&key) {
            return r.valid.then_some((r.latency_ms, r.area_mm2));
        }
        self.evals += 1;
        let cost = self.inner.cost(nas_d, has_d);
        // Invalid pairings are cached too (valid = false): repeatedly
        // sampling an unsimulable design must not re-run validation.
        let r = match cost {
            Some((lat, area)) => {
                EvalResult { latency_ms: lat, area_mm2: area, valid: true, ..Default::default() }
            }
            None => EvalResult::invalid(),
        };
        self.cache.insert(key, r);
        cost
    }
}

#[derive(Clone, Debug)]
pub struct OneshotCfg {
    /// Weight-only warmup steps (TuNAS: RL warmup).
    pub warmup_steps: usize,
    /// Interleaved steps after warmup.
    pub search_steps: usize,
    /// Latency target (ms) and area target (mm^2) for the absolute reward.
    pub t_latency_ms: f64,
    pub t_area_mm2: f64,
    /// Absolute-reward slope (TuNAS beta < 0).
    pub beta: f64,
    pub lr0: f32,
    pub seed: u64,
}

impl Default for OneshotCfg {
    fn default() -> Self {
        OneshotCfg {
            warmup_steps: 60,
            search_steps: 200,
            t_latency_ms: 0.02,
            t_area_mm2: crate::accel::area::baseline_area_mm2(),
            beta: -0.5,
            lr0: 0.08,
            seed: 0,
        }
    }
}

pub struct OneshotOutcome {
    pub best_nas: Vec<usize>,
    pub best_has: Vec<usize>,
    /// Held-out accuracy of the final subnetwork under shared weights.
    pub final_acc: f32,
    pub final_latency_ms: f64,
    pub final_area_mm2: f64,
    /// (step, reward) trace of controller updates.
    pub reward_trace: Vec<(usize, f64)>,
    /// Cost-oracle traffic: total queries vs queries that missed the
    /// memo cache and reached the simulator / cost model.
    pub oracle_requests: usize,
    pub oracle_evals: usize,
}

/// Run oneshot joint search on the proxy supernet.
pub fn oneshot_search(
    trainer: &mut ProxyTrainer,
    oracle: &mut dyn LatencyOracle,
    cfg: &OneshotCfg,
) -> Result<OneshotOutcome> {
    let space = trainer.space().clone();
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(&space, &has);
    let mut ctl = ReinforceController::new(&cards);
    let mut rng = Rng::new(cfg.seed);
    let total = cfg.warmup_steps + cfg.search_steps;
    // Memoize the oracle: repeat samples of a sharpened policy become
    // cache hits instead of fresh simulator / cost-model queries.
    let mut oracle = CachedOracle::new(oracle);

    let mut st: SupernetState = trainer.init_supernet(cfg.seed as i32)?;
    let mut trace = Vec::new();
    // Best *valid* sample seen, as the fallback if the controller's
    // argmax lands on an invalid hardware pairing.
    let mut best_valid: Option<(Vec<usize>, f64)> = None;

    for step in 0..total {
        let joint = ctl.sample(&mut rng);
        let (nas_d, has_d) = layout.split(&joint);
        let lr = lr_at(step, total, cfg.lr0);
        // Weight update on the sampled subnetwork (always).
        let (_loss, train_acc) = trainer.supernet_step(&mut st, nas_d, lr)?;
        // Controller update only after warmup (TuNAS RL warmup).
        if step >= cfg.warmup_steps {
            let reward = match oracle.cost(nas_d, has_d) {
                None => 0.0,
                Some((lat, area)) => {
                    let r = absolute_reward(
                        train_acc as f64,
                        lat,
                        cfg.t_latency_ms,
                        cfg.beta,
                    );
                    // Area enters as a second absolute term.
                    let r = r + cfg.beta * 0.5 * (area / cfg.t_area_mm2 - 1.0).max(0.0);
                    if best_valid.as_ref().map(|(_, br)| r > *br).unwrap_or(true) {
                        best_valid = Some((joint.clone(), r));
                    }
                    r
                }
            };
            ctl.update(&[(joint.clone(), reward)]);
            trace.push((step, ctl_last_reward(reward)));
        }
    }

    let mut best_joint = ctl.best();
    {
        let (nas_d, has_d) = layout.split(&best_joint);
        if oracle.cost(nas_d, has_d).is_none() {
            if let Some((bv, _)) = &best_valid {
                best_joint = bv.clone();
            }
        }
    }
    let (nas_d, has_d) = layout.split(&best_joint);
    let final_acc = trainer.supernet_eval(&st, nas_d)?;
    let (final_latency_ms, final_area_mm2) =
        oracle.cost(nas_d, has_d).unwrap_or((f64::NAN, f64::NAN));
    Ok(OneshotOutcome {
        best_nas: nas_d.to_vec(),
        best_has: has_d.to_vec(),
        final_acc,
        final_latency_ms,
        final_area_mm2,
        reward_trace: trace,
        oracle_requests: oracle.requests,
        oracle_evals: oracle.evals,
    })
}

fn ctl_last_reward(r: f64) -> f64 {
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::NasSpaceId;

    #[test]
    fn sim_oracle_costs_valid_pairs() {
        let mut o = SimOracle { space: NasSpace::new(NasSpaceId::Proxy), has: HasSpace::new() };
        let has = HasSpace::new();
        let mut rng = Rng::new(3);
        let nas_d = o.space.random(&mut rng);
        let c = o.cost(&nas_d, &has.baseline_decisions());
        let (lat, area) = c.expect("baseline hw valid");
        assert!(lat > 0.0 && area > 10.0);
    }

    #[test]
    fn sim_oracle_rejects_invalid_hw() {
        let mut o = SimOracle { space: NasSpace::new(NasSpaceId::Proxy), has: HasSpace::new() };
        let mut rng = Rng::new(4);
        let nas_d = o.space.random(&mut rng);
        assert!(o.cost(&nas_d, &[4, 4, 0, 0, 0, 0, 0]).is_none());
    }

    #[test]
    fn cached_oracle_is_transparent_and_dedups() {
        let mut fresh =
            SimOracle { space: NasSpace::new(NasSpaceId::Proxy), has: HasSpace::new() };
        let mut backing =
            SimOracle { space: NasSpace::new(NasSpaceId::Proxy), has: HasSpace::new() };
        let space = NasSpace::new(NasSpaceId::Proxy);
        let has = HasSpace::new();
        let mut cached = CachedOracle::new(&mut backing);
        let mut rng = Rng::new(6);
        let pairs: Vec<(Vec<usize>, Vec<usize>)> =
            (0..12).map(|_| (space.random(&mut rng), has.random(&mut rng))).collect();
        for _round in 0..2 {
            for (nas_d, has_d) in &pairs {
                assert_eq!(cached.cost(nas_d, has_d), fresh.cost(nas_d, has_d));
            }
        }
        assert_eq!(cached.requests, 24);
        assert_eq!(cached.evals, 12, "second round must be all cache hits");
    }
}
