//! The NAHAS search framework (paper §3.4–§3.5).
//!
//! * [`reward`] — the constrained weighted-product objective (Eq. 4–6),
//!   hard (p=0, q=-1) and soft (p=q=-0.07) variants, latency- or
//!   energy-driven;
//! * [`evaluator`] — how a sampled (alpha, h) becomes (accuracy,
//!   latency, energy, area): surrogate+simulator, real proxy training,
//!   learned cost model, or the remote simulator service;
//! * [`ppo`] — the multi-trial controller (paper: PPO over a joint
//!   categorical space, Adam lr 5e-4, gradients clipped at 1.0);
//! * [`reinforce`] — the oneshot controller (TuNAS-style REINFORCE with
//!   absolute reward and warmup);
//! * [`evolution`] / random — baselines for the controller ablation;
//! * [`joint`] — multi-trial joint search driver (NAS x HAS, or either
//!   alone by fixing the other — Eq. 1 reduces to NAS or HAS);
//! * [`parallel`] — batched evaluation: the joint-decision memo cache
//!   and the multi-threaded [`ParallelSim`] evaluator (paper §4.1's
//!   "parallel requests", in-process; the remote tiers are
//!   [`crate::service::ServiceEvaluator`] and
//!   [`crate::cluster::ShardedEvaluator`]);
//! * [`broker`] — the shared evaluation seam: [`EvalBroker`]
//!   multiplexes any number of concurrent search sessions onto one
//!   backend tier behind a cross-search memo cache;
//! * [`store`] — cross-run persistence: the versioned append-only
//!   [`CacheStore`] file the broker (and the `nahas serve` result
//!   cache) spill to, so repeated runs warm-start (`--cache-dir`);
//! * [`sweep`] — the concurrent multi-scenario orchestrator (latency
//!   targets x objectives x drivers over one broker, merged into a
//!   union Pareto frontier — the paper's headline figures are sweeps);
//! * [`scenario`] — the substrate registry: named, pluggable (space x
//!   task x objective) workload families — multi-task co-design,
//!   area-constrained, N-objective — that compile down to [`sweep`]
//!   scenarios (`nahas scenarios`, `nahas sweep --scenario NAME`);
//! * [`oneshot`] — weight-sharing search over the AOT supernet, its
//!   cost oracle a broker session ([`oneshot::BrokerOracle`]);
//! * [`phase`] — the phase-based (HAS-then-NAS) ablation of Fig. 9.

pub mod broker;
pub mod evaluator;
pub mod evolution;
pub mod joint;
pub mod oneshot;
pub mod parallel;
pub mod phase;
pub mod ppo;
pub mod reinforce;
pub mod reward;
pub mod scenario;
pub mod store;
pub mod sweep;

pub use broker::{
    BackendSnapshot, BrokerOverlapStats, BrokerSession, BrokerSnapshot, EvalBroker,
    SessionCounters,
};
pub use evaluator::{
    EvalResult, EvalStats, Evaluator, HostEvalStats, SimScratch, SurrogateSim, Task,
};
pub use joint::{joint_search, Sample, SearchCfg, SearchOutcome};
pub use parallel::{joint_key, MemoCache, ParallelSim};
pub use reward::{ConstraintMode, CostObjective, RewardCfg};
pub use scenario::multitask::{multi_task_search, MultiTaskEval, MultiTaskOutcome, TaskSpec};
pub use scenario::{
    builtin_registry, compile_substrates, find_substrate, ScenarioSubstrate, SubstrateParams,
};
pub use store::{CacheStore, CacheValue};
pub use sweep::{
    run_scenario, run_sweep, run_sweep_observed, run_sweep_resumable, scenario_grid,
    ControllerKind, Scenario, ScenarioOutcome, SweepCheckpoint, SweepDriver, SweepOutcome,
    SweepProgress,
};

use crate::util::Rng;

/// A controller proposes decision vectors and learns from rewards.
pub trait Controller {
    fn sample(&mut self, rng: &mut Rng) -> Vec<usize>;
    /// Batch of (decisions, reward) pairs from the evaluator.
    fn update(&mut self, batch: &[(Vec<usize>, f64)]);
    /// Greedy argmax decision vector (the controller's current belief).
    fn best(&self) -> Vec<usize>;
}

/// Uniform-random controller (search baseline).
pub struct RandomController {
    cards: Vec<usize>,
    best_seen: Vec<usize>,
    best_reward: f64,
}

impl RandomController {
    pub fn new(cards: Vec<usize>) -> Self {
        let best_seen = vec![0; cards.len()];
        RandomController { cards, best_seen, best_reward: f64::NEG_INFINITY }
    }
}

impl Controller for RandomController {
    fn sample(&mut self, rng: &mut Rng) -> Vec<usize> {
        self.cards.iter().map(|&c| rng.below(c)).collect()
    }

    fn update(&mut self, batch: &[(Vec<usize>, f64)]) {
        for (d, r) in batch {
            if *r > self.best_reward {
                self.best_reward = *r;
                self.best_seen = d.clone();
            }
        }
    }

    fn best(&self) -> Vec<usize> {
        self.best_seen.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_controller_tracks_best() {
        let mut c = RandomController::new(vec![3, 3]);
        c.update(&[(vec![1, 2], 0.5), (vec![2, 0], 0.9), (vec![0, 0], 0.1)]);
        assert_eq!(c.best(), vec![2, 0]);
    }
}
