//! The NAHAS search objective (paper §3.4, Eq. 4–6):
//!
//! ```text
//! maximize  Accuracy(a, h) * (Cost(a, h) / T_cost)^w0 * (Area(h) / T_area)^w1
//! w0 = p if Cost <= T_cost else q;   w1 = p if Area <= T_area else q
//! ```
//!
//! Hard constraint: p = 0, q = -1 (accuracy-only when feasible, sharp
//! penalty otherwise). Soft constraint: p = q = -0.07 (MnasNet's
//! empirically Pareto-fair exponent). The cost metric is latency for the
//! latency-driven search and energy (power x latency) for the
//! energy-driven one — "the latency constraint can be easily swapped
//! with an energy constraint".

use crate::accel::area::baseline_area_mm2;
use crate::search::evaluator::EvalResult;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConstraintMode {
    /// p = 0, q = -1.
    Hard,
    /// p = q = -0.07.
    Soft,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostObjective {
    Latency,
    Energy,
    /// Chip area as the primary cost axis (mm^2). Area-driven scenarios
    /// trade accuracy directly against silicon budget; latency/energy
    /// still bound feasibility through `t_cost` on the other axes when
    /// combined in an N-objective frontier.
    Area,
}

impl CostObjective {
    /// Extract this objective's cost metric from an evaluation result.
    pub fn cost_of(&self, r: &EvalResult) -> f64 {
        match self {
            CostObjective::Latency => r.latency_ms,
            CostObjective::Energy => r.energy_mj,
            CostObjective::Area => r.area_mm2,
        }
    }

    /// Unit label for tables/CSV headers.
    pub fn unit(&self) -> &'static str {
        match self {
            CostObjective::Latency => "ms",
            CostObjective::Energy => "mJ",
            CostObjective::Area => "mm2",
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct RewardCfg {
    /// Target on the cost metric (ms for latency, mJ for energy).
    pub t_cost: f64,
    /// Chip-area target, mm^2 (paper: the baseline design's area).
    pub t_area: f64,
    pub mode: ConstraintMode,
    pub objective: CostObjective,
    /// Reward assigned to invalid (unsimulable / rejected) samples. The
    /// paper keeps traversing them ("can help converge to more
    /// pareto-optimal samples"), so this is low but not -inf.
    pub invalid_reward: f64,
}

impl RewardCfg {
    pub fn latency(t_ms: f64) -> Self {
        RewardCfg {
            t_cost: t_ms,
            t_area: baseline_area_mm2(),
            mode: ConstraintMode::Hard,
            objective: CostObjective::Latency,
            invalid_reward: 0.05,
        }
    }

    pub fn energy(t_mj: f64) -> Self {
        RewardCfg { objective: CostObjective::Energy, t_cost: t_mj, ..Self::latency(0.0) }
    }

    /// Area-driven objective: the cost axis is chip area itself (mm^2).
    /// `t_area` doubles as the cost target so the two constraints agree.
    pub fn area(t_mm2: f64) -> Self {
        RewardCfg {
            objective: CostObjective::Area,
            t_cost: t_mm2,
            t_area: t_mm2,
            ..Self::latency(0.0)
        }
    }

    pub fn soft(mut self) -> Self {
        self.mode = ConstraintMode::Soft;
        self
    }

    /// Override the chip-area target (mm^2). Area-constrained scenarios
    /// tighten this below the baseline design's area.
    pub fn with_t_area(mut self, t_mm2: f64) -> Self {
        self.t_area = t_mm2;
        self
    }

    fn p_q(&self) -> (f64, f64) {
        match self.mode {
            ConstraintMode::Hard => (0.0, -1.0),
            ConstraintMode::Soft => (-0.07, -0.07),
        }
    }

    /// Eq. 4 over an evaluation result; accuracy enters as a fraction.
    pub fn reward(&self, r: &EvalResult) -> f64 {
        if !r.valid {
            return self.invalid_reward;
        }
        let cost = self.objective.cost_of(r);
        let (p, q) = self.p_q();
        let w0 = if cost <= self.t_cost { p } else { q };
        let w1 = if r.area_mm2 <= self.t_area { p } else { q };
        let acc = r.acc; // fraction in [0, 1]
        acc * (cost / self.t_cost).powf(w0) * (r.area_mm2 / self.t_area).powf(w1)
    }

    /// True iff the sample meets both constraints.
    pub fn feasible(&self, r: &EvalResult) -> bool {
        r.valid && self.objective.cost_of(r) <= self.t_cost && r.area_mm2 <= self.t_area
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn res(acc: f64, lat: f64, area: f64) -> EvalResult {
        EvalResult { acc, latency_ms: lat, energy_mj: lat * 2.0, area_mm2: area, valid: true }
    }

    #[test]
    fn hard_mode_is_accuracy_when_feasible() {
        let cfg = RewardCfg::latency(0.5);
        let a = baseline_area_mm2();
        assert!((cfg.reward(&res(0.75, 0.4, a)) - 0.75).abs() < 1e-12);
        assert!((cfg.reward(&res(0.75, 0.5, a)) - 0.75).abs() < 1e-12); // boundary
    }

    #[test]
    fn hard_mode_penalizes_violation_sharply() {
        let cfg = RewardCfg::latency(0.5);
        let a = baseline_area_mm2();
        let ok = cfg.reward(&res(0.75, 0.5, a));
        let bad = cfg.reward(&res(0.75, 1.0, a)); // 2x over: acc * (2)^-1
        assert!((bad - 0.375).abs() < 1e-12);
        assert!(bad < ok);
    }

    #[test]
    fn soft_mode_trades_smoothly() {
        let cfg = RewardCfg::latency(0.5).soft();
        let a = baseline_area_mm2();
        // MnasNet property: halving latency at equal accuracy changes
        // reward by 2^0.07 ~ 5%.
        let r1 = cfg.reward(&res(0.75, 0.5, a));
        let r2 = cfg.reward(&res(0.75, 0.25, a));
        assert!(r2 > r1);
        assert!((r2 / r1 - 2f64.powf(0.07)).abs() < 1e-9);
    }

    #[test]
    fn area_violation_also_penalized() {
        let cfg = RewardCfg::latency(0.5);
        let a = baseline_area_mm2();
        let ok = cfg.reward(&res(0.75, 0.4, a));
        let big = cfg.reward(&res(0.75, 0.4, a * 1.5));
        assert!(big < ok);
        assert!((big - 0.75 / 1.5).abs() < 1e-9);
    }

    #[test]
    fn energy_objective_uses_energy() {
        let cfg = RewardCfg::energy(1.0);
        let a = baseline_area_mm2();
        let r = res(0.75, 0.4, a); // energy = 0.8 <= 1.0
        assert!(cfg.feasible(&r));
        let r2 = res(0.75, 0.6, a); // energy 1.2 > 1.0
        assert!(!cfg.feasible(&r2));
        assert!(cfg.reward(&r) > cfg.reward(&r2));
    }

    #[test]
    fn area_objective_uses_area() {
        let a = baseline_area_mm2();
        let cfg = RewardCfg::area(a);
        assert!(cfg.feasible(&res(0.75, 0.4, a)));
        assert!(!cfg.feasible(&res(0.75, 0.4, a * 1.2)));
        assert_eq!(CostObjective::Area.cost_of(&res(0.75, 0.4, a)), a);
        assert_eq!(CostObjective::Area.unit(), "mm2");
    }

    #[test]
    fn with_t_area_tightens_the_constraint() {
        let a = baseline_area_mm2();
        let loose = RewardCfg::latency(0.5);
        let tight = RewardCfg::latency(0.5).with_t_area(a * 0.6);
        let r = res(0.75, 0.4, a * 0.8);
        assert!(loose.feasible(&r));
        assert!(!tight.feasible(&r));
        assert!(tight.reward(&r) < loose.reward(&r));
    }

    #[test]
    fn invalid_gets_floor_reward() {
        let cfg = RewardCfg::latency(0.5);
        let mut r = res(0.9, 0.1, 10.0);
        r.valid = false;
        assert_eq!(cfg.reward(&r), cfg.invalid_reward);
    }

    #[test]
    fn prop_reward_monotone_in_accuracy() {
        let cfg = RewardCfg::latency(0.5);
        proptest::check(
            "reward monotone in acc",
            128,
            |r| (r.f64(), 0.1 + r.f64(), 40.0 + 80.0 * r.f64()),
            |&(acc, lat, area)| {
                let lo = cfg.reward(&res(acc * 0.5, lat, area));
                let hi = cfg.reward(&res(acc, lat, area));
                if hi >= lo {
                    Ok(())
                } else {
                    Err(format!("{hi} < {lo}"))
                }
            },
        );
    }
}
