//! Micro-bench harness for the paper-figure bench binaries.
//!
//! criterion is not vendored in this offline environment, so the benches
//! (`rust/benches/*.rs`, `harness = false`) use this Instant-based
//! harness: warmup, N timed iterations, min/median/mean reporting — plus
//! table helpers that print the same rows the paper's tables and figures
//! report.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<40} iters {:>5}  mean {:>12}  median {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.min_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: samples[samples.len() / 2],
        min_ns: samples[0],
    };
    res.report();
    res
}

/// Markdown-style table printer for paper-row reproduction.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", line(&sep));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-sum", 2, 10, || (0..1000u64).sum::<u64>());
        assert!(r.min_ns > 0.0 && r.mean_ns >= r.min_ns);
    }

    #[test]
    fn table_prints_aligned() {
        let mut t = Table::new(&["Model", "Acc"]);
        t.row(vec!["MobileNetV2".into(), "74.4%".into()]);
        t.print();
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500.0ns");
        assert!(fmt_ns(2.5e6).ends_with("ms"));
    }
}
