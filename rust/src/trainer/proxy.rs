//! Proxy-task training driven entirely from rust through PJRT.
//!
//! One supernet artifact serves both search modes (paper §3.5):
//!
//! * **multi-trial** ([`ProxyTrainer::train_child`]): fresh weights per
//!   sampled child (`supernet_init` with a per-trial seed), a fixed mask,
//!   N SGD steps with the paper's warmup+cosine schedule, accuracy on a
//!   held-out batch — the "child program" of MnasNet-style search;
//! * **oneshot** ([`SupernetState`]): persistent shared weights, masks
//!   re-sampled per step by the controller, interleaved weight/controller
//!   updates — the ProxylessNAS/TuNAS regime.
//!
//! Python never runs here: batches are generated in rust (`data`),
//! pushed as literals, and the train-step HLO (which embeds the L1
//! pallas matmul in its head + its VJP) does the rest.

use anyhow::Result;

use crate::data::{DataGen, CHANNELS, IMG};
use crate::nas::{NasSpace, NasSpaceId, ProxyMasks};
use crate::runtime::{lit_f32, lit_f32_scalar, lit_i32, lit_i32_scalar, scalar_f32, Runtime};

/// Learning-rate schedule (the paper's warmup + cosine shape, §4.1,
/// re-tuned for the proxy's Adam optimizer): linear warmup for the
/// first 20% of steps, cosine decay to 0 for the rest.
pub fn lr_at(step: usize, total: usize, lr0: f32) -> f32 {
    let w = (total / 5).max(1); // 1-of-5 epochs warmup
    if step < w {
        lr0 * (step + 1) as f32 / w as f32
    } else {
        let t = (step - w) as f32 / (total - w).max(1) as f32;
        lr0 * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

/// Drives the supernet artifacts for child training / oneshot search.
pub struct ProxyTrainer {
    pub rt: Runtime,
    space: NasSpace,
    train_batch: usize,
    eval_batch: usize,
    datagen: DataGen,
    eval_x: Vec<f32>,
    eval_y: Vec<i32>,
    /// Default proxy-training budget (steps) and peak LR.
    pub steps: usize,
    pub lr0: f32,
}

impl ProxyTrainer {
    pub fn new(rt: Runtime, seed: u64) -> Result<Self> {
        let train_batch = rt.manifest.config_usize("TRAIN_BATCH")?;
        let eval_batch = rt.manifest.config_usize("EVAL_BATCH")?;
        let datagen = DataGen::new(seed);
        // Fixed held-out evaluation batch (same for every child).
        let mut eval_gen = DataGen::new(seed ^ 0xE7A1);
        let mut eval_x = vec![0.0; eval_batch * IMG * IMG * CHANNELS];
        let mut eval_y = vec![0; eval_batch];
        eval_gen.fill_batch(&mut eval_x, &mut eval_y);
        Ok(ProxyTrainer {
            rt,
            space: NasSpace::new(NasSpaceId::Proxy),
            train_batch,
            eval_batch,
            datagen,
            eval_x,
            eval_y,
            steps: 40,
            lr0: 0.008,
        })
    }

    pub fn space(&self) -> &NasSpace {
        &self.space
    }

    fn mask_literals(&self, m: &ProxyMasks) -> Result<[xla::Literal; 4]> {
        let nb = crate::nas::spaces::PROXY_BLOCKS;
        Ok([
            lit_f32(&m.opsel, &[nb, 2])?,
            lit_f32(&m.ksel, &[nb, 3])?,
            lit_f32(&m.expmask, &[nb, crate::nas::spaces::PROXY_CEXP_MAX])?,
            lit_f32(&m.outmask, &[nb, crate::nas::spaces::PROXY_CMAX])?,
        ])
    }

    /// Multi-trial fidelity: train a fresh child with this decision
    /// vector for `self.steps` steps; return held-out accuracy.
    pub fn train_child(&mut self, decisions: &[usize], seed: i32) -> Result<f32> {
        let masks = self.space.decode_masks(decisions);
        let ml = self.mask_literals(&masks)?;
        let init = self.rt.run("supernet_init", &[&lit_i32_scalar(seed)])?;
        let mut it = init.into_iter();
        let mut params = it.next().unwrap();
        let mut m = it.next().unwrap();
        let mut v = it.next().unwrap();

        let mut x = vec![0.0f32; self.train_batch * IMG * IMG * CHANNELS];
        let mut y = vec![0i32; self.train_batch];
        for step in 0..self.steps {
            self.datagen.fill_batch(&mut x, &mut y);
            let lr = lr_at(step, self.steps, self.lr0);
            let xb = lit_f32(&x, &[self.train_batch, IMG, IMG, CHANNELS])?;
            let yb = lit_i32(&y, &[self.train_batch])?;
            let out = self.rt.run(
                "supernet_train",
                &[
                    &params,
                    &m,
                    &v,
                    &lit_i32_scalar(step as i32),
                    &xb,
                    &yb,
                    &ml[0],
                    &ml[1],
                    &ml[2],
                    &ml[3],
                    &lit_f32_scalar(lr),
                ],
            )?;
            let mut it = out.into_iter();
            params = it.next().unwrap();
            m = it.next().unwrap();
            v = it.next().unwrap();
        }
        self.eval_params(&params, &ml)
    }

    fn eval_params(&mut self, params: &xla::Literal, ml: &[xla::Literal; 4]) -> Result<f32> {
        // (borrowed-literal path: no parameter copies)
        let xb = lit_f32(&self.eval_x, &[self.eval_batch, IMG, IMG, CHANNELS])?;
        let yb = lit_i32(&self.eval_y, &[self.eval_batch])?;
        let out = self.rt.run(
            "supernet_eval",
            &[params, &xb, &yb, &ml[0], &ml[1], &ml[2], &ml[3]],
        )?;
        scalar_f32(&out[1])
    }

    /// Start a persistent shared-weight supernet (oneshot mode).
    pub fn init_supernet(&mut self, seed: i32) -> Result<SupernetState> {
        let init = self.rt.run("supernet_init", &[&lit_i32_scalar(seed)])?;
        let mut it = init.into_iter();
        Ok(SupernetState {
            params: it.next().unwrap(),
            m: it.next().unwrap(),
            v: it.next().unwrap(),
            steps_done: 0,
        })
    }

    /// One shared-weight training step under the given masks. Returns
    /// (train loss, train accuracy) of the sampled subnetwork.
    pub fn supernet_step(
        &mut self,
        st: &mut SupernetState,
        decisions: &[usize],
        lr: f32,
    ) -> Result<(f32, f32)> {
        let masks = self.space.decode_masks(decisions);
        let ml = self.mask_literals(&masks)?;
        let mut x = vec![0.0f32; self.train_batch * IMG * IMG * CHANNELS];
        let mut y = vec![0i32; self.train_batch];
        self.datagen.fill_batch(&mut x, &mut y);
        let xb = lit_f32(&x, &[self.train_batch, IMG, IMG, CHANNELS])?;
        let yb = lit_i32(&y, &[self.train_batch])?;
        let out = self.rt.run(
            "supernet_train",
            &[
                &st.params,
                &st.m,
                &st.v,
                &lit_i32_scalar(st.steps_done as i32),
                &xb,
                &yb,
                &ml[0],
                &ml[1],
                &ml[2],
                &ml[3],
                &lit_f32_scalar(lr),
            ],
        )?;
        let mut it = out.into_iter();
        st.params = it.next().unwrap();
        st.m = it.next().unwrap();
        st.v = it.next().unwrap();
        st.steps_done += 1;
        let loss = scalar_f32(&it.next().unwrap())?;
        let acc = scalar_f32(&it.next().unwrap())?;
        Ok((loss, acc))
    }

    /// Held-out accuracy of one subnetwork under shared weights.
    pub fn supernet_eval(&mut self, st: &SupernetState, decisions: &[usize]) -> Result<f32> {
        let masks = self.space.decode_masks(decisions);
        let ml = self.mask_literals(&masks)?;
        self.eval_params(&st.params, &ml)
    }
}

/// Persistent shared weights of the oneshot supernet.
pub struct SupernetState {
    params: xla::Literal,
    m: xla::Literal,
    v: xla::Literal,
    pub steps_done: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_warms_up_then_decays() {
        let total = 30;
        assert!(lr_at(0, total, 0.1) < 0.04);
        let peak = lr_at(total / 5, total, 0.1);
        assert!(peak > 0.09, "peak {peak}");
        assert!(lr_at(total - 1, total, 0.1) < 0.01);
        // Monotone up then down.
        for s in 1..(total / 5) {
            assert!(lr_at(s, total, 0.1) >= lr_at(s - 1, total, 0.1));
        }
        for s in (total / 5 + 1)..total {
            assert!(lr_at(s, total, 0.1) <= lr_at(s - 1, total, 0.1));
        }
    }
}
