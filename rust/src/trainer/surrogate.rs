//! Calibrated analytic accuracy surrogate (DESIGN.md §Substitutions 3).
//!
//! The paper trains every sampled child on ImageNet for 5 epochs — ~10^4
//! GPU-hours across a search. The surrogate replaces *only* that
//! accuracy oracle for the large paper-figure sweeps (latency / energy /
//! area always come from the real simulator); the end-to-end example and
//! small searches use real proxy-task training instead.
//!
//! Functional form: accuracy saturates in *effective capacity* — a
//! MAC count where k×k full convolutions are discounted (their extra
//! weights are redundant relative to depthwise+pointwise factorization),
//! which is exactly why Fused-IBN trades well on latency but is not an
//! accuracy free-lunch. Fitted against the published points:
//!
//! | model            | capacity | formula | paper top-1 |
//! |------------------|----------|---------|-------------|
//! | MobileNetV2      | ~296 M   | 74.4    | 74.4        |
//! | MnasNet-B1       | ~311 M   | 74.6    | 74.5        |
//! | EfficientNet-B1  | ~672 M   | 76.8    | 76.9        |
//! | EfficientNet-B3  | ~1717 M  | 78.8    | 78.8        |

use crate::model::{Layer, NetworkIr};
use crate::util::Rng;

/// Effective capacity in MACs: full k>1 convs over real input channels
/// count at 35% (weight redundancy vs the factorized depthwise +
/// pointwise form — fused-IBN trades well on latency but is not an
/// accuracy free-lunch, paper §3.2.2).
pub fn effective_capacity(net: &NetworkIr) -> f64 {
    net.layers
        .iter()
        .map(|l| {
            let m = l.macs() as f64;
            match l.op {
                Layer::Conv2d { kh, cin, .. } if kh > 1 && cin > 3 => 0.35 * m,
                _ => m,
            }
        })
        .sum()
}

fn arch_noise(net: &NetworkIr, seed: u64) -> f64 {
    // Deterministic per-architecture jitter: hash the layer list.
    let mut h = seed ^ 0x9E37_79B9_7F4A_7C15;
    for l in &net.layers {
        let sig = l.macs() ^ (l.params() << 1) ^ ((l.in_h as u64) << 40);
        h = h.rotate_left(13) ^ sig.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    }
    Rng::new(h).normal() as f64
}

/// ImageNet top-1 (%) surrogate for the 224-input spaces.
pub fn imagenet_accuracy(net: &NetworkIr, seed: u64) -> f64 {
    let cap_m = (effective_capacity(net) / 1e6).max(1.0);
    let mut acc = 83.0 - 84.3 * cap_m.powf(-0.4);
    if net.layers.iter().any(|l| matches!(l.op, Layer::SePool { .. })) {
        acc += 0.4; // squeeze-excite helps accuracy (paper §1)
    }
    if net.layers.iter().any(|l| matches!(l.op, Layer::Swish { .. })) {
        acc += 0.2; // swish helps accuracy
    }
    (acc + 0.15 * arch_noise(net, seed)).clamp(20.0, 85.0)
}

/// Proxy-space (8x8 synthetic) accuracy surrogate in [0, 1] — used when
/// a proxy-space sweep wants to skip real training.
pub fn proxy_accuracy(net: &NetworkIr, seed: u64) -> f64 {
    let cap_m = (effective_capacity(net) / 1e6).max(0.05);
    let acc = 0.99 - 0.27 * cap_m.powf(-0.4);
    (acc + 0.01 * arch_noise(net, seed)).clamp(0.1, 0.97)
}

/// Cityscapes-style mIOU (%) surrogate for the segmentation transfer
/// (Table 4): same capacity law, segmentation ceiling, and a bonus for
/// preserved late-stage spatial detail (wide late stages help dense
/// prediction).
pub fn segmentation_miou(net: &NetworkIr, seed: u64) -> f64 {
    let cap_m = (effective_capacity(net) / 1e6).max(1.0);
    let mut miou = 78.0 - 46.0 * cap_m.powf(-0.4);
    // Dense prediction benefits from fused (full-conv) early stages:
    // better low-level features at high resolution.
    let fused_early = net
        .layers
        .iter()
        .take(net.layers.len() / 3)
        .any(|l| matches!(l.op, Layer::Conv2d { kh, cin, .. } if kh > 1 && cin > 3));
    if fused_early {
        miou += 0.8;
    }
    (miou + 0.25 * arch_noise(net, seed)).clamp(20.0, 80.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::baselines;

    #[test]
    fn matches_published_calibration_points() {
        let cases = [
            (baselines::mobilenet_v2(1.0), 74.4, 0.6),
            (baselines::mnasnet_b1(), 74.5, 0.6),
            (baselines::efficientnet(1, false), 76.9, 0.6),
            (baselines::efficientnet(3, false), 78.8, 0.6),
            (baselines::efficientnet(0, false), 74.7, 1.0),
        ];
        for (net, want, tol) in cases {
            let got = imagenet_accuracy(&net, 0);
            assert!((got - want).abs() < tol, "{}: {got} vs paper {want}", net.name);
        }
    }

    #[test]
    fn capacity_discounts_fused_convs() {
        let manual = baselines::manual_edgetpu(false);
        let cap = effective_capacity(&manual);
        let macs = manual.total_macs() as f64;
        assert!(cap < 0.8 * macs, "fused convs must be discounted ({cap} vs {macs})");
        // ... but Manual-EdgeTPU still lands near its published 76.2%.
        let acc = imagenet_accuracy(&manual, 0);
        assert!((75.0..78.0).contains(&acc), "manual-edgetpu acc {acc}");
    }

    #[test]
    fn monotone_in_scale_with_diminishing_returns() {
        let a0 = imagenet_accuracy(&baselines::efficientnet(0, false), 1);
        let a1 = imagenet_accuracy(&baselines::efficientnet(1, false), 1);
        let a3 = imagenet_accuracy(&baselines::efficientnet(3, false), 1);
        assert!(a0 < a1 && a1 < a3);
        assert!((a1 - a0) > (a3 - a1) * 0.5); // saturation
    }

    #[test]
    fn noise_is_deterministic_and_small() {
        let net = baselines::mobilenet_v2(1.0);
        assert_eq!(imagenet_accuracy(&net, 7), imagenet_accuracy(&net, 7));
        let spread = (imagenet_accuracy(&net, 1) - imagenet_accuracy(&net, 2)).abs();
        assert!(spread < 1.5, "noise spread {spread}");
    }

    #[test]
    fn proxy_accuracy_in_unit_range_and_monotone() {
        use crate::nas::{NasSpace, NasSpaceId};
        let sp = NasSpace::new(NasSpaceId::Proxy);
        let small = sp.decode(&vec![0; sp.num_decisions()]);
        let big_d: Vec<usize> = sp.specs().iter().map(|s| s.cardinality - 1).collect();
        let big = sp.decode(&big_d);
        let a_small = proxy_accuracy(&small, 3);
        let a_big = proxy_accuracy(&big, 3);
        assert!((0.1..0.97).contains(&a_small));
        assert!(a_big > a_small);
    }

    #[test]
    fn segmentation_scale_matches_table4() {
        let b0 = segmentation_miou(&baselines::efficientnet(0, false), 0);
        assert!((71.0..76.0).contains(&b0), "B0 seg {b0} (paper 73.8)");
        let manual_m = segmentation_miou(&baselines::manual_edgetpu(true), 0);
        assert!(manual_m > 73.0, "Manual-M {manual_m} (paper 74.4)");
    }
}
