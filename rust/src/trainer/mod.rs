//! Child-model evaluation: real proxy-task training through the AOT
//! supernet artifacts ([`proxy`]) and the calibrated analytic accuracy
//! surrogate ([`surrogate`]) used by the large paper-figure sweeps
//! (DESIGN.md §Substitutions item 3).

pub mod proxy;
pub mod surrogate;

pub use proxy::{ProxyTrainer, SupernetState};
