//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! This is the only boundary between L3 and the L2/L1 programs: the
//! manifest (`artifacts/manifest.json`, written by aot.py) is the single
//! source of truth for program signatures and shared configuration
//! constants. Executables are compiled once on first use and cached.
//!
//! Interchange is HLO *text* — see aot.py for why serialized protos are
//! rejected by xla_extension 0.5.1.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use manifest::{Dtype, Manifest, ProgramSpec, TensorSpec};

/// A loaded artifact directory + PJRT CPU client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load `artifacts/` (manifest + lazy HLO compilation).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest = Manifest::parse(&text).context("parsing manifest.json")?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, dir, exes: HashMap::new() })
    }

    /// Default artifact location: `$NAHAS_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var("NAHAS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .programs
            .get(name)
            .with_context(|| format!("program '{name}' not in manifest"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("loading {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a program by manifest name. Inputs are *borrowed* (no
    /// literal copies on the hot path — a supernet train step carries
    /// ~6.6 MB of parameter/optimizer state per call, and cloning it
    /// dominated the request loop before this signature; see
    /// EXPERIMENTS.md §Perf). Inputs are validated against the manifest
    /// signature; the 1-tuple output (return_tuple=True) is unwrapped
    /// into its elements.
    pub fn run(&mut self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .programs
            .get(name)
            .with_context(|| format!("program '{name}' not in manifest"))?
            .clone();
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        for (lit, ts) in inputs.iter().zip(&spec.inputs) {
            let n = lit.element_count();
            let want: usize = ts.shape.iter().product::<usize>().max(1);
            if n != want {
                bail!(
                    "{name}: input '{}' has {} elements, manifest says {:?} ({} elements)",
                    ts.name,
                    n,
                    ts.shape,
                    want
                );
            }
        }
        self.ensure_compiled(name)?;
        let exe = self.exes.get(name).unwrap();
        let out = exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e:?}"))?;
        let elems = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name} result: {e:?}"))?;
        if elems.len() != spec.outputs.len() {
            bail!(
                "{name}: manifest promises {} outputs, tuple has {}",
                spec.outputs.len(),
                elems.len()
            );
        }
        Ok(elems)
    }

    /// Number of programs available.
    pub fn num_programs(&self) -> usize {
        self.manifest.programs.len()
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let want: usize = shape.iter().product::<usize>().max(1);
    if data.len() != want {
        bail!("lit_f32: {} elements for shape {:?}", data.len(), shape);
    }
    let l = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    l.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let want: usize = shape.iter().product::<usize>().max(1);
    if data.len() != want {
        bail!("lit_i32: {} elements for shape {:?}", data.len(), shape);
    }
    let l = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    l.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// Scalar literals.
pub fn lit_f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Fetch an f32 literal's contents.
pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))
}

/// Fetch a scalar f32.
pub fn scalar_f32(l: &xla::Literal) -> Result<f32> {
    l.get_first_element::<f32>().map_err(|e| anyhow::anyhow!("scalar: {e:?}"))
}
