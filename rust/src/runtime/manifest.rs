//! `artifacts/manifest.json` schema: program signatures + shared config.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::nas::spaces;
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype '{other}'"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub programs: BTreeMap<String, ProgramSpec>,
    pub supernet_param_count: usize,
    pub costmodel_param_count: usize,
    /// Raw config block (python/compile/config.py constants).
    pub config: BTreeMap<String, Json>,
}

fn tensor_specs(arr: &Json) -> Result<Vec<TensorSpec>> {
    arr.as_arr()
        .ok_or_else(|| anyhow!("specs not an array"))?
        .iter()
        .map(|s| {
            Ok(TensorSpec {
                name: s
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("spec missing name"))?
                    .to_string(),
                dtype: Dtype::parse(
                    s.get("dtype").and_then(Json::as_str).ok_or_else(|| anyhow!("dtype"))?,
                )?,
                shape: s
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("dim")))
                    .collect::<Result<Vec<_>>>()?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let mut programs = BTreeMap::new();
        for (name, p) in
            j.get("programs").and_then(Json::as_obj).context("programs block")?
        {
            programs.insert(
                name.clone(),
                ProgramSpec {
                    file: p
                        .get("file")
                        .and_then(Json::as_str)
                        .context("program file")?
                        .to_string(),
                    inputs: tensor_specs(p.get("inputs").context("inputs")?)?,
                    outputs: tensor_specs(p.get("outputs").context("outputs")?)?,
                },
            );
        }
        let m = Manifest {
            programs,
            supernet_param_count: j
                .get("supernet_param_count")
                .and_then(Json::as_usize)
                .context("supernet_param_count")?,
            costmodel_param_count: j
                .get("costmodel_param_count")
                .and_then(Json::as_usize)
                .context("costmodel_param_count")?,
            config: j.get("config").and_then(Json::as_obj).context("config")?.clone(),
        };
        m.check_proxy_consts()?;
        Ok(m)
    }

    /// Assert the python-side constants match the rust mirrors — a
    /// drifted constant would silently mis-map masks onto the supernet.
    pub fn check_proxy_consts(&self) -> Result<()> {
        let get = |k: &str| -> Result<usize> {
            self.config.get(k).and_then(Json::as_usize).with_context(|| format!("config {k}"))
        };
        let checks = [
            ("BLOCKS", spaces::PROXY_BLOCKS),
            ("IMG", spaces::PROXY_IMG),
            ("CMAX", spaces::PROXY_CMAX),
            ("CEXP_MAX", spaces::PROXY_CEXP_MAX),
            ("STEM_CH", spaces::PROXY_STEM),
            ("MAX_EXPANSION", spaces::PROXY_MAX_EXPANSION),
            ("FEATURE_DIM", crate::costmodel::FEATURE_DIM),
        ];
        for (key, want) in checks {
            let got = get(key)?;
            if got != want {
                bail!("manifest config {key}={got} but rust expects {want}");
            }
        }
        let widths = self
            .config
            .get("WIDTHS")
            .and_then(Json::as_arr)
            .context("config WIDTHS")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect::<Vec<_>>();
        if widths != spaces::PROXY_WIDTHS.to_vec() {
            bail!("manifest WIDTHS {widths:?} != rust {:?}", spaces::PROXY_WIDTHS);
        }
        Ok(())
    }

    pub fn config_usize(&self, key: &str) -> Result<usize> {
        self.config.get(key).and_then(Json::as_usize).with_context(|| format!("config {key}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest(feature_dim: usize) -> String {
        format!(
            r#"{{
          "config": {{"BLOCKS": 5, "IMG": 8, "CMAX": 32, "CEXP_MAX": 192,
                     "STEM_CH": 8, "MAX_EXPANSION": 6, "FEATURE_DIM": {feature_dim},
                     "WIDTHS": [8, 16, 16, 32, 32], "TRAIN_BATCH": 32}},
          "supernet_param_count": 1000,
          "costmodel_param_count": 500,
          "programs": {{
            "p": {{"file": "p.hlo.txt",
                   "inputs": [{{"name": "x", "dtype": "f32", "shape": [2, 3]}}],
                   "outputs": [{{"name": "y", "dtype": "f32", "shape": []}}]}}
          }}
        }}"#
        )
    }

    #[test]
    fn parses_and_checks_consts() {
        let m = Manifest::parse(&mini_manifest(crate::costmodel::FEATURE_DIM)).unwrap();
        assert_eq!(m.supernet_param_count, 1000);
        let p = &m.programs["p"];
        assert_eq!(p.inputs[0].shape, vec![2, 3]);
        assert_eq!(p.inputs[0].dtype, Dtype::F32);
        assert_eq!(m.config_usize("TRAIN_BATCH").unwrap(), 32);
    }

    #[test]
    fn rejects_drifted_constants() {
        assert!(Manifest::parse(&mini_manifest(9999)).is_err());
    }
}
