//! Simulator-as-a-service (paper §4.1: "We deployed both of these
//! estimators as a service where multiple NAHAS clients can send
//! parallel requests").
//!
//! Wire protocol: newline-delimited JSON over TCP.
//!
//! ```text
//! -> {"space": "efficientnet", "nas": [..], "hw": [..], "task": "cls"}
//! <- {"valid": true, "latency_ms": 0.41, "energy_mj": 0.9,
//!     "area_mm2": 79.2, "utilization": 0.21}
//! ```
//!
//! The server is a std-thread TCP accept loop (tokio is not vendored in
//! this offline build); each connection gets a worker thread, which is
//! exactly the paper's "parallel requests" scale-out on one box.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::accel::simulate_network;
use crate::has::{validate, HasSpace};
use crate::nas::{NasSpace, NasSpaceId};
use crate::search::evaluator::segmentation_variant;
use crate::util::json::{obj, Json};

fn space_by_name(name: &str) -> Option<NasSpaceId> {
    match name {
        "mobilenetv2" | "s1" => Some(NasSpaceId::MobileNetV2),
        "efficientnet" | "s2" => Some(NasSpaceId::EfficientNet),
        "evolved" | "s3" => Some(NasSpaceId::Evolved),
        "proxy" => Some(NasSpaceId::Proxy),
        _ => None,
    }
}

/// Handle one request object, producing the response object.
pub fn handle_request(req: &Json) -> Json {
    let fail = |msg: &str| obj(vec![("valid", false.into()), ("error", msg.into())]);
    let Some(space_name) = req.get("space").and_then(Json::as_str) else {
        return fail("missing 'space'");
    };
    let Some(id) = space_by_name(space_name) else {
        return fail("unknown space");
    };
    let space = NasSpace::new(id);
    let to_vec = |key: &str| -> Option<Vec<usize>> {
        req.get(key)?.as_arr()?.iter().map(|v| v.as_usize()).collect()
    };
    let Some(nas_d) = to_vec("nas") else { return fail("missing 'nas'") };
    let Some(has_d) = to_vec("hw") else { return fail("missing 'hw'") };
    if nas_d.len() != space.num_decisions() || has_d.len() != 7 {
        return fail("decision vector length");
    }
    if nas_d
        .iter()
        .zip(space.specs())
        .any(|(d, s)| *d >= s.cardinality)
    {
        return fail("nas decision out of range");
    }
    let has = HasSpace::new();
    if has_d.iter().zip(has.specs()).any(|(d, s)| *d >= s.cardinality) {
        return fail("hw decision out of range");
    }
    let cfg = has.decode(&has_d);
    if let Err(e) = validate(&cfg) {
        return obj(vec![("valid", false.into()), ("error", e.as_str().into())]);
    }
    let mut net = space.decode(&nas_d);
    if req.get("task").and_then(Json::as_str) == Some("seg") {
        net = segmentation_variant(&net);
    }
    match simulate_network(&cfg, &net) {
        Err(e) => obj(vec![("valid", false.into()), ("error", e.to_string().as_str().into())]),
        Ok(rep) => obj(vec![
            ("valid", true.into()),
            ("latency_ms", rep.latency_ms.into()),
            ("energy_mj", rep.energy_mj.into()),
            ("area_mm2", rep.area_mm2.into()),
            ("utilization", rep.utilization.into()),
        ]),
    }
}

/// Running server handle.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    pub requests: Arc<AtomicU64>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    pub fn spawn(addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).context("binding simulator service")?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let requests = Arc::new(AtomicU64::new(0));
        let (stop2, req2) = (stop.clone(), requests.clone());
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let req3 = req2.clone();
                        // Detached worker: it exits when the client hangs
                        // up (joining here would deadlock on clients that
                        // outlive the server).
                        std::thread::spawn(move || serve_conn(stream, req3));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Server { addr: local, stop, requests, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_conn(stream: TcpStream, requests: Arc<AtomicU64>) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Json::parse(&line) {
            Err(e) => obj(vec![("valid", false.into()), ("error", e.as_str().into())]),
            Ok(req) => handle_request(&req),
        };
        requests.fetch_add(1, Ordering::Relaxed);
        if writeln!(writer, "{}", resp.to_string()).is_err() {
            break;
        }
    }
}

/// Client for the simulator service.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to simulator service")?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Query one (space, nas, hw) sample; returns the raw response.
    pub fn query(
        &mut self,
        space: &str,
        nas_d: &[usize],
        has_d: &[usize],
        seg: bool,
    ) -> Result<Json> {
        let arr = |v: &[usize]| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
        let req = obj(vec![
            ("space", space.into()),
            ("nas", arr(nas_d)),
            ("hw", arr(has_d)),
            ("task", if seg { "seg".into() } else { "cls".into() }),
        ]);
        writeln!(self.writer, "{}", req.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow!("bad response: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn request_roundtrip_over_tcp() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let has = HasSpace::new();
        let mut rng = Rng::new(2);
        let nas_d = space.random(&mut rng);
        let resp = client.query("efficientnet", &nas_d, &has.baseline_decisions(), false).unwrap();
        assert_eq!(resp.get("valid"), Some(&Json::Bool(true)));
        assert!(resp.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);
        server.stop();
    }

    #[test]
    fn parallel_clients_all_served() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let mut joins = Vec::new();
        for t in 0..4 {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let space = NasSpace::new(NasSpaceId::MobileNetV2);
                let has = HasSpace::new();
                let mut rng = Rng::new(t);
                for _ in 0..8 {
                    let nas_d = space.random(&mut rng);
                    let resp = client
                        .query("mobilenetv2", &nas_d, &has.baseline_decisions(), false)
                        .unwrap();
                    assert!(resp.get("valid").is_some());
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(server.requests.load(Ordering::Relaxed), 32);
        server.stop();
    }

    #[test]
    fn malformed_requests_get_errors_not_crashes() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        writeln!(stream, "this is not json").unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("valid"), Some(&Json::Bool(false)));
        // Valid JSON, bad payload.
        writeln!(stream, "{{\"space\": \"nope\"}}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert_eq!(Json::parse(&line).unwrap().get("valid"), Some(&Json::Bool(false)));
        server.stop();
    }
}

/// Remote evaluator: implements the search-side [`crate::search::Evaluator`]
/// against a simulator service — the paper's deployment where "multiple
/// NAHAS clients send parallel requests" to the estimator farm. Accuracy
/// still comes from the local surrogate (the paper's clients likewise
/// train locally and query the service only for hardware metrics).
pub struct RemoteEval {
    client: Client,
    space_name: &'static str,
    space: NasSpace,
    seed: u64,
    seg: bool,
}

impl RemoteEval {
    pub fn connect(addr: &str, id: NasSpaceId, seed: u64) -> Result<Self> {
        let space_name = match id {
            NasSpaceId::MobileNetV2 => "mobilenetv2",
            NasSpaceId::EfficientNet => "efficientnet",
            NasSpaceId::Evolved => "evolved",
            NasSpaceId::Proxy => "proxy",
        };
        Ok(RemoteEval {
            client: Client::connect(addr)?,
            space_name,
            space: NasSpace::new(id),
            seed,
            seg: false,
        })
    }
}

impl crate::search::Evaluator for RemoteEval {
    fn evaluate(
        &mut self,
        nas_d: &[usize],
        has_d: &[usize],
    ) -> crate::search::EvalResult {
        let Ok(resp) = self.client.query(self.space_name, nas_d, has_d, self.seg) else {
            return crate::search::EvalResult::invalid();
        };
        if resp.get("valid") != Some(&Json::Bool(true)) {
            return crate::search::EvalResult::invalid();
        }
        let f = |k: &str| resp.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
        let net = self.space.decode(nas_d);
        let acc = match self.space.id {
            NasSpaceId::Proxy => crate::trainer::surrogate::proxy_accuracy(&net, self.seed),
            _ => crate::trainer::surrogate::imagenet_accuracy(&net, self.seed) / 100.0,
        };
        crate::search::EvalResult {
            acc,
            latency_ms: f("latency_ms"),
            energy_mj: f("energy_mj"),
            area_mm2: f("area_mm2"),
            valid: true,
        }
    }
}

#[cfg(test)]
mod remote_tests {
    use super::*;
    use crate::search::joint::JointLayout;
    use crate::search::ppo::PpoController;
    use crate::search::{joint_search, Evaluator, RewardCfg, SearchCfg};

    #[test]
    fn remote_eval_matches_local_simulator() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut remote =
            RemoteEval::connect(&server.addr.to_string(), NasSpaceId::EfficientNet, 3).unwrap();
        let mut local =
            crate::search::SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
        let has = HasSpace::new();
        let mut rng = crate::util::Rng::new(4);
        for _ in 0..8 {
            let nas_d = local.space.random(&mut rng);
            let r = remote.evaluate(&nas_d, &has.baseline_decisions());
            let l = local.evaluate(&nas_d, &has.baseline_decisions());
            assert_eq!(r.valid, l.valid);
            if r.valid {
                assert!((r.latency_ms - l.latency_ms).abs() < 1e-9);
                assert!((r.energy_mj - l.energy_mj).abs() < 1e-9);
                assert!((r.acc - l.acc).abs() < 1e-12);
            }
        }
        server.stop();
    }

    #[test]
    fn whole_search_over_the_wire() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let space = NasSpace::new(NasSpaceId::MobileNetV2);
        let has = HasSpace::new();
        let (cards, layout) = JointLayout::cards(&space, &has);
        let mut remote =
            RemoteEval::connect(&server.addr.to_string(), NasSpaceId::MobileNetV2, 5).unwrap();
        let mut ctl = PpoController::new(&cards);
        let cfg = SearchCfg::new(120, RewardCfg::latency(0.5), 5);
        let out = joint_search(&mut remote, &mut ctl, &layout, None, None, &cfg);
        assert!(out.best_feasible.is_some());
        assert!(server.requests.load(Ordering::Relaxed) >= 120);
        server.stop();
    }
}
