//! Simulator-as-a-service (paper §4.1: "We deployed both of these
//! estimators as a service where multiple NAHAS clients can send
//! parallel requests").
//!
//! Wire protocol: newline-delimited JSON over TCP.
//!
//! ```text
//! -> {"space": "efficientnet", "nas": [..], "hw": [..], "task": "cls"}
//! <- {"valid": true, "latency_ms": 0.41, "energy_mj": 0.9,
//!     "area_mm2": 79.2, "utilization": 0.21}
//! ```
//!
//! The server is a **non-blocking multiplexed event loop** (std-only;
//! tokio is not vendored in this offline build): an accept thread
//! deals connections round-robin onto a handful of readiness-polled
//! event threads, each multiplexing many non-blocking sockets —
//! buffering partial request lines, parsing complete ones, and
//! flushing responses as the sockets accept them — while a shared pool
//! of simulation workers drains the actual simulator work. One `nahas
//! serve` host therefore multiplexes hundreds of concurrent sessions
//! on a handful of OS threads (`--event-threads`), and a stalled
//! (slow-loris) client costs one idle socket, never a hostage thread
//! (`tests/service_concurrency.rs`).
//!
//! Requests on one connection may be **pipelined**: a request carrying
//! an `"id"` field gets that id echoed in its response and is answered
//! in *completion* order — the client keeps many requests in flight on
//! one socket and matches responses by id ([`Client::query_pipelined`]).
//! Requests without an id keep the strict request/response contract:
//! responses come back in arrival order, so pre-pipelining clients work
//! unchanged.
//!
//! **Binary wire protocol** (negotiated, [`crate::util::codec`]): a new
//! client opens with one JSON hello line —
//! `{"hello": "nahas-wire", "version": 1}` — and a server that speaks
//! the binary protocol answers a JSON hello-ack and switches that
//! connection to length-prefixed binary frames
//! (`[u32 len][u8 kind][body]`): one `REQ_BATCH` frame carries a whole
//! pipelined burst (space/task bytes + varint-packed keys, replacing
//! per-key JSON text), and each `RESP_ITEM` frame ships the result as
//! raw f64 bits in completion order, matched by (batch, index). An old
//! server answers the hello with an ordinary error object (it is just
//! another well-formed request line to it), so the client falls back to
//! the JSON line protocol — old clients, old servers and mixed clusters
//! interoperate, and `--wire json` forces the fallback. Responses are
//! built from the same cached response strings on either protocol, so
//! binary results are **bit-identical** to JSON results by
//! construction.

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::accel::simulate_network;
use crate::has::{validate, HasSpace};
use crate::nas::{NasSpace, NasSpaceId};
use crate::search::evaluator::segmentation_variant;
use crate::search::store::CacheStore;
use crate::search::MemoCache;
use crate::util::codec::{self, put_f64_bits, put_u32, put_varint, ByteReader};
use crate::util::json::{obj, Json};

/// Protocol name in the hello line; anything else is not ours.
pub const WIRE_PROTO: &str = "nahas-wire";
/// Highest binary protocol version this build speaks.
pub const WIRE_VERSION: usize = 1;

/// Frame kind: one pipelined request burst (client -> server).
const FK_REQ_BATCH: u8 = 1;
/// Frame kind: one completed result (server -> client).
const FK_RESP_ITEM: u8 = 2;
/// Frame kind: a warm-cache handoff — a fingerprint plus a
/// [`crate::search::store`] segment stream of serve-cache entries to
/// install (client -> server). Sent to a joining cluster host so it
/// answers its first shard traffic from cache instead of simulating.
const FK_CACHE_INSTALL: u8 = 3;
/// Frame kind: the install verdict (server -> client).
const FK_CACHE_ACK: u8 = 4;

/// Which wire protocol a client asks for (and, post-negotiation, got).
/// `Binary` is a *preference*: the hello falls back to JSON against a
/// server that does not answer it, so it is always safe to request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Wire {
    Json,
    Binary,
}

fn space_by_name(name: &str) -> Option<NasSpaceId> {
    match name {
        "mobilenetv2" | "s1" => Some(NasSpaceId::MobileNetV2),
        "efficientnet" | "s2" => Some(NasSpaceId::EfficientNet),
        "evolved" | "s3" => Some(NasSpaceId::Evolved),
        "proxy" => Some(NasSpaceId::Proxy),
        _ => None,
    }
}

/// Binary-frame space byte (the discriminant [`serve_cache_key`] also
/// uses, so both protocols key the result cache identically).
fn space_by_byte(b: u8) -> Option<NasSpaceId> {
    match b as usize {
        x if x == NasSpaceId::MobileNetV2 as usize => Some(NasSpaceId::MobileNetV2),
        x if x == NasSpaceId::EfficientNet as usize => Some(NasSpaceId::EfficientNet),
        x if x == NasSpaceId::Evolved as usize => Some(NasSpaceId::Evolved),
        x if x == NasSpaceId::Proxy as usize => Some(NasSpaceId::Proxy),
        _ => None,
    }
}

/// Handle one request object, producing the response object.
pub fn handle_request(req: &Json) -> Json {
    let fail = |msg: &str| obj(vec![("valid", false.into()), ("error", msg.into())]);
    let Some(space_name) = req.get("space").and_then(Json::as_str) else {
        return fail("missing 'space'");
    };
    let Some(id) = space_by_name(space_name) else {
        return fail("unknown space");
    };
    let space = NasSpace::new(id);
    let to_vec = |key: &str| -> Option<Vec<usize>> {
        req.get(key)?.as_arr()?.iter().map(|v| v.as_usize()).collect()
    };
    let Some(nas_d) = to_vec("nas") else { return fail("missing 'nas'") };
    let Some(has_d) = to_vec("hw") else { return fail("missing 'hw'") };
    if nas_d.len() != space.num_decisions() || has_d.len() != 7 {
        return fail("decision vector length");
    }
    if nas_d
        .iter()
        .zip(space.specs())
        .any(|(d, s)| *d >= s.cardinality)
    {
        return fail("nas decision out of range");
    }
    let has = HasSpace::new();
    if has_d.iter().zip(has.specs()).any(|(d, s)| *d >= s.cardinality) {
        return fail("hw decision out of range");
    }
    let cfg = has.decode(&has_d);
    if let Err(e) = validate(&cfg) {
        return obj(vec![("valid", false.into()), ("error", e.as_str().into())]);
    }
    let mut net = space.decode(&nas_d);
    if req.get("task").and_then(Json::as_str) == Some("seg") {
        net = segmentation_variant(&net);
    }
    match simulate_network(&cfg, &net) {
        Err(e) => obj(vec![("valid", false.into()), ("error", e.to_string().as_str().into())]),
        Ok(rep) => obj(vec![
            ("valid", true.into()),
            ("latency_ms", rep.latency_ms.into()),
            ("energy_mj", rep.energy_mj.into()),
            ("area_mm2", rep.area_mm2.into()),
            ("utilization", rep.utilization.into()),
        ]),
    }
}

/// Server-side simulator result cache, shared by every connection
/// thread: responses are memoized on the (space, task, nas, hw) key,
/// so repeat queries — which the cluster tier's affinity routing makes
/// the common case, and which independent sweep runs re-issue — cost a
/// map lookup instead of a simulation. Everything the server computes
/// is a deterministic function of the key (the server never does
/// accuracy, only hardware metrics), so entries never expire; the
/// two-generation [`MemoCache`] bounds residency. With a persistent
/// [`CacheStore`] attached ([`ServeCache::with_store`], CLI
/// `--cache-dir`) the cache additionally survives the process: spilled
/// entries pre-load at startup and every fresh response is appended
/// (each append flushes — a serve process is usually killed, not
/// dropped).
pub struct ServeCache {
    cache: Mutex<MemoCache<String>>,
    /// The persistent spill file, behind its own lock so response
    /// lookups never wait on another connection's disk write.
    store: Mutex<Option<CacheStore<String>>>,
    /// Simulate requests answered from the cache.
    pub hits: AtomicU64,
    /// Simulate requests actually simulated (cacheable misses).
    pub sim_evals: AtomicU64,
    /// Entries installed by warm-cache handoffs (`CACHE_INSTALL`
    /// frames), cumulative.
    pub installed: AtomicU64,
}

const SERVE_CACHE_CAPACITY: usize = 64 * 1024;

impl Default for ServeCache {
    fn default() -> Self {
        ServeCache {
            cache: Mutex::new(MemoCache::new(SERVE_CACHE_CAPACITY)),
            store: Mutex::new(None),
            hits: AtomicU64::new(0),
            sim_evals: AtomicU64::new(0),
            installed: AtomicU64::new(0),
        }
    }
}

impl ServeCache {
    /// Warm-start from (and spill back to) a persistent store — the
    /// same format and staleness rules as the search-side broker
    /// cache, opened with
    /// [`crate::search::store::serve_fingerprint`]. The cache sizes up
    /// to the loaded inventory so no persisted response is evicted
    /// before it is ever re-served.
    pub fn with_store(mut store: CacheStore<String>) -> Self {
        let mut cache = MemoCache::new(SERVE_CACHE_CAPACITY.max(store.loaded_len()));
        for (key, resp) in store.take_loaded() {
            cache.insert(key, resp);
        }
        ServeCache {
            cache: Mutex::new(cache),
            store: Mutex::new(Some(store)),
            hits: AtomicU64::new(0),
            sim_evals: AtomicU64::new(0),
            installed: AtomicU64::new(0),
        }
    }

    /// Install a warm-handoff slice into the result cache (and the
    /// spill store, when one is attached — a handed-off entry is as
    /// durable as a simulated one). Later queries for these keys are
    /// cache hits, not simulations. Returns how many entries landed.
    pub fn install(&self, entries: Vec<(Vec<usize>, String)>) -> usize {
        let n = entries.len();
        {
            let mut cache = self.lock();
            for (key, resp) in &entries {
                cache.insert(key.clone(), resp.clone());
            }
        }
        if let Some(store) = self.store_lock().as_mut() {
            for (key, resp) in &entries {
                store.append(key, resp);
            }
        }
        self.installed.fetch_add(n as u64, Ordering::Relaxed);
        n
    }

    /// Resident entries in the result cache (the `cache_size` field of
    /// the `{"stats": true}` protocol).
    pub fn len(&self) -> usize {
        self.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Answer `req` (whose derived cache key is `key`) from the cache,
    /// simulating on a miss. The cache lock covers only the map
    /// operations — two connections racing on the same fresh key may
    /// both simulate it (deterministic, so harmless — at worst the
    /// spill file gets a duplicate line, and reloads are last-wins),
    /// but neither ever blocks behind another's simulation, and the
    /// spill file's own lock keeps cache hits off the disk-write path
    /// entirely.
    fn get_or_compute(&self, key: Vec<usize>, req: &Json) -> String {
        if let Some(resp) = self.lock().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return resp;
        }
        let resp = handle_request(req).to_string();
        self.sim_evals.fetch_add(1, Ordering::Relaxed);
        self.lock().insert(key.clone(), resp.clone());
        // Spill outside the cache lock (append flushes immediately).
        if let Some(store) = self.store_lock().as_mut() {
            store.append(&key, &resp);
        }
        resp
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, MemoCache<String>> {
        self.cache.lock().expect("serve cache poisoned")
    }

    fn store_lock(&self) -> std::sync::MutexGuard<'_, Option<CacheStore<String>>> {
        self.store.lock().expect("serve cache store poisoned")
    }
}

/// Derive the memo key of a simulate request: space id, task, and the
/// two decision vectors (nas length included, so the concatenation is
/// unambiguous). `None` for anything that is not a well-formed
/// simulate request — probes, stats queries and malformed payloads go
/// straight to [`handle_request`], uncached.
fn serve_cache_key(req: &Json) -> Option<Vec<usize>> {
    let id = space_by_name(req.get("space")?.as_str()?)?;
    let seg = req.get("task").and_then(Json::as_str) == Some("seg");
    let nas = req.get("nas")?.as_arr()?;
    let hw = req.get("hw")?.as_arr()?;
    let mut key = Vec::with_capacity(3 + nas.len() + hw.len());
    key.push(id as usize);
    key.push(seg as usize);
    key.push(nas.len());
    for v in nas.iter().chain(hw) {
        // Same numeric interpretation as handle_request's decoding, so
        // the key cannot alias two requests the handler would tell
        // apart.
        key.push(v.as_usize()?);
    }
    Some(key)
}

/// Tuning knobs for the event-loop server ([`Server::spawn_with_opts`],
/// CLI `nahas serve --event-threads N`).
#[derive(Clone, Copy, Debug)]
pub struct ServerOpts {
    /// Readiness-polling event-loop threads; each multiplexes its share
    /// of the open connections (socket IO + request framing + response
    /// ordering). A handful is plenty — connections cost a buffer, not
    /// a thread.
    pub event_threads: usize,
    /// Worker threads draining the shared simulation job queue (the
    /// CPU-bound half, kept off the event threads so a burst of
    /// expensive simulations never stalls socket readiness).
    pub sim_workers: usize,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts { event_threads: 2, sim_workers: 4 }
    }
}

/// How a finished response is released onto its connection.
enum RespTag {
    /// The request carried an `"id"`: the response (id echoed) is
    /// written in *completion* order — pipelining.
    Ident,
    /// No id: the response is held until every earlier no-id request
    /// on the connection has been answered — the strict
    /// request/response contract pre-pipelining clients rely on.
    Seq(u64),
}

/// One finished message staged for a connection: a JSON response line
/// or an already-framed binary block.
enum OutMsg {
    Line(String),
    Frame(Vec<u8>),
}

/// The half of a connection shared with the simulation workers:
/// finished responses parked here until the owning event thread drains
/// them onto the socket.
struct ConnShared {
    done: Mutex<Vec<(RespTag, OutMsg)>>,
}

/// One multiplexed connection, owned by exactly one event thread.
struct Conn {
    stream: TcpStream,
    read_buf: Vec<u8>,
    write_buf: Vec<u8>,
    shared: Arc<ConnShared>,
    /// Arrival sequence assigned to the next no-id request.
    next_seq: u64,
    /// Next no-id sequence allowed onto the socket (in-order release).
    next_release: u64,
    /// No-id responses finished out of order, held for release.
    held: BTreeMap<u64, String>,
    /// Requests handed to the sim pool and not yet drained back.
    outstanding: usize,
    /// Peer sent EOF; the connection closes once fully drained.
    eof: bool,
    /// Negotiated the binary protocol (bytes after the hello ack are
    /// length-prefixed frames, not JSON lines).
    binary: bool,
}

/// The per-item half of a binary `REQ_BATCH`: which (batch, index)
/// slot the `RESP_ITEM` frame must name.
#[derive(Clone, Copy)]
struct BinSlot {
    batch_id: u32,
    index: u64,
}

/// One queued simulation request (the CPU-bound half of a request
/// line or frame, computed off the event threads).
struct SimJob {
    shared: Arc<ConnShared>,
    tag: RespTag,
    id: Option<Json>,
    req: Json,
    /// `Some` when the request arrived as a binary frame item: the
    /// response ships as a `RESP_ITEM` frame instead of a JSON line.
    bin: Option<BinSlot>,
}

/// The shared simulation work queue the event threads feed.
struct SimPool {
    jobs: Mutex<VecDeque<SimJob>>,
    ready: Condvar,
}

/// Echo the request's `id` onto a response line (cached response
/// strings are stored id-less and shared; every requester gets its own
/// id back).
fn attach_id(resp: String, id: Option<Json>) -> String {
    let Some(id) = id else { return resp };
    match Json::parse(&resp) {
        Ok(Json::Obj(mut m)) => {
            m.insert("id".to_string(), id);
            Json::Obj(m).to_string()
        }
        _ => resp,
    }
}

/// Running server handle.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    /// Request lines served, of any kind (simulate, probe, stats).
    pub requests: Arc<AtomicU64>,
    /// The shared simulate-result cache and its hit/eval counters.
    pub cache: Arc<ServeCache>,
    sim_pool: Arc<SimPool>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    pub fn spawn(addr: &str) -> Result<Server> {
        Self::spawn_with_cache(addr, ServeCache::default())
    }

    /// [`Server::spawn`] with a caller-built result cache — e.g. one
    /// warm-started from a persistent store (`nahas serve
    /// --cache-dir`).
    pub fn spawn_with_cache(addr: &str, cache: ServeCache) -> Result<Server> {
        Self::spawn_with_opts(addr, cache, ServerOpts::default())
    }

    /// Bind and serve with explicit event-loop sizing.
    pub fn spawn_with_opts(addr: &str, cache: ServeCache, opts: ServerOpts) -> Result<Server> {
        let listener = TcpListener::bind(addr).context("binding simulator service")?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let requests = Arc::new(AtomicU64::new(0));
        let cache = Arc::new(cache);
        let sim_pool =
            Arc::new(SimPool { jobs: Mutex::new(VecDeque::new()), ready: Condvar::new() });
        let mut handles = Vec::new();

        // Per-event-thread intake queues; the accept thread deals new
        // connections round-robin.
        let event_threads = opts.event_threads.max(1);
        let intakes: Vec<Arc<Mutex<Vec<TcpStream>>>> =
            (0..event_threads).map(|_| Arc::new(Mutex::new(Vec::new()))).collect();

        {
            let (stop, intakes) = (stop.clone(), intakes.clone());
            handles.push(std::thread::spawn(move || {
                let mut next = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if stream.set_nonblocking(true).is_err() {
                                continue;
                            }
                            let _ = stream.set_nodelay(true);
                            intakes[next].lock().expect("intake poisoned").push(stream);
                            next = (next + 1) % intakes.len();
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }

        for intake in intakes {
            let (stop, requests, cache, sim_pool) =
                (stop.clone(), requests.clone(), cache.clone(), sim_pool.clone());
            handles.push(std::thread::spawn(move || {
                event_loop(&stop, &intake, &requests, &cache, &sim_pool)
            }));
        }

        for _ in 0..opts.sim_workers.max(1) {
            let (stop, cache, sim_pool) = (stop.clone(), cache.clone(), sim_pool.clone());
            handles.push(std::thread::spawn(move || sim_worker(&stop, &cache, &sim_pool)));
        }

        Ok(Server { addr: local, stop, requests, cache, sim_pool, handles })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.sim_pool.ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One event thread: multiplex every connection on the intake list —
/// drain finished responses onto write buffers, flush writable
/// sockets, read readable ones, frame complete request lines, answer
/// the cheap ones inline and queue the simulations. Never blocks on
/// any one socket, so a stalled client stalls only itself.
fn event_loop(
    stop: &AtomicBool,
    intake: &Mutex<Vec<TcpStream>>,
    requests: &AtomicU64,
    cache: &ServeCache,
    sim_pool: &SimPool,
) {
    let mut conns: Vec<Conn> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        for stream in intake.lock().expect("intake poisoned").drain(..) {
            conns.push(Conn {
                stream,
                read_buf: Vec::new(),
                write_buf: Vec::new(),
                shared: Arc::new(ConnShared { done: Mutex::new(Vec::new()) }),
                next_seq: 0,
                next_release: 0,
                held: BTreeMap::new(),
                outstanding: 0,
                eof: false,
                binary: false,
            });
        }
        let mut busy = false;
        conns.retain_mut(|conn| {
            let alive = tick_conn(conn, requests, cache, sim_pool, &mut busy);
            alive
                && !(conn.eof
                    && conn.outstanding == 0
                    && conn.held.is_empty()
                    && conn.write_buf.is_empty())
        });
        if !busy {
            // Nothing moved this pass: idle-poll instead of spinning.
            std::thread::sleep(std::time::Duration::from_micros(500));
        }
    }
}

/// Advance one connection without blocking. Returns `false` on a fatal
/// socket error (the connection is dropped, like a hangup mid-response
/// always was). Sets `busy` if any byte or response moved.
fn tick_conn(
    conn: &mut Conn,
    requests: &AtomicU64,
    cache: &ServeCache,
    sim_pool: &SimPool,
    busy: &mut bool,
) -> bool {
    // 1. Collect responses the sim workers finished.
    let done: Vec<(RespTag, OutMsg)> =
        std::mem::take(&mut *conn.shared.done.lock().expect("conn outbox poisoned"));
    for (tag, resp) in done {
        conn.outstanding -= 1;
        *busy = true;
        release(conn, tag, resp);
    }

    // 2. Flush as much of the write buffer as the socket accepts.
    while !conn.write_buf.is_empty() {
        match conn.stream.write(&conn.write_buf) {
            Ok(0) => return false,
            Ok(n) => {
                conn.write_buf.drain(..n);
                *busy = true;
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }

    // 3. Read whatever is waiting (bounded per tick so one firehose
    // client cannot starve its siblings on this event thread).
    if !conn.eof {
        let mut buf = [0u8; 4096];
        for _ in 0..16 {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.eof = true;
                    break;
                }
                Ok(n) => {
                    conn.read_buf.extend_from_slice(&buf[..n]);
                    *busy = true;
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
    }

    // 4. Frame and answer complete requests: binary frames after a
    // successful hello, JSON lines otherwise.
    if conn.binary {
        return tick_binary_frames(conn, requests, cache, sim_pool, busy);
    }
    while let Some(pos) = conn.read_buf.iter().position(|&b| b == b'\n') {
        let raw: Vec<u8> = conn.read_buf.drain(..=pos).collect();
        let line = String::from_utf8_lossy(&raw[..raw.len() - 1]).into_owned();
        if line.trim().is_empty() {
            continue;
        }
        *busy = true;
        match Json::parse(&line) {
            Err(e) => {
                // Parse errors are answered inline (no id to echo —
                // the line never became a request object).
                let resp = obj(vec![("valid", false.into()), ("error", e.as_str().into())])
                    .to_string();
                requests.fetch_add(1, Ordering::Relaxed);
                let tag = next_tag(conn, &None);
                release(conn, tag, OutMsg::Line(resp));
            }
            // Wire negotiation: a supported hello flips this
            // connection to binary framing; the ack goes out as the
            // last JSON line. Unsupported hellos get a plain error
            // line and the connection stays on JSON.
            Ok(req) if req.get("hello").is_some() => {
                requests.fetch_add(1, Ordering::Relaxed);
                let proto = req.get("hello").and_then(Json::as_str);
                let version = req.get("version").and_then(Json::as_usize).unwrap_or(0);
                let resp = if proto == Some(WIRE_PROTO) && version >= 1 {
                    conn.binary = true;
                    obj(vec![
                        ("hello", WIRE_PROTO.into()),
                        ("version", (version.min(WIRE_VERSION) as f64).into()),
                    ])
                } else {
                    obj(vec![("valid", false.into()), ("error", "unsupported hello".into())])
                };
                release(conn, RespTag::Ident, OutMsg::Line(resp.to_string()));
                if conn.binary {
                    // Anything already buffered past the hello line is
                    // binary frames.
                    return tick_binary_frames(conn, requests, cache, sim_pool, busy);
                }
            }
            // `{"stats": true}`: report this server's counters (used by
            // `nahas cluster-status` to surface cache effectiveness).
            // Cheap, so answered inline on the event thread; the
            // request count snapshot excludes the probe itself.
            Ok(req) if req.get("stats").is_some() => {
                let resp = obj(vec![
                    ("requests", (requests.load(Ordering::Relaxed) as f64).into()),
                    ("cache_hits", (cache.hits.load(Ordering::Relaxed) as f64).into()),
                    ("sim_evals", (cache.sim_evals.load(Ordering::Relaxed) as f64).into()),
                    ("cache_size", (cache.len() as f64).into()),
                    ("installed", (cache.installed.load(Ordering::Relaxed) as f64).into()),
                ]);
                requests.fetch_add(1, Ordering::Relaxed);
                let id = req.get("id").cloned();
                let resp = attach_id(resp.to_string(), id.clone());
                let tag = next_tag(conn, &id);
                release(conn, tag, OutMsg::Line(resp));
            }
            Ok(req) => {
                // Simulation work goes to the worker pool; the event
                // thread stays on socket duty.
                requests.fetch_add(1, Ordering::Relaxed);
                let id = req.get("id").cloned();
                let tag = next_tag(conn, &id);
                conn.outstanding += 1;
                sim_pool
                    .jobs
                    .lock()
                    .expect("sim pool poisoned")
                    .push_back(SimJob { shared: conn.shared.clone(), tag, id, req, bin: None });
                sim_pool.ready.notify_one();
            }
        }
    }
    true
}

/// Frame-split and dispatch the binary half of [`tick_conn`]. Returns
/// `false` on a malformed frame (the connection is dropped — there is
/// no way to resynchronize a binary stream after framing is lost).
fn tick_binary_frames(
    conn: &mut Conn,
    requests: &AtomicU64,
    cache: &ServeCache,
    sim_pool: &SimPool,
    busy: &mut bool,
) -> bool {
    loop {
        let (payload, total) = match codec::frame_payload(&conn.read_buf) {
            Ok(Some((payload, total))) => (payload.to_vec(), total),
            Ok(None) => return true,
            Err(_) => return false,
        };
        conn.read_buf.drain(..total);
        *busy = true;
        if !dispatch_binary_frame(conn, &payload, requests, cache, sim_pool) {
            return false;
        }
    }
}

/// Handle a `CACHE_INSTALL` frame inline on the event thread:
/// `[fingerprint][handoff segment stream]`. The whole stream decodes
/// before any entry installs — a mangled transfer acks `ok=false` and
/// installs *nothing*, so the host stays cold but consistent. A stale
/// fingerprint likewise refuses the lot: installing responses from a
/// different simulator version would make this host lie.
fn handle_cache_install(conn: &mut Conn, r: &mut ByteReader, cache: &ServeCache) -> bool {
    let Some(fingerprint) = r.str() else { return false };
    let ack = |ok: bool, installed: usize, msg: &str| {
        let mut body = Vec::with_capacity(8 + msg.len());
        body.push(FK_CACHE_ACK);
        body.push(ok as u8);
        put_varint(&mut body, installed as u64);
        codec::put_str(&mut body, msg);
        OutMsg::Frame(codec::frame(&body))
    };
    let want = crate::search::store::serve_fingerprint();
    let out = if fingerprint != want {
        ack(false, 0, &format!("fingerprint mismatch (got '{fingerprint}', want '{want}')"))
    } else {
        match crate::search::store::decode_handoff::<String>(r.take(r.remaining()).unwrap_or(&[]))
        {
            Ok(entries) => {
                let n = cache.install(entries);
                ack(true, n, "")
            }
            Err(why) => ack(false, 0, &why),
        }
    };
    release(conn, RespTag::Ident, out);
    true
}

/// Decode one client frame and queue its simulate jobs. `REQ_BATCH`
/// and `CACHE_INSTALL` are the valid client->server frames.
fn dispatch_binary_frame(
    conn: &mut Conn,
    payload: &[u8],
    requests: &AtomicU64,
    cache: &ServeCache,
    sim_pool: &SimPool,
) -> bool {
    let mut r = ByteReader::new(payload);
    let kind = r.u8();
    if kind == Some(FK_CACHE_INSTALL) {
        requests.fetch_add(1, Ordering::Relaxed);
        return handle_cache_install(conn, &mut r, cache);
    }
    if kind != Some(FK_REQ_BATCH) {
        return false;
    }
    let (Some(space_byte), Some(seg_byte), Some(nas_len), Some(batch_id), Some(count)) =
        (r.u8(), r.u8(), r.varint_usize(), r.u32(), r.varint_usize())
    else {
        return false;
    };
    let (Some(space_id), true) = (space_by_byte(space_byte), seg_byte <= 1) else {
        return false;
    };
    let space_name = service_space_name(space_id);
    let seg = seg_byte == 1;
    let arr = |v: &[usize]| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
    let mut jobs = Vec::with_capacity(count);
    for index in 0..count {
        let Some(key) = r.usize_slice() else { return false };
        if key.len() < nas_len {
            return false;
        }
        let (nas_d, has_d) = key.split_at(nas_len);
        // The same request object the JSON protocol would have parsed,
        // so the cache key, validation ladder and response string are
        // shared between protocols.
        let req = obj(vec![
            ("space", space_name.into()),
            ("nas", arr(nas_d)),
            ("hw", arr(has_d)),
            ("task", if seg { "seg".into() } else { "cls".into() }),
        ]);
        jobs.push(SimJob {
            shared: conn.shared.clone(),
            tag: RespTag::Ident,
            id: None,
            req,
            bin: Some(BinSlot { batch_id, index: index as u64 }),
        });
    }
    if !r.is_empty() {
        return false;
    }
    requests.fetch_add(jobs.len() as u64, Ordering::Relaxed);
    conn.outstanding += jobs.len();
    let mut q = sim_pool.jobs.lock().expect("sim pool poisoned");
    for job in jobs {
        q.push_back(job);
        sim_pool.ready.notify_one();
    }
    true
}

/// Encode one finished response string as a framed `RESP_ITEM`: the
/// result's f64s ship as raw bits parsed from the *same* cached
/// response string the JSON protocol serves, which is what makes the
/// two protocols bit-identical.
fn encode_resp_item(slot: BinSlot, resp: &str) -> Vec<u8> {
    let parsed = Json::parse(resp).ok();
    let field = |k: &str| -> f64 {
        parsed.as_ref().and_then(|j| j.get(k)).and_then(Json::as_f64).unwrap_or(f64::NAN)
    };
    let valid = parsed.as_ref().and_then(|j| j.get("valid")) == Some(&Json::Bool(true));
    let mut body = Vec::with_capacity(1 + 4 + 10 + 1 + 32);
    body.push(FK_RESP_ITEM);
    put_u32(&mut body, slot.batch_id);
    put_varint(&mut body, slot.index);
    body.push(valid as u8);
    for k in ["latency_ms", "energy_mj", "area_mm2", "utilization"] {
        put_f64_bits(&mut body, field(k));
    }
    codec::frame(&body)
}

/// Ordering tag for the next response on `conn`: id'd requests release
/// in completion order, id-less ones in arrival order.
fn next_tag(conn: &mut Conn, id: &Option<Json>) -> RespTag {
    if id.is_some() {
        RespTag::Ident
    } else {
        let seq = conn.next_seq;
        conn.next_seq += 1;
        RespTag::Seq(seq)
    }
}

/// Stage a finished response for writing, honoring its ordering tag.
/// Binary frames are always completion-ordered (the `RESP_ITEM` header
/// carries the slot), so only JSON lines ever hold a `Seq` tag.
fn release(conn: &mut Conn, tag: RespTag, resp: OutMsg) {
    let resp = match resp {
        OutMsg::Frame(bytes) => {
            conn.write_buf.extend_from_slice(&bytes);
            return;
        }
        OutMsg::Line(line) => line,
    };
    match tag {
        RespTag::Ident => {
            conn.write_buf.extend_from_slice(resp.as_bytes());
            conn.write_buf.push(b'\n');
        }
        RespTag::Seq(seq) => {
            conn.held.insert(seq, resp);
            while let Some(line) = conn.held.remove(&conn.next_release) {
                conn.write_buf.extend_from_slice(line.as_bytes());
                conn.write_buf.push(b'\n');
                conn.next_release += 1;
            }
        }
    }
}

/// One simulation worker: drain the shared job queue, answer through
/// the result cache, park the response on the owning connection.
fn sim_worker(stop: &AtomicBool, cache: &ServeCache, sim_pool: &SimPool) {
    loop {
        let job = {
            let mut q = sim_pool.jobs.lock().expect("sim pool poisoned");
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if stop.load(Ordering::Relaxed) {
                    break None;
                }
                let (guard, _) = sim_pool
                    .ready
                    .wait_timeout(q, std::time::Duration::from_millis(50))
                    .expect("sim pool poisoned");
                q = guard;
            }
        };
        let Some(job) = job else { return };
        let resp = match serve_cache_key(&job.req) {
            Some(key) => cache.get_or_compute(key, &job.req),
            None => handle_request(&job.req).to_string(),
        };
        let out = match job.bin {
            Some(slot) => OutMsg::Frame(encode_resp_item(slot, &resp)),
            None => OutMsg::Line(attach_id(resp, job.id)),
        };
        job.shared.done.lock().expect("conn outbox poisoned").push((job.tag, out));
    }
}

/// Client for the simulator service.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Socket read/write timeout this client was opened with; carried
    /// so transparent reconnects preserve the policy.
    io_timeout: Option<std::time::Duration>,
    /// Wire preference this client was opened with (reconnects
    /// renegotiate with the same preference).
    wire_pref: Wire,
    /// Negotiated mode: true only when a binary hello was acked.
    binary: bool,
    /// Next binary batch id (frames of concurrent bursts on one
    /// connection could otherwise not be told apart).
    next_batch: u32,
    /// Application bytes written/read on this connection, both
    /// protocols — the `perf_wire_codec` bytes-on-wire measurement.
    tx_bytes: u64,
    rx_bytes: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        Self::connect_opts(addr, None)
    }

    /// Connect with socket read/write timeouts: a stalled host then
    /// surfaces as a transport error (and, in the cluster tier, a
    /// failover) instead of blocking the caller forever.
    pub fn connect_with_io_timeout(addr: &str, timeout: std::time::Duration) -> Result<Client> {
        Self::connect_opts(addr, Some(timeout))
    }

    /// Connect with an explicit wire preference. `Wire::Binary` sends
    /// the versioned hello and downgrades to the JSON line protocol if
    /// the server answers anything but a hello-ack — old servers treat
    /// the hello as an ordinary (failing) request line, so mixed
    /// clusters keep working.
    pub fn connect_wire(
        addr: &str,
        io_timeout: Option<std::time::Duration>,
        wire: Wire,
    ) -> Result<Client> {
        let mut client = Self::connect_opts(addr, io_timeout)?;
        if wire == Wire::Binary {
            client.wire_pref = Wire::Binary;
            client.negotiate()?;
        }
        Ok(client)
    }

    /// Reconnect-with-the-same-policy: timeout and wire preference
    /// carry over (a binary client renegotiates; against a downgraded
    /// server it lands back on JSON).
    fn reconnect(&self, addr: &str) -> Result<Client> {
        Self::connect_wire(addr, self.io_timeout, self.wire_pref)
    }

    /// One hello roundtrip; flips `self.binary` on a versioned ack.
    fn negotiate(&mut self) -> Result<()> {
        let hello = obj(vec![
            ("hello", WIRE_PROTO.into()),
            ("version", (WIRE_VERSION as f64).into()),
        ]);
        self.write_line(&hello.to_string())?;
        let line = self.read_line()?;
        let resp = Json::parse(line.trim()).map_err(|e| anyhow!("bad hello response: {e}"))?;
        self.binary = resp.get("hello").and_then(Json::as_str) == Some(WIRE_PROTO)
            && resp.get("version").and_then(Json::as_usize).unwrap_or(0) >= 1;
        Ok(())
    }

    /// True when the binary protocol was negotiated on this connection.
    pub fn is_binary(&self) -> bool {
        self.binary
    }

    /// (bytes written, bytes read) on this connection so far.
    pub fn wire_bytes(&self) -> (u64, u64) {
        (self.tx_bytes, self.rx_bytes)
    }

    fn write_line(&mut self, line: &str) -> Result<()> {
        self.tx_bytes += line.len() as u64 + 1;
        writeln!(self.writer, "{line}")?;
        Ok(())
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("connection closed"));
        }
        self.rx_bytes += line.len() as u64;
        Ok(line)
    }

    /// Read one length-prefixed binary frame (payload only).
    fn read_frame(&mut self) -> Result<Vec<u8>> {
        let mut len4 = [0u8; 4];
        self.reader.read_exact(&mut len4)?;
        let len = u32::from_le_bytes(len4) as usize;
        if len == 0 || len > codec::MAX_FRAME_PAYLOAD {
            return Err(anyhow!("bad frame length {len}"));
        }
        let mut payload = vec![0u8; len];
        self.reader.read_exact(&mut payload)?;
        self.rx_bytes += 4 + len as u64;
        Ok(payload)
    }

    fn connect_opts(addr: &str, io_timeout: Option<std::time::Duration>) -> Result<Client> {
        // With a timeout policy, the connect itself is bounded too: a
        // black-holed host (dropped packets, unroutable IP) must not
        // stall the caller for the OS default of a minute or more.
        let stream = match io_timeout {
            None => TcpStream::connect(addr).context("connecting to simulator service")?,
            Some(t) => {
                let sock = addr
                    .to_socket_addrs()
                    .context("resolving simulator service address")?
                    .next()
                    .ok_or_else(|| anyhow!("unresolvable address {addr}"))?;
                TcpStream::connect_timeout(&sock, t)
                    .context("connecting to simulator service")?
            }
        };
        stream.set_read_timeout(io_timeout)?;
        stream.set_write_timeout(io_timeout)?;
        let writer = stream.try_clone()?;
        Ok(Client {
            reader: BufReader::new(stream),
            writer,
            io_timeout,
            wire_pref: Wire::Json,
            binary: false,
            next_batch: 0,
            tx_bytes: 0,
            rx_bytes: 0,
        })
    }

    /// Query one (space, nas, hw) sample; returns the raw response.
    pub fn query(
        &mut self,
        space: &str,
        nas_d: &[usize],
        has_d: &[usize],
        seg: bool,
    ) -> Result<Json> {
        if self.binary {
            let key: Vec<usize> = nas_d.iter().chain(has_d).copied().collect();
            let mut resps =
                self.query_pipelined(space, seg, std::slice::from_ref(&key), nas_d.len())?;
            return Ok(resps.pop().expect("one response per key"));
        }
        let arr = |v: &[usize]| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
        let req = obj(vec![
            ("space", space.into()),
            ("nas", arr(nas_d)),
            ("hw", arr(has_d)),
            ("task", if seg { "seg".into() } else { "cls".into() }),
        ]);
        self.write_line(&req.to_string())?;
        let line = self.read_line()?;
        Json::parse(line.trim_end_matches(['\n', '\r']))
            .map_err(|e| anyhow!("bad response: {e}"))
    }

    /// Pipeline a burst of joint-key queries on this one connection:
    /// every request carries its index as an `"id"`, the whole burst
    /// is written before any response is read, and the server answers
    /// in *completion* order — the echoed ids restore request order
    /// here. Responses are returned in `keys` order. Any transport
    /// error, unparseable line, or missing/duplicate id fails the
    /// whole burst (the caller falls back to one-at-a-time
    /// roundtrips, which keep per-key transport verdicts exact).
    pub fn query_pipelined(
        &mut self,
        space: &str,
        seg: bool,
        keys: &[Vec<usize>],
        nas_len: usize,
    ) -> Result<Vec<Json>> {
        if keys.is_empty() {
            return Ok(Vec::new());
        }
        if self.binary {
            return self.query_pipelined_binary(space, seg, keys, nas_len);
        }
        let arr = |v: &[usize]| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
        let mut burst = String::new();
        for (i, key) in keys.iter().enumerate() {
            let (nas_d, has_d) = key.split_at(nas_len);
            let req = obj(vec![
                ("space", space.into()),
                ("nas", arr(nas_d)),
                ("hw", arr(has_d)),
                ("task", if seg { "seg".into() } else { "cls".into() }),
                ("id", Json::Num(i as f64)),
            ]);
            burst.push_str(&req.to_string());
            burst.push('\n');
        }
        self.tx_bytes += burst.len() as u64;
        self.writer.write_all(burst.as_bytes())?;
        let mut out: Vec<Option<Json>> = vec![None; keys.len()];
        for _ in 0..keys.len() {
            let line = self.read_line().map_err(|_| anyhow!("connection closed mid-pipeline"))?;
            let resp = Json::parse(line.trim_end_matches(['\n', '\r']))
                .map_err(|e| anyhow!("bad response: {e}"))?;
            let Some(id) = resp.get("id").and_then(Json::as_usize) else {
                return Err(anyhow!("pipelined response without id: {line}"));
            };
            let slot =
                out.get_mut(id).ok_or_else(|| anyhow!("response id {id} out of range"))?;
            if slot.is_some() {
                return Err(anyhow!("duplicate response id {id}"));
            }
            *slot = Some(resp);
        }
        Ok(out.into_iter().map(|r| r.expect("every id matched")).collect())
    }

    /// The binary-mode burst: one `REQ_BATCH` frame out, `keys.len()`
    /// `RESP_ITEM` frames back in completion order, matched by the
    /// (batch, index) slot each frame names. Each item is rebuilt as
    /// the response object the JSON protocol would have produced (raw
    /// bits, never re-parsed text), so callers cannot tell the
    /// protocols apart — except by the bytes moved.
    fn query_pipelined_binary(
        &mut self,
        space: &str,
        seg: bool,
        keys: &[Vec<usize>],
        nas_len: usize,
    ) -> Result<Vec<Json>> {
        let space_id =
            space_by_name(space).ok_or_else(|| anyhow!("unknown space '{space}'"))?;
        let batch_id = self.next_batch;
        self.next_batch = self.next_batch.wrapping_add(1);
        let mut body = Vec::with_capacity(16 + keys.len() * (keys[0].len() + 2));
        body.push(FK_REQ_BATCH);
        body.push(space_id as u8);
        body.push(seg as u8);
        put_varint(&mut body, nas_len as u64);
        put_u32(&mut body, batch_id);
        put_varint(&mut body, keys.len() as u64);
        for key in keys {
            codec::put_usize_slice(&mut body, key);
        }
        let frame = codec::frame(&body);
        self.tx_bytes += frame.len() as u64;
        self.writer.write_all(&frame)?;
        let mut out: Vec<Option<Json>> = vec![None; keys.len()];
        for _ in 0..keys.len() {
            let payload = self.read_frame()?;
            let mut r = ByteReader::new(&payload);
            if r.u8() != Some(FK_RESP_ITEM) {
                return Err(anyhow!("unexpected frame kind"));
            }
            let (Some(bid), Some(index), Some(valid)) = (r.u32(), r.varint_usize(), r.u8())
            else {
                return Err(anyhow!("truncated RESP_ITEM frame"));
            };
            if bid != batch_id {
                return Err(anyhow!("response for stale batch {bid} (expected {batch_id})"));
            }
            let mut fields = [0.0f64; 4];
            for f in &mut fields {
                *f = r.f64_bits().ok_or_else(|| anyhow!("truncated RESP_ITEM frame"))?;
            }
            let resp = obj(vec![
                ("id", Json::Num(index as f64)),
                ("valid", (valid == 1).into()),
                ("latency_ms", fields[0].into()),
                ("energy_mj", fields[1].into()),
                ("area_mm2", fields[2].into()),
                ("utilization", fields[3].into()),
            ]);
            let slot = out
                .get_mut(index)
                .ok_or_else(|| anyhow!("response index {index} out of range"))?;
            if slot.is_some() {
                return Err(anyhow!("duplicate response index {index}"));
            }
            *slot = Some(resp);
        }
        Ok(out.into_iter().map(|r| r.expect("every index matched")).collect())
    }

    /// Stream a warm-cache handoff to this server: one `CACHE_INSTALL`
    /// frame carrying the serve fingerprint plus a
    /// [`crate::search::store::encode_handoff`] segment stream, one
    /// `CACHE_ACK` back. Binary-wire only — a JSON-only peer predates
    /// the protocol, and the caller should skip the handoff (the host
    /// just starts cold). Returns how many entries the server
    /// installed; a refused install (mangled stream, stale
    /// fingerprint) is an error carrying the server's reason.
    pub fn install_cache(&mut self, fingerprint: &str, segments: &[u8]) -> Result<usize> {
        if !self.binary {
            return Err(anyhow!("cache handoff needs the binary wire"));
        }
        let mut body = Vec::with_capacity(1 + 4 + fingerprint.len() + segments.len());
        body.push(FK_CACHE_INSTALL);
        codec::put_str(&mut body, fingerprint);
        body.extend_from_slice(segments);
        let frame = codec::frame(&body);
        self.tx_bytes += frame.len() as u64;
        self.writer.write_all(&frame)?;
        let payload = self.read_frame()?;
        let mut r = ByteReader::new(&payload);
        if r.u8() != Some(FK_CACHE_ACK) {
            return Err(anyhow!("unexpected frame kind in install ack"));
        }
        let (Some(ok), Some(installed), Some(msg)) = (r.u8(), r.varint_usize(), r.str()) else {
            return Err(anyhow!("truncated CACHE_ACK frame"));
        };
        if ok != 1 {
            return Err(anyhow!("server refused cache handoff: {msg}"));
        }
        Ok(installed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn request_roundtrip_over_tcp() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let has = HasSpace::new();
        let mut rng = Rng::new(2);
        let nas_d = space.random(&mut rng);
        let resp = client.query("efficientnet", &nas_d, &has.baseline_decisions(), false).unwrap();
        assert_eq!(resp.get("valid"), Some(&Json::Bool(true)));
        assert!(resp.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);
        server.stop();
    }

    #[test]
    fn parallel_clients_all_served() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let mut joins = Vec::new();
        for t in 0..4 {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let space = NasSpace::new(NasSpaceId::MobileNetV2);
                let has = HasSpace::new();
                let mut rng = Rng::new(t);
                for _ in 0..8 {
                    let nas_d = space.random(&mut rng);
                    let resp = client
                        .query("mobilenetv2", &nas_d, &has.baseline_decisions(), false)
                        .unwrap();
                    assert!(resp.get("valid").is_some());
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(server.requests.load(Ordering::Relaxed), 32);
        server.stop();
    }

    #[test]
    fn server_memoizes_repeat_simulations_and_reports_stats() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let has = HasSpace::new();
        let mut rng = Rng::new(8);
        let nas_d = space.random(&mut rng);
        let hw = has.baseline_decisions();
        let r1 = client.query("efficientnet", &nas_d, &hw, false).unwrap();
        let r2 = client.query("efficientnet", &nas_d, &hw, false).unwrap();
        assert_eq!(r1, r2, "cached response must be byte-identical");
        assert_eq!(server.cache.sim_evals.load(Ordering::Relaxed), 1);
        assert_eq!(server.cache.hits.load(Ordering::Relaxed), 1);
        // A different task decodes differently: it must not alias.
        let r3 = client.query("efficientnet", &nas_d, &hw, true).unwrap();
        assert_ne!(r1, r3);
        assert_eq!(server.cache.sim_evals.load(Ordering::Relaxed), 2);
        // The stats protocol reports the counters over the same socket.
        let mut stream = TcpStream::connect(server.addr).unwrap();
        writeln!(stream, "{{\"stats\": true}}").unwrap();
        let mut line = String::new();
        BufReader::new(stream).read_line(&mut line).unwrap();
        let st = Json::parse(line.trim()).unwrap();
        assert_eq!(st.get("cache_hits").and_then(Json::as_usize), Some(1));
        assert_eq!(st.get("sim_evals").and_then(Json::as_usize), Some(2));
        assert_eq!(st.get("cache_size").and_then(Json::as_usize), Some(2));
        server.stop();
    }

    #[test]
    fn serve_cache_warm_starts_from_a_persistent_store() {
        use crate::search::store::serve_fingerprint;
        let path = std::env::temp_dir()
            .join(format!("nahas-serve-warm-{}.cache", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let has = HasSpace::new();
        let mut rng = Rng::new(11);
        let nas_d = space.random(&mut rng);
        let hw = has.baseline_decisions();

        // First server: simulates once, spills the response.
        let store = CacheStore::open(&path, &serve_fingerprint()).unwrap();
        let server = Server::spawn_with_cache("127.0.0.1:0", ServeCache::with_store(store))
            .unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let r1 = client.query("efficientnet", &nas_d, &hw, false).unwrap();
        assert_eq!(server.cache.sim_evals.load(Ordering::Relaxed), 1);
        server.stop();

        // Second server, same file: the response is served from the
        // warm cache byte-identically, with zero fresh simulations.
        let store = CacheStore::open(&path, &serve_fingerprint()).unwrap();
        assert!(store.discarded().is_none());
        assert_eq!(store.loaded_len(), 1);
        let server = Server::spawn_with_cache("127.0.0.1:0", ServeCache::with_store(store))
            .unwrap();
        assert_eq!(server.cache.len(), 1);
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let r2 = client.query("efficientnet", &nas_d, &hw, false).unwrap();
        assert_eq!(r1, r2, "warm response must match the original");
        assert_eq!(server.cache.sim_evals.load(Ordering::Relaxed), 0);
        assert_eq!(server.cache.hits.load(Ordering::Relaxed), 1);
        server.stop();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn binary_negotiation_roundtrips_bit_identically_to_json() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let mut bin = Client::connect_wire(&addr, None, Wire::Binary).unwrap();
        assert!(bin.is_binary(), "new server must ack the hello");
        let mut json = Client::connect(&addr).unwrap();
        assert!(!json.is_binary());
        let space = NasSpace::new(NasSpaceId::EfficientNet);
        let has = HasSpace::new();
        let mut rng = Rng::new(13);
        for _ in 0..6 {
            let nas_d = space.random(&mut rng);
            let hw = has.baseline_decisions();
            let b = bin.query("efficientnet", &nas_d, &hw, false).unwrap();
            let j = json.query("efficientnet", &nas_d, &hw, false).unwrap();
            assert_eq!(b.get("valid"), j.get("valid"));
            for k in ["latency_ms", "energy_mj", "area_mm2", "utilization"] {
                let bb = b.get(k).and_then(Json::as_f64).map(f64::to_bits);
                let jb = j.get(k).and_then(Json::as_f64).map(f64::to_bits);
                assert_eq!(bb, jb, "field {k} must be bit-identical across protocols");
            }
        }
        // Pipelined bursts through the binary frame, matched by index.
        let keys: Vec<Vec<usize>> = (0..8)
            .map(|_| {
                let mut k = space.random(&mut rng);
                k.extend(has.baseline_decisions());
                k
            })
            .collect();
        let nas_len = space.num_decisions();
        let br = bin.query_pipelined("efficientnet", false, &keys, nas_len).unwrap();
        let jr = json.query_pipelined("efficientnet", false, &keys, nas_len).unwrap();
        for (b, j) in br.iter().zip(&jr) {
            assert_eq!(b.get("valid"), j.get("valid"));
            let bb = b.get("latency_ms").and_then(Json::as_f64).map(f64::to_bits);
            let jb = j.get("latency_ms").and_then(Json::as_f64).map(f64::to_bits);
            assert_eq!(bb, jb);
        }
        // And the binary burst moved fewer application bytes.
        let (btx, brx) = bin.wire_bytes();
        let (jtx, jrx) = json.wire_bytes();
        assert!(btx < jtx, "binary tx {btx} must be below json tx {jtx}");
        assert!(brx < jrx, "binary rx {brx} must be below json rx {jrx}");
        server.stop();
    }

    #[test]
    fn binary_preference_falls_back_to_json_against_an_old_server() {
        // A pre-binary "server": answers every line with an error
        // object, which is exactly what an old nahas serve does with a
        // hello line it has never heard of.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut w = stream.try_clone().unwrap();
            let mut r = BufReader::new(stream);
            let mut line = String::new();
            while r.read_line(&mut line).unwrap_or(0) > 0 {
                writeln!(w, "{{\"valid\": false, \"error\": \"missing 'space'\"}}").unwrap();
                line.clear();
            }
        });
        let client = Client::connect_wire(&addr, None, Wire::Binary).unwrap();
        assert!(!client.is_binary(), "no hello-ack means the JSON line protocol");
        drop(client);
        handle.join().unwrap();

        // A real server keeps speaking JSON on the same connection
        // after rejecting a hello it does not support.
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        writeln!(stream, "{{\"hello\": \"other-proto\", \"version\": 9}}").unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("valid"), Some(&Json::Bool(false)));
        writeln!(stream, "{{\"stats\": true}}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(Json::parse(line.trim()).unwrap().get("requests").is_some());
        server.stop();
    }

    #[test]
    fn malformed_requests_get_errors_not_crashes() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        writeln!(stream, "this is not json").unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("valid"), Some(&Json::Bool(false)));
        // Valid JSON, bad payload.
        writeln!(stream, "{{\"space\": \"nope\"}}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert_eq!(Json::parse(&line).unwrap().get("valid"), Some(&Json::Bool(false)));
        server.stop();
    }
}

/// Decode one service response into an [`crate::search::EvalResult`],
/// filling in the locally computed surrogate accuracy (the paper's
/// clients likewise query the service only for hardware metrics).
/// Accuracy goes through [`SurrogateSim::accuracy_of`] — the same
/// decode + task dispatch as the local tiers — so local and remote
/// accuracy cannot diverge.
pub(crate) fn remote_result(
    resp: &Json,
    sim: &crate::search::SurrogateSim,
    nas_d: &[usize],
) -> crate::search::EvalResult {
    if resp.get("valid") != Some(&Json::Bool(true)) {
        return crate::search::EvalResult::invalid();
    }
    let f = |k: &str| resp.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
    crate::search::EvalResult {
        acc: sim.accuracy_of(nas_d),
        latency_ms: f("latency_ms"),
        energy_mj: f("energy_mj"),
        area_mm2: f("area_mm2"),
        valid: true,
    }
}

pub(crate) fn service_space_name(id: NasSpaceId) -> &'static str {
    match id {
        NasSpaceId::MobileNetV2 => "mobilenetv2",
        NasSpaceId::EfficientNet => "efficientnet",
        NasSpaceId::Evolved => "evolved",
        NasSpaceId::Proxy => "proxy",
    }
}

/// One service roundtrip with a single transparent reconnect; the
/// replacement connection inherits the pooled client's timeout policy
/// and takes over its slot on success. Shared by both remote tiers
/// ([`ServiceEvaluator`], [`crate::cluster::ShardedEvaluator`]) so the
/// transport-failure ladder cannot diverge between them; an `Err`
/// means the host failed two attempts in a row.
pub(crate) fn query_with_reconnect(
    client: &mut Client,
    addr: &str,
    space_name: &str,
    seg: bool,
    key: &[usize],
    nas_len: usize,
) -> Result<Json> {
    let (nas_d, has_d) = key.split_at(nas_len);
    if let Ok(resp) = client.query(space_name, nas_d, has_d, seg) {
        return Ok(resp);
    }
    let mut fresh = client.reconnect(addr)?;
    let resp = fresh.query(space_name, nas_d, has_d, seg)?;
    *client = fresh;
    Ok(resp)
}

/// Batched remote evaluator: the paper's "multiple NAHAS clients can
/// send parallel requests" made literal. Holds one TCP connection per
/// worker; `evaluate_batch` dedups the batch through a joint-decision
/// memo cache, splits the misses into contiguous per-connection
/// slices, and **pipelines** each slice as one id-tagged burst over
/// its connection ([`Client::query_pipelined`]) — many requests in
/// flight per socket, matched by id, with the server's event loop
/// answering in completion order. A failed burst falls back to
/// one-at-a-time roundtrips after a reconnect, so per-key transport
/// verdicts (and their cacheable tags) stay exact. Results are
/// reassembled in batch order and — because the simulator and the
/// local surrogate accuracy are deterministic — are bit-identical to
/// the local [`crate::search::SurrogateSim`] path for the same seed
/// (`workers: 1` gives the serial single-connection client).
pub struct ServiceEvaluator {
    conns: Vec<Client>,
    /// Kept for transparent one-shot reconnects on transport failure.
    addr: String,
    space_name: &'static str,
    /// Local accuracy half (decode + task dispatch) — hardware metrics
    /// come from the service, accuracy from the same code as the local
    /// tiers.
    sim: crate::search::SurrogateSim,
    seg: bool,
    cache: crate::search::MemoCache,
    counters: crate::search::evaluator::EvalCounters,
}

impl ServiceEvaluator {
    /// Connect `workers` parallel clients to a `nahas serve` instance.
    /// Prefers the binary wire protocol (safe: the hello downgrades to
    /// JSON against a server that does not speak it); pass
    /// [`Wire::Json`] through [`ServiceEvaluator::connect_wire`] to
    /// force the line protocol.
    pub fn connect(addr: &str, id: NasSpaceId, seed: u64, workers: usize) -> Result<Self> {
        Self::connect_wire(addr, id, seed, workers, Wire::Binary)
    }

    /// [`ServiceEvaluator::connect`] with an explicit wire preference
    /// (CLI `--wire json|binary`).
    pub fn connect_wire(
        addr: &str,
        id: NasSpaceId,
        seed: u64,
        workers: usize,
        wire: Wire,
    ) -> Result<Self> {
        let conns = (0..workers.max(1))
            .map(|_| Client::connect_wire(addr, None, wire))
            .collect::<Result<Vec<Client>>>()?;
        Ok(ServiceEvaluator {
            conns,
            addr: addr.to_string(),
            space_name: service_space_name(id),
            sim: crate::search::SurrogateSim::new(NasSpace::new(id), seed),
            seg: false,
            cache: crate::search::MemoCache::new(16 * 1024),
            counters: crate::search::evaluator::EvalCounters::default(),
        })
    }

    pub fn segmentation(mut self) -> Self {
        self.seg = true;
        self.sim = self.sim.segmentation();
        self
    }

    pub fn workers(&self) -> usize {
        self.conns.len()
    }

    /// True when every pooled connection negotiated the binary
    /// protocol.
    pub fn all_binary(&self) -> bool {
        self.conns.iter().all(Client::is_binary)
    }

    /// Total (bytes written, bytes read) across the connection pool —
    /// the `perf_wire_codec` bytes-on-wire measurement. Connections
    /// replaced by a transparent reconnect restart their counters.
    pub fn wire_bytes(&self) -> (u64, u64) {
        self.conns
            .iter()
            .map(Client::wire_bytes)
            .fold((0, 0), |(tx, rx), (t, r)| (tx + t, rx + r))
    }

    /// One service roundtrip through [`query_with_reconnect`]. The
    /// bool is "cacheable": an in-protocol response (even `valid:
    /// false`) is deterministic and memoizable; a transport failure is
    /// not — caching it would poison the memo cache and starve later
    /// resamples of a retry. A restarted server therefore costs one
    /// failed roundtrip per connection instead of corrupting the rest
    /// of the search.
    fn query_one(
        client: &mut Client,
        addr: &str,
        space_name: &str,
        sim: &crate::search::SurrogateSim,
        seg: bool,
        key: &[usize],
        nas_len: usize,
    ) -> (crate::search::EvalResult, bool) {
        match query_with_reconnect(client, addr, space_name, seg, key, nas_len) {
            Ok(resp) => (remote_result(&resp, sim, &key[..nas_len]), true),
            Err(_) => {
                eprintln!("service evaluator: transport failure to {addr}; sample invalid");
                (crate::search::EvalResult::invalid(), false)
            }
        }
    }

    /// Pipeline one contiguous key slice over one connection; on a
    /// failed burst, reconnect and replay the slice one key at a time
    /// so each key gets its own exact transport verdict.
    fn query_chunk(
        client: &mut Client,
        addr: &str,
        space_name: &str,
        sim: &crate::search::SurrogateSim,
        seg: bool,
        keys: &[Vec<usize>],
        nas_len: usize,
    ) -> Vec<(crate::search::EvalResult, bool)> {
        match client.query_pipelined(space_name, seg, keys, nas_len) {
            Ok(resps) => resps
                .iter()
                .zip(keys)
                .map(|(resp, key)| (remote_result(resp, sim, &key[..nas_len]), true))
                .collect(),
            Err(_) => {
                // The burst died somewhere mid-stream: the connection
                // may still hold unread id-tagged responses, so it
                // must never serve another query (a stale line would
                // silently answer the wrong key). Reconnect, then let
                // the serial ladder sort out per-key success/failure;
                // if even the reconnect fails, the whole slice is a
                // transport failure (uncacheable, retried on the next
                // resample).
                match client.reconnect(addr) {
                    Ok(fresh) => {
                        *client = fresh;
                        keys.iter()
                            .map(|k| {
                                Self::query_one(client, addr, space_name, sim, seg, k, nas_len)
                            })
                            .collect()
                    }
                    Err(_) => {
                        eprintln!(
                            "service evaluator: transport failure to {addr}; \
                             {} sample(s) invalid",
                            keys.len()
                        );
                        keys.iter()
                            .map(|_| (crate::search::EvalResult::invalid(), false))
                            .collect()
                    }
                }
            }
        }
    }

    /// Evaluate deduped keys across the connection pool, in key order:
    /// one pipelined burst per connection over contiguous slices.
    fn query_pending(
        &mut self,
        pending: &[Vec<usize>],
        nas_len: usize,
    ) -> Vec<(crate::search::EvalResult, bool)> {
        use crate::search::EvalResult;
        if pending.is_empty() {
            return Vec::new();
        }
        let (sim, space_name, seg) = (&self.sim, self.space_name, self.seg);
        let addr = self.addr.as_str();
        let nconn = self.conns.len().min(pending.len());
        let chunk = pending.len().div_ceil(nconn);
        let mut fresh = Vec::with_capacity(pending.len());
        if nconn == 1 {
            let client = &mut self.conns[0];
            fresh = Self::query_chunk(client, addr, space_name, sim, seg, pending, nas_len);
        } else {
            // One worker thread per connection; each pipelines its
            // contiguous slice of the deduped keys as a single burst,
            // so concatenated join output restores key order.
            std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .conns
                    .iter_mut()
                    .zip(pending.chunks(chunk))
                    .map(|(client, keys)| {
                        s.spawn(move || -> Vec<(EvalResult, bool)> {
                            Self::query_chunk(client, addr, space_name, sim, seg, keys, nas_len)
                        })
                    })
                    .collect();
                for h in handles {
                    fresh.extend(h.join().expect("service client worker panicked"));
                }
            });
        }
        fresh
    }
}

impl crate::search::Evaluator for ServiceEvaluator {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> crate::search::EvalResult {
        self.counters.requests += 1;
        let key = crate::search::joint_key(nas_d, has_d);
        let r = match self.cache.get(&key) {
            Some(r) => r,
            None => {
                self.counters.evals += 1;
                let (conns, addr) = (&mut self.conns, self.addr.as_str());
                let (r, cacheable) = Self::query_one(
                    &mut conns[0],
                    addr,
                    self.space_name,
                    &self.sim,
                    self.seg,
                    &key,
                    nas_d.len(),
                );
                if cacheable {
                    self.cache.insert(key, r);
                }
                r
            }
        };
        if !r.valid {
            self.counters.invalid += 1;
        }
        r
    }

    fn evaluate_batch(
        &mut self,
        batch: &[(Vec<usize>, Vec<usize>)],
    ) -> Vec<crate::search::EvalResult> {
        self.evaluate_batch_tagged(batch).into_iter().map(|(r, _)| r).collect()
    }

    fn evaluate_batch_tagged(
        &mut self,
        batch: &[(Vec<usize>, Vec<usize>)],
    ) -> Vec<(crate::search::EvalResult, bool)> {
        if batch.is_empty() {
            return Vec::new();
        }
        self.counters.requests += batch.len();
        let nas_len = batch[0].0.len();
        assert!(
            batch.iter().all(|(nas_d, _)| nas_d.len() == nas_len),
            "mixed decision lengths in one batch"
        );
        let plan = crate::search::parallel::BatchPlan::build(&mut self.cache, batch);
        let fresh = self.query_pending(plan.pending(), nas_len);
        self.counters.evals += fresh.len();
        // Keep the per-slot transport verdicts: an upstream cache
        // (e.g. the shared `EvalBroker`) must not memoize a transport
        // failure any more than the local cache here does.
        let out = plan.finish_tagged(&mut self.cache, fresh);
        self.counters.invalid += out.iter().filter(|(r, _)| !r.valid).count();
        out
    }

    fn stats(&self) -> crate::search::EvalStats {
        self.counters.stats()
    }

    /// One roundtrip can be in flight per pooled connection, so the
    /// broker may usefully keep that many session batches admitted.
    fn capacity(&self) -> usize {
        self.conns.len()
    }

    fn wire_bytes(&self) -> (u64, u64) {
        ServiceEvaluator::wire_bytes(self)
    }
}

#[cfg(test)]
mod service_eval_tests {
    use super::*;
    use crate::search::joint::JointLayout;
    use crate::search::ppo::PpoController;
    use crate::search::{joint_search, Evaluator, RewardCfg, SearchCfg, SurrogateSim};

    #[test]
    fn batched_service_eval_matches_local_simulator() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut remote =
            ServiceEvaluator::connect(&server.addr.to_string(), NasSpaceId::EfficientNet, 3, 4)
                .unwrap();
        let mut local =
            SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
        let has = crate::has::HasSpace::new();
        let mut rng = crate::util::Rng::new(9);
        let batch: Vec<(Vec<usize>, Vec<usize>)> = (0..16)
            .map(|_| (local.space.random(&mut rng), has.random(&mut rng)))
            .collect();
        let rs = remote.evaluate_batch(&batch);
        let ls = local.evaluate_batch(&batch);
        for (r, l) in rs.iter().zip(&ls) {
            assert_eq!(r.valid, l.valid);
            if r.valid {
                assert!((r.latency_ms - l.latency_ms).abs() < 1e-9);
                assert!((r.energy_mj - l.energy_mj).abs() < 1e-9);
                assert!((r.acc - l.acc).abs() < 1e-12);
            }
        }
        // Second pass: everything is a memo-cache hit, no new requests.
        let before = server.requests.load(Ordering::Relaxed);
        let again = remote.evaluate_batch(&batch);
        assert_eq!(server.requests.load(Ordering::Relaxed), before);
        for (a, b) in rs.iter().zip(&again) {
            assert_eq!(a.acc.to_bits(), b.acc.to_bits());
        }
        server.stop();
    }

    #[test]
    fn single_connection_eval_matches_local_simulator() {
        // workers = 1: the serial single-client path (covers the
        // nconn == 1 branch and per-call `evaluate`).
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut remote =
            ServiceEvaluator::connect(&server.addr.to_string(), NasSpaceId::EfficientNet, 3, 1)
                .unwrap();
        let mut local = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
        let has = crate::has::HasSpace::new();
        let mut rng = crate::util::Rng::new(4);
        for _ in 0..8 {
            let nas_d = local.space.random(&mut rng);
            let r = remote.evaluate(&nas_d, &has.baseline_decisions());
            let l = local.evaluate(&nas_d, &has.baseline_decisions());
            assert_eq!(r.valid, l.valid);
            if r.valid {
                assert!((r.latency_ms - l.latency_ms).abs() < 1e-9);
                assert!((r.energy_mj - l.energy_mj).abs() < 1e-9);
                assert!((r.acc - l.acc).abs() < 1e-12);
            }
        }
        server.stop();
    }

    #[test]
    fn segmentation_accuracy_matches_local_evaluator() {
        // The service returns hardware metrics for the segmentation
        // variant; the client-side accuracy must be the segmentation
        // mIOU too (not classification top-1).
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut remote =
            ServiceEvaluator::connect(&server.addr.to_string(), NasSpaceId::EfficientNet, 3, 2)
                .unwrap()
                .segmentation();
        let mut local =
            SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3).segmentation();
        let has = crate::has::HasSpace::new();
        let mut rng = crate::util::Rng::new(6);
        let batch: Vec<(Vec<usize>, Vec<usize>)> = (0..6)
            .map(|_| (local.space.random(&mut rng), has.baseline_decisions()))
            .collect();
        let rs = remote.evaluate_batch(&batch);
        let ls = local.evaluate_batch(&batch);
        for (r, l) in rs.iter().zip(&ls) {
            assert_eq!(r.valid, l.valid);
            if r.valid {
                assert_eq!(r.acc.to_bits(), l.acc.to_bits(), "seg accuracy must match local");
                assert!((r.latency_ms - l.latency_ms).abs() < 1e-9);
            }
        }
        server.stop();
    }

    #[test]
    fn whole_search_through_parallel_service_clients() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let space = NasSpace::new(NasSpaceId::MobileNetV2);
        let has = crate::has::HasSpace::new();
        let (cards, layout) = JointLayout::cards(&space, &has);
        let mut remote =
            ServiceEvaluator::connect(&server.addr.to_string(), NasSpaceId::MobileNetV2, 5, 4)
                .unwrap();
        let mut ctl = PpoController::new(&cards);
        let cfg = SearchCfg::new(120, RewardCfg::latency(0.5), 5);
        let out = joint_search(&mut remote, &mut ctl, &layout, None, None, &cfg);
        assert!(out.best_feasible.is_some());
        assert_eq!(out.eval_stats.requests, 120);
        assert_eq!(
            out.eval_stats.evals + out.eval_stats.cache_hits,
            out.eval_stats.requests
        );
        server.stop();
    }
}
