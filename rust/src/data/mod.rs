//! Synthetic proxy-task dataset (stand-in for ImageNet; DESIGN.md
//! §Substitutions).
//!
//! Class-conditional oriented sinusoid ("Gabor-like") textures over RGB
//! with random phase, amplitude jitter and additive noise. Sixteen
//! classes live on a 4x4 grid of (x-frequency, y-frequency) pairs, so
//! class identity is recoverable by oriented filters — exactly what small
//! ConvNets learn — and accuracy rises smoothly with model capacity,
//! which is the gradient the NAS controllers climb.
//!
//! The generator is pure-rust, deterministic per seed, and fills
//! caller-provided buffers (NHWC f32 + i32 labels) sized for the AOT
//! artifact batch shapes.

use crate::util::Rng;

/// Mirror of python/compile/config.py (checked against the manifest at
/// runtime-load).
pub const IMG: usize = 8;
pub const CHANNELS: usize = 3;
pub const NUM_CLASSES: usize = 16;

/// Synthetic dataset generator.
pub struct DataGen {
    rng: Rng,
    /// Noise standard deviation (difficulty knob).
    pub noise: f32,
}

impl DataGen {
    pub fn new(seed: u64) -> Self {
        DataGen { rng: Rng::new(seed), noise: 0.35 }
    }

    /// Fill one batch: `x` is `[n, IMG, IMG, 3]` flattened NHWC, `y` is
    /// `[n]` class ids.
    pub fn fill_batch(&mut self, x: &mut [f32], y: &mut [i32]) {
        let n = y.len();
        assert_eq!(x.len(), n * IMG * IMG * CHANNELS);
        for i in 0..n {
            let class = self.rng.below(NUM_CLASSES);
            y[i] = class as i32;
            let img = &mut x[i * IMG * IMG * CHANNELS..(i + 1) * IMG * IMG * CHANNELS];
            self.fill_image(img, class);
        }
    }

    fn fill_image(&mut self, img: &mut [f32], class: usize) {
        // Class -> (fx, fy) on a 4x4 frequency grid.
        let fx = 0.35 + 0.30 * (class % 4) as f32;
        let fy = 0.25 + 0.28 * (class / 4) as f32;
        let phase = self.rng.f32() * std::f32::consts::TAU;
        let amp = 0.8 + 0.4 * self.rng.f32();
        for h in 0..IMG {
            for w in 0..IMG {
                let base = amp * (fx * w as f32 + fy * h as f32 + phase).sin();
                let o = (h * IMG + w) * CHANNELS;
                img[o] = base + self.noise * self.rng.normal();
                img[o + 1] = 0.5 * base + self.noise * self.rng.normal();
                img[o + 2] = -base + self.noise * self.rng.normal();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(seed: u64, n: usize) -> (Vec<f32>, Vec<i32>) {
        let mut g = DataGen::new(seed);
        let mut x = vec![0.0; n * IMG * IMG * CHANNELS];
        let mut y = vec![0; n];
        g.fill_batch(&mut x, &mut y);
        (x, y)
    }

    #[test]
    fn deterministic_per_seed() {
        let (x1, y1) = batch(3, 16);
        let (x2, y2) = batch(3, 16);
        assert_eq!(y1, y2);
        assert_eq!(x1, x2);
        let (x3, _) = batch(4, 16);
        assert_ne!(x1, x3);
    }

    #[test]
    fn labels_in_range_and_cover_classes() {
        let (_, y) = batch(5, 2_000);
        assert!(y.iter().all(|&c| (0..NUM_CLASSES as i32).contains(&c)));
        let mut seen = [false; NUM_CLASSES];
        for &c in &y {
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all classes appear in 2000 draws");
    }

    #[test]
    fn pixels_bounded() {
        let (x, _) = batch(6, 64);
        assert!(x.iter().all(|v| v.is_finite() && v.abs() < 10.0));
    }

    #[test]
    fn classes_are_linearly_distinguishable_in_frequency() {
        // Nearest-centroid on the raw pixels of clean images should beat
        // chance comfortably — the signal the ConvNet amplifies.
        let mut g = DataGen::new(7);
        g.noise = 0.0;
        let n = 320;
        let mut x = vec![0.0; n * IMG * IMG * CHANNELS];
        let mut y = vec![0; n];
        g.fill_batch(&mut x, &mut y);
        // Centroid per class of |FFT|-like energy: use mean |pixel| per
        // row/col as a crude frequency signature.
        let d = IMG * 2;
        let feat = |img: &[f32]| -> Vec<f32> {
            let mut f = vec![0.0f32; d];
            for h in 0..IMG {
                for w in 0..IMG {
                    let v = img[(h * IMG + w) * CHANNELS];
                    // discrete gradient magnitudes by row/col
                    if w + 1 < IMG {
                        f[h] += (img[(h * IMG + w + 1) * CHANNELS] - v).abs();
                    }
                    if h + 1 < IMG {
                        f[IMG + w] += (img[((h + 1) * IMG + w) * CHANNELS] - v).abs();
                    }
                }
            }
            f
        };
        let mut cents = vec![vec![0.0f32; d]; NUM_CLASSES];
        let mut counts = vec![0usize; NUM_CLASSES];
        for i in 0..n / 2 {
            let f = feat(&x[i * IMG * IMG * CHANNELS..]);
            for j in 0..d {
                cents[y[i] as usize][j] += f[j];
            }
            counts[y[i] as usize] += 1;
        }
        for (c, cent) in cents.iter_mut().enumerate() {
            for v in cent.iter_mut() {
                *v /= counts[c].max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in n / 2..n {
            let f = feat(&x[i * IMG * IMG * CHANNELS..]);
            let best = (0..NUM_CLASSES)
                .min_by(|&a, &b| {
                    let da: f32 = f.iter().zip(&cents[a]).map(|(u, v)| (u - v) * (u - v)).sum();
                    let db: f32 = f.iter().zip(&cents[b]).map(|(u, v)| (u - v) * (u - v)).sum();
                    da.total_cmp(&db)
                })
                .unwrap();
            if best == y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / (n / 2) as f64;
        assert!(acc > 0.20, "nearest-centroid acc {acc} should beat 1/16 chance");
    }
}
