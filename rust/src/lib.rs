//! NAHAS — joint Neural Architecture and Hardware Accelerator Search.
//!
//! A reproduction of "Rethinking Co-design of Neural Architectures and
//! Hardware Accelerators" (Zhou et al., 2021) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the search framework: NAS/HAS search spaces,
//!   PPO / REINFORCE controllers, the weighted-product constrained reward
//!   (paper Eq. 4–6), multi-trial / oneshot / phase-based search drivers,
//!   a cycle-level simulator of the paper's parameterized edge
//!   accelerator (Fig. 5 / Table 1) with analytical area + energy models,
//!   a learned latency/area cost model, and a simulator-as-a-service.
//! * **L2** — JAX programs (proxy-task supernet, cost-model MLP)
//!   AOT-lowered to HLO text at build time (`make artifacts`).
//! * **L1** — Pallas kernels (tiled matmul, fused MLP trunk) on the
//!   training/inference paths of the L2 programs.
//!
//! Python never runs on the search path: the L3 binary loads the HLO
//! artifacts through PJRT (`runtime`) and owns every loop.

pub mod accel;
pub mod bench;
pub mod costmodel;
pub mod data;
pub mod has;
pub mod metrics;
pub mod model;
pub mod nas;
pub mod pareto;
pub mod runtime;
pub mod search;
pub mod service;
pub mod trainer;
pub mod util;
