//! NAHAS — joint Neural Architecture and Hardware Accelerator Search.
//!
//! A reproduction of "Rethinking Co-design of Neural Architectures and
//! Hardware Accelerators" (Zhou et al., 2021) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the search framework: NAS/HAS search spaces,
//!   PPO / REINFORCE controllers, the weighted-product constrained reward
//!   (paper Eq. 4–6), multi-trial / oneshot / phase-based search drivers,
//!   a cycle-level simulator of the paper's parameterized edge
//!   accelerator (Fig. 5 / Table 1) with analytical area + energy models,
//!   a learned latency/area cost model, and a simulator-as-a-service.
//! * **L2** — JAX programs (proxy-task supernet, cost-model MLP)
//!   AOT-lowered to HLO text at build time (`make artifacts`).
//! * **L1** — Pallas kernels (tiled matmul, fused MLP trunk) on the
//!   training/inference paths of the L2 programs.
//!
//! Python never runs on the search path: the L3 binary loads the HLO
//! artifacts through PJRT (`runtime`) and owns every loop.
//!
//! # Evaluation architecture
//!
//! Search throughput is bounded by evaluation (paper §4.1 deploys the
//! estimators "as a service where multiple NAHAS clients can send
//! parallel requests"), so every search driver is batch-structured: a
//! full controller batch is sampled up front, evaluated in one
//! [`search::Evaluator::evaluate_batch`] call, and rewarded/applied in
//! sample order — **bit-identical to the serial path for the same
//! seed**, whatever the evaluator. Three fan-out tiers implement the
//! trait:
//!
//! * **local** — [`search::SurrogateSim`] (also `TrainedEval`,
//!   `CostModelEval`): the trait's default serial loop;
//! * **parallel** — [`search::ParallelSim`]: a joint-decision memo
//!   cache ([`search::MemoCache`], dedups the controller's repeat
//!   samples) in front of `std::thread::scope` workers;
//! * **service** — [`service::ServiceEvaluator`]: one TCP connection
//!   per worker against a `nahas serve` simulator farm — the paper's
//!   parallel clients made literal;
//! * **cluster** — [`cluster::ShardedEvaluator`]: rendezvous-hash
//!   sharding of the joint key over a health-checked pool of `nahas
//!   serve` hosts, with deterministic failover when a host dies.
//!
//! Above the tiers sits the shared seam: [`search::EvalBroker`] wraps
//! any backend behind an `Arc` handle layer and multiplexes any number
//! of concurrent search sessions onto it, with a cross-search memo
//! cache (a joint decision evaluated by one search is never
//! re-evaluated by another — including *mid-flight*: a key one
//! session's batch has claimed is waited on, not dispatched twice) and
//! per-session stats deltas. Its dispatch path is admission-controlled
//! (`--broker-inflight N`, clamped to the backend's
//! [`search::Evaluator::capacity`] hint): up to N session batches
//! overlap on the backend, coalescing into shared backend calls. The
//! [`search::sweep`] orchestrator (`nahas sweep`) runs whole scenario
//! grids — latency targets x objectives x joint/phase drivers — as
//! concurrent sessions over one broker and merges the winners into a
//! union Pareto frontier per objective. With `--cache-dir`, the broker
//! cache also persists *across* processes ([`search::store`]): a
//! versioned append-only cache file with fingerprint-based staleness
//! rejection, so repeated runs and sweeps warm-start at zero backend
//! cost for every joint decision any earlier run already evaluated.
//!
//! CLI: `--evaluator local|parallel|service|cluster --workers N` on
//! `search` / `sweep` / `phase` (workers default to the machine's
//! parallelism; `--remote ADDR` selects the service tier, `--hosts
//! a:7878,b:7878=2` the cluster tier, with optional per-host weights).
//! Pick `parallel` on one box — the evaluation is compute-bound and
//! scales with cores until the batch size (`SearchCfg::batch`) caps
//! it; pick `service` to share one simulator farm between searches,
//! sized so `workers` is at most the farm's thread budget; pick
//! `cluster` to spread the run over several farms (`nahas
//! cluster-status` probes pool health and server-side cache hits).
//! Cache-hit, throughput and per-host counters come back in
//! `SearchOutcome::eval_stats`.
//!
//! The full architecture book for this stack — layer diagram, the
//! [`search::Evaluator`] contract, a life-of-an-evaluation
//! walkthrough, and a which-knob-do-I-turn table — is
//! `docs/ARCHITECTURE.md` at the repo root.

pub mod accel;
pub mod bench;
pub mod cluster;
pub mod costmodel;
pub mod data;
pub mod has;
pub mod metrics;
pub mod model;
pub mod nas;
pub mod pareto;
pub mod runtime;
pub mod search;
pub mod service;
pub mod trainer;
pub mod util;
