//! Analytical chip-area model (stands in for the paper's
//! synthesis-derived area estimator; see DESIGN.md §Substitutions).
//!
//! Component densities are representative of an edge-node (7–16 nm class)
//! implementation; absolute mm² values matter less than *relative* cost —
//! the area constraint in the reward (Eq. 4) is normalized to the
//! baseline design's area, exactly as the paper sets `T_area`.

use super::config::AcceleratorConfig;

/// mm^2 per SIMD unit (4 int8 MACs + operand routing).
const A_SIMD_UNIT: f64 = 0.0020;
/// mm^2 per KB of register file (flop-dense, multiported).
const A_RF_PER_KB: f64 = 0.0080;
/// Fixed per-lane overhead (sequencer, load/store) mm^2.
const A_LANE_FIXED: f64 = 0.050;
/// mm^2 per MB of local SRAM (incl. controller/banking).
const A_MEM_PER_MB: f64 = 1.20;
/// Fixed per-PE overhead (NoC port, control) mm^2.
const A_PE_FIXED: f64 = 0.20;
/// mm^2 per GB/s of IO bandwidth (PHY + SerDes lanes).
const A_IO_PER_GBPS: f64 = 0.30;
/// Fixed chip overhead (host interface, clocking, pads) mm^2.
const A_CHIP_FIXED: f64 = 5.0;

/// Die area of a configuration, mm^2.
pub fn chip_area_mm2(c: &AcceleratorConfig) -> f64 {
    let lane = c.simd_units as f64 * A_SIMD_UNIT
        + c.register_file_kb as f64 * A_RF_PER_KB
        + A_LANE_FIXED;
    let pe = c.compute_lanes as f64 * lane + c.local_memory_mb * A_MEM_PER_MB + A_PE_FIXED;
    c.num_pes() as f64 * pe + c.io_bandwidth_gbps * A_IO_PER_GBPS + A_CHIP_FIXED
}

/// The paper's `T_area`: the baseline design's area.
pub fn baseline_area_mm2() -> f64 {
    chip_area_mm2(&AcceleratorConfig::baseline())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::Rng;

    fn random_config(r: &mut Rng) -> AcceleratorConfig {
        let pick = |r: &mut Rng, v: &[usize]| v[r.below(v.len())];
        AcceleratorConfig {
            pe_x: pick(r, &[1, 2, 4, 6, 8]),
            pe_y: pick(r, &[1, 2, 4, 6, 8]),
            simd_units: pick(r, &[16, 32, 64, 128]),
            compute_lanes: pick(r, &[1, 2, 4, 8]),
            local_memory_mb: [0.5, 1.0, 2.0, 3.0, 4.0][r.below(5)],
            register_file_kb: pick(r, &[8, 16, 32, 64, 128]),
            io_bandwidth_gbps: [5.0, 10.0, 15.0, 20.0, 25.0][r.below(5)],
        }
    }

    #[test]
    fn baseline_area_is_edge_scale() {
        let a = baseline_area_mm2();
        // An edge accelerator die, not a datacenter one.
        assert!((20.0..200.0).contains(&a), "baseline area {a} mm^2");
    }

    #[test]
    fn area_monotone_in_every_knob() {
        let b = AcceleratorConfig::baseline();
        let a0 = chip_area_mm2(&b);
        for f in [
            &mut |c: &mut AcceleratorConfig| c.pe_x = 8,
            &mut |c: &mut AcceleratorConfig| c.simd_units = 128,
            &mut |c: &mut AcceleratorConfig| c.compute_lanes = 8,
            &mut |c: &mut AcceleratorConfig| c.local_memory_mb = 4.0,
            &mut |c: &mut AcceleratorConfig| c.register_file_kb = 128,
            &mut |c: &mut AcceleratorConfig| c.io_bandwidth_gbps = 25.0,
        ] as [&mut dyn FnMut(&mut AcceleratorConfig); 6]
        {
            let mut c = b;
            f(&mut c);
            assert!(chip_area_mm2(&c) > a0);
        }
    }

    #[test]
    fn prop_area_positive_and_bounded() {
        proptest::check(
            "area in sane band",
            proptest::CASES,
            random_config,
            |c| {
                let a = chip_area_mm2(c);
                if a > A_CHIP_FIXED && a < 1000.0 {
                    Ok(())
                } else {
                    Err(format!("area {a}"))
                }
            },
        );
    }

    #[test]
    fn prop_area_additive_in_pes() {
        // area(pe_x=2k) - fixed == 2 * (area(pe_x=k) - fixed) at equal y.
        proptest::check("pe additivity", 64, random_config, |c| {
            if c.pe_x > 4 {
                return Ok(());
            }
            let mut c2 = *c;
            c2.pe_x *= 2;
            let io = c.io_bandwidth_gbps * A_IO_PER_GBPS + A_CHIP_FIXED;
            let lhs = chip_area_mm2(&c2) - io;
            let rhs = 2.0 * (chip_area_mm2(c) - io);
            if (lhs - rhs).abs() < 1e-9 {
                Ok(())
            } else {
                Err(format!("{lhs} vs {rhs}"))
            }
        });
    }
}
