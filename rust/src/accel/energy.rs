//! Energy model: switching energy per MAC / SRAM byte / DRAM byte plus
//! area-proportional leakage. Calibrated (with `timing`) so the baseline
//! reproduces the paper's Table 3 energy scale — MobileNetV2 ~0.70 mJ —
//! and the qualitative orderings (fused-IBN trades MAC energy for DRAM
//! energy, SE/Swish burn leakage through serialization).

use super::timing::LayerCost;

/// pJ per int8 MAC (datapath switching, incl. operand movement within
/// the lane). Calibrated against the paper's Table 3: MobileNetV2
/// 0.70 mJ, Manual-EdgeTPU-S 1.78 mJ, EfficientNet-B1 1.50 mJ.
pub const E_MAC_PJ: f64 = 1.0;
/// pJ per byte of on-chip SRAM traffic.
pub const E_SRAM_PJ_PER_BYTE: f64 = 2.0;
/// pJ per byte of off-chip DRAM traffic (LPDDR-class).
pub const E_DRAM_PJ_PER_BYTE: f64 = 40.0;
/// Leakage + clock-tree power density, W per mm^2.
pub const LEAK_W_PER_MM2: f64 = 0.012;

/// Joules for one simulated layer (dynamic part only; leakage is added
/// at network level from total latency x area).
pub fn layer_dynamic_energy_j(c: &LayerCost, dram_write_bytes: u64) -> f64 {
    let mac = c.macs as f64 * E_MAC_PJ;
    let sram = c.sram_bytes as f64 * E_SRAM_PJ_PER_BYTE;
    let dram = (c.dram_read_bytes + dram_write_bytes) as f64 * E_DRAM_PJ_PER_BYTE;
    (mac + sram + dram) * 1e-12
}

/// Leakage energy over `latency_s` for a die of `area_mm2`.
pub fn leakage_energy_j(area_mm2: f64, latency_s: f64) -> f64 {
    area_mm2 * LEAK_W_PER_MM2 * latency_s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(macs: u64, sram: u64, dram: u64) -> LayerCost {
        LayerCost { macs, sram_bytes: sram, dram_read_bytes: dram, ..Default::default() }
    }

    #[test]
    fn dram_byte_costs_far_more_than_mac() {
        let mac_only = layer_dynamic_energy_j(&cost(1000, 0, 0), 0);
        let dram_only = layer_dynamic_energy_j(&cost(0, 0, 1000), 0);
        assert!(dram_only > 20.0 * mac_only);
    }

    #[test]
    fn write_traffic_counted() {
        let base = layer_dynamic_energy_j(&cost(0, 0, 0), 0);
        let w = layer_dynamic_energy_j(&cost(0, 0, 0), 10_000);
        assert!(w > base);
        assert!((w - 10_000.0 * E_DRAM_PJ_PER_BYTE * 1e-12).abs() < 1e-18);
    }

    #[test]
    fn leakage_scales_with_area_and_time() {
        let e = leakage_energy_j(80.0, 0.3e-3);
        assert!((e - 80.0 * LEAK_W_PER_MM2 * 0.3e-3).abs() < 1e-12);
        assert!(leakage_energy_j(160.0, 0.3e-3) > e);
    }
}
