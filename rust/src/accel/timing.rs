//! Cycle-level per-layer timing: maps one IR layer onto the PE array and
//! walks its weight passes with double-buffered DMA/compute overlap.
//!
//! Mapping (matches the Fig. 5 machine):
//!   * output *spatial* tiles across the `pe_x x pe_y` grid;
//!   * output *channels* across the compute lanes inside a PE;
//!   * the *reduction* (kh*kw*cin/groups) across the 4-way SIMD MAC
//!     units inside a lane — the axis depthwise convs cannot fill,
//!     which is where the paper's regular-vs-depthwise utilization gap
//!     comes from;
//!   * accumulators live in the lane register file; output chunks larger
//!     than the RF drain early (extra cycles);
//!   * weights too large for the PE-local memory stream in multiple
//!     passes (extra SRAM traffic + per-pass overhead).

use super::config::{
    AcceleratorConfig, ACC_BYTES, DW_DATAPATH_EFF, LAYER_OVERHEAD_CYCLES,
    MEM_USABLE_FRACTION, PASS_OVERHEAD_CYCLES, RF_ACC_FRACTION, RF_DRAIN_CYCLES,
    SCALAR_OP_MACS_PER_CYCLE, SCALAR_SYNC_CYCLES, SIMD_WAY,
};
use super::simulator::SimError;
use crate::model::{Layer, LayerInstance};

/// Cost breakdown of one layer on one configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerCost {
    /// End-to-end layer cycles (passes walked with DMA overlap).
    pub cycles: u64,
    pub compute_cycles: u64,
    pub dma_cycles: u64,
    /// DRAM bytes read (weights + non-retained inputs incl. halo).
    pub dram_read_bytes: u64,
    /// Output bytes (written to DRAM unless the simulator retains them).
    pub out_bytes: u64,
    /// On-chip SRAM traffic bytes (tile reads per pass + weight fill).
    pub sram_bytes: u64,
    pub macs: u64,
    /// Achieved MACs / peak MACs over the layer's cycles.
    pub utilization: f64,
}

fn ceil_div(a: u64, b: u64) -> u64 {
    a.div_ceil(b.max(1))
}

/// Compute cycles for one lane's share of the layer (before RF drains).
fn lane_compute_cycles(cfg: &AcceleratorConfig, li: &LayerInstance) -> (u64, u64) {
    let (oh, ow, oc) = li.out_shape();
    let simd = cfg.simd_units as u64;
    let way = SIMD_WAY as u64;
    // Worst-case (largest) spatial tile on the grid.
    let tile_h = ceil_div(oh as u64, cfg.pe_y as u64);
    let tile_w = ceil_div(ow as u64, cfg.pe_x as u64);
    match li.op {
        Layer::Conv2d { kh, kw, cin, groups, .. } => {
            let red = (kh * kw) as u64 * (cin / groups) as u64;
            let oc_lane = ceil_div(oc as u64, cfg.compute_lanes as u64);
            let per_elem = ceil_div(red, simd * way);
            let out_elems = tile_h * tile_w * oc_lane;
            (out_elems * per_elem, out_elems)
        }
        Layer::DwConv { k, c, .. } => {
            // SIMD units parallelize channels; the 4-way dot covers k*k
            // taps; DW_DATAPATH_EFF models the per-channel accumulator
            // port conflicts that keep real edge arrays ~3x less
            // efficient on depthwise (paper §3.2.2).
            let c_lane = ceil_div(c as u64, cfg.compute_lanes as u64);
            let ch_groups = ceil_div(c_lane, simd);
            let taps = ceil_div((k * k) as u64, way);
            let cyc = (tile_h * tile_w * ch_groups * taps) as f64 / DW_DATAPATH_EFF;
            (cyc.ceil() as u64, tile_h * tile_w * c_lane)
        }
        Layer::Dense { cin, cout } => {
            // Output channels across PEs*lanes; reduction across SIMD.
            let oc_pe = ceil_div(cout as u64, cfg.num_pes() as u64);
            let oc_lane = ceil_div(oc_pe, cfg.compute_lanes as u64);
            (oc_lane * ceil_div(cin as u64, simd * way), oc_lane)
        }
        Layer::GlobalPool { c } => {
            let elems = (li.in_h * li.in_w * c) as u64;
            let adders = (cfg.num_pes() * cfg.compute_lanes) as u64 * simd;
            (ceil_div(elems, adders.max(1)), ceil_div(c as u64, cfg.compute_lanes as u64))
        }
        Layer::SePool { .. } | Layer::Swish { .. } => {
            // Scalar path with a global sync: parallel only across PEs.
            let cyc = li.macs() as f64 / (SCALAR_OP_MACS_PER_CYCLE * cfg.num_pes() as f64);
            (cyc.ceil() as u64 + SCALAR_SYNC_CYCLES, 1)
        }
        Layer::Add { c } => {
            let elems = (li.in_h * li.in_w * c) as u64;
            let width = (cfg.num_pes() * cfg.compute_lanes) as u64 * simd;
            (ceil_div(elems, width.max(1)), ceil_div(elems, cfg.num_pes() as u64))
        }
    }
}

/// Bytes of the input tile (with conv halo) one PE needs resident, plus
/// the bytes of one halo row (the re-fetch unit when the tile is
/// row-striped to fit local memory).
fn input_tile_bytes(cfg: &AcceleratorConfig, li: &LayerInstance) -> (u64, u64) {
    let (oh, ow, _) = li.out_shape();
    let tile_h = ceil_div(oh as u64, cfg.pe_y as u64);
    let tile_w = ceil_div(ow as u64, cfg.pe_x as u64);
    let (k, stride, cin) = match li.op {
        Layer::Conv2d { kh, cin, stride, .. } => (kh as u64, stride as u64, cin as u64),
        Layer::DwConv { k, c, stride } => (k as u64, stride as u64, c as u64),
        Layer::Dense { cin, .. } => return (cin as u64, 0),
        Layer::GlobalPool { c } | Layer::SePool { c, .. } | Layer::Swish { c } => {
            return (ceil_div((li.in_h * li.in_w * c) as u64, cfg.num_pes() as u64), 0)
        }
        Layer::Add { c } => {
            return (2 * ceil_div((li.in_h * li.in_w * c) as u64, cfg.num_pes() as u64), 0)
        }
    };
    let ih = (tile_h - 1) * stride + k;
    let iw = (tile_w - 1) * stride + k;
    (ih * iw * cin, (k - 1) * iw * cin)
}

/// Per-configuration constants of the cost model, hoisted out of the
/// per-layer loop: every field is a pure function of the
/// [`AcceleratorConfig`] alone, recomputed identically for each layer
/// before this struct existed. Build one per config (one network walk)
/// and feed every [`layer_cost_ctx`] call — bit-identical to the
/// per-layer recomputation by construction.
#[derive(Clone, Copy, Debug)]
pub struct CostCtx {
    /// Register-file accumulator capacity, in accumulator elements.
    acc_elems: u64,
    /// Usable PE-local memory, bytes.
    usable: u64,
    /// DMA bytes per core cycle at the config's IO bandwidth.
    bytes_per_cycle: f64,
    /// Peak MACs per cycle across the whole array.
    peak_macs_cycle: f64,
}

impl CostCtx {
    pub fn new(cfg: &AcceleratorConfig) -> Self {
        CostCtx {
            acc_elems: ((cfg.register_file_kb * 1024) as f64 * RF_ACC_FRACTION
                / ACC_BYTES as f64)
                .max(1.0) as u64,
            usable: (cfg.local_memory_mb * 1e6 * MEM_USABLE_FRACTION).max(1.0) as u64,
            bytes_per_cycle: cfg.io_bandwidth_gbps / super::config::CLOCK_GHZ,
            peak_macs_cycle: (cfg.num_pes() * cfg.compute_lanes * cfg.macs_per_lane_cycle())
                as f64,
        }
    }
}

/// Full per-layer cost. `input_retained` skips the input DRAM fetch
/// (activations already resident from the previous layer);
/// `weights_resident` skips the weight DRAM stream (the whole network's
/// weights are pinned on-chip — steady-state serving). Builds a fresh
/// [`CostCtx`] per call; network walks build one and call
/// [`layer_cost_ctx`] directly.
pub fn layer_cost(
    cfg: &AcceleratorConfig,
    li: &LayerInstance,
    input_retained: bool,
    weights_resident: bool,
) -> Result<LayerCost, SimError> {
    layer_cost_ctx(cfg, &CostCtx::new(cfg), li, input_retained, weights_resident)
}

/// [`layer_cost`] with the per-config constants precomputed — the
/// simulator hot path (`ctx` must be built from this `cfg`).
pub fn layer_cost_ctx(
    cfg: &AcceleratorConfig,
    ctx: &CostCtx,
    li: &LayerInstance,
    input_retained: bool,
    weights_resident: bool,
) -> Result<LayerCost, SimError> {
    let macs = li.macs();
    let weight_bytes = li.weight_bytes();
    let out_bytes = li.output_bytes();
    let (lane_cycles, out_elems_lane) = lane_compute_cycles(cfg, li);

    // Register-file accumulation chunks.
    let rf_chunks = ceil_div(out_elems_lane, ctx.acc_elems);
    let compute_cycles = lane_cycles + rf_chunks * RF_DRAIN_CYCLES;

    // PE-local working set. Oversized activation tiles are row-striped:
    // the tile is processed in `act_split` sequential stripes (the
    // mapper's fallback for high-resolution layers), each stripe
    // re-fetching its halo rows; the mapping only fails when even one
    // stripe cannot fit.
    let usable = ctx.usable;
    let (in_tile, halo_row) = input_tile_bytes(cfg, li);
    let out_tile = ceil_div(out_bytes, cfg.num_pes() as u64);
    let act_split = ceil_div(in_tile + out_tile, usable).max(1);
    let max_split = {
        let (oh, _, _) = li.out_shape();
        ceil_div(oh as u64, cfg.pe_y as u64).max(1)
    };
    if act_split > max_split {
        return Err(SimError::WorkingSetTooLarge {
            layer: format!("{:?}", li.op),
            need: (in_tile + out_tile) / max_split,
            have: usable,
        });
    }
    let resident_act = ceil_div(in_tile + out_tile, act_split);
    let weight_room = usable.saturating_sub(resident_act);
    let n_passes = ceil_div(weight_bytes, weight_room.max(1)).max(1) * act_split;

    // DRAM traffic: weights stream once; inputs (with halo over-fetch)
    // unless retained on-chip from the previous layer. Row-striping
    // re-fetches one halo row per extra stripe.
    let in_bytes = li.input_bytes();
    let halo_fetch = {
        let total = in_tile * cfg.num_pes() as u64;
        total.max(in_bytes).min(in_bytes * 4) // halo over-fetch, bounded
            + (act_split - 1) * halo_row * cfg.num_pes() as u64
    };
    let weight_stream = if weights_resident { 0 } else { weight_bytes };
    let input_stream = if input_retained { 0 } else { halo_fetch };
    let dram_read = weight_stream + input_stream;

    // SRAM traffic: weights written once per PE (multicast fill), input
    // tile re-read every pass, outputs written once.
    let sram_bytes = weight_bytes * cfg.num_pes() as u64
        + in_tile * cfg.num_pes() as u64 * n_passes
        + out_bytes;

    // DMA cycles at io bandwidth (bytes per core cycle).
    let dma_cycles = (dram_read as f64 / ctx.bytes_per_cycle).ceil() as u64;

    // Pass walk with double buffering: DMA of pass i+1 overlaps compute
    // of pass i. Every pass costs the same, so the walk closes to one
    // multiply (exact u64 arithmetic — identical to the loop it
    // replaces): pipeline fill, then n identical overlapped passes.
    let comp_per_pass = ceil_div(compute_cycles, n_passes);
    let dma_per_pass = ceil_div(dma_cycles, n_passes);
    let cycles = dma_per_pass
        + n_passes * (comp_per_pass.max(dma_per_pass) + PASS_OVERHEAD_CYCLES)
        + LAYER_OVERHEAD_CYCLES;

    let utilization = macs as f64 / (cycles as f64 * ctx.peak_macs_cycle);

    Ok(LayerCost {
        cycles,
        compute_cycles,
        dma_cycles,
        dram_read_bytes: dram_read,
        out_bytes,
        sram_bytes,
        macs,
        utilization,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Layer, LayerInstance};

    fn conv(k: usize, cin: usize, cout: usize, stride: usize) -> LayerInstance {
        LayerInstance {
            op: Layer::Conv2d { kh: k, kw: k, cin, cout, stride, groups: 1 },
            in_h: 56,
            in_w: 56,
        }
    }

    #[test]
    fn regular_conv_beats_depthwise_utilization() {
        let cfg = AcceleratorConfig::baseline();
        let full = layer_cost(&cfg, &conv(3, 96, 96, 1), false, false).unwrap();
        let dw = layer_cost(
            &cfg,
            &LayerInstance { op: Layer::DwConv { k: 3, c: 96, stride: 1 }, in_h: 56, in_w: 56 },
            false,
            false,
        )
        .unwrap();
        // Paper: regular conv can use the hardware ~3x more efficiently
        // per MAC despite much larger FLOPs.
        assert!(
            full.utilization > 2.0 * dw.utilization,
            "conv util {} vs dw util {}",
            full.utilization,
            dw.utilization
        );
        // ... while depthwise still finishes faster in absolute time
        // for this shape (96x fewer MACs).
        assert!(dw.cycles < full.cycles);
    }

    #[test]
    fn utilization_below_one() {
        let cfg = AcceleratorConfig::baseline();
        for li in [conv(3, 64, 128, 1), conv(1, 256, 256, 1), conv(7, 3, 32, 2)] {
            let c = layer_cost(&cfg, &li, false, false).unwrap();
            assert!(c.utilization <= 1.0 + 1e-9, "{:?} util {}", li.op, c.utilization);
            assert!(c.cycles >= LAYER_OVERHEAD_CYCLES);
        }
    }

    #[test]
    fn retained_input_reduces_dram_traffic() {
        let cfg = AcceleratorConfig::baseline();
        let a = layer_cost(&cfg, &conv(3, 64, 64, 1), false, false).unwrap();
        let b = layer_cost(&cfg, &conv(3, 64, 64, 1), true, false).unwrap();
        assert!(b.dram_read_bytes < a.dram_read_bytes);
        assert_eq!(b.dram_read_bytes, conv(3, 64, 64, 1).weight_bytes());
    }

    #[test]
    fn more_bandwidth_never_slower() {
        let mut cfg = AcceleratorConfig::baseline();
        cfg.io_bandwidth_gbps = 5.0;
        let slow = layer_cost(&cfg, &conv(3, 128, 128, 1), false, false).unwrap();
        cfg.io_bandwidth_gbps = 25.0;
        let fast = layer_cost(&cfg, &conv(3, 128, 128, 1), false, false).unwrap();
        assert!(fast.cycles <= slow.cycles);
    }

    #[test]
    fn tiny_rf_adds_drain_cycles() {
        let mut cfg = AcceleratorConfig::baseline();
        cfg.register_file_kb = 8;
        let small = layer_cost(&cfg, &conv(3, 64, 256, 1), false, false).unwrap();
        cfg.register_file_kb = 128;
        let big = layer_cost(&cfg, &conv(3, 64, 256, 1), false, false).unwrap();
        assert!(small.compute_cycles > big.compute_cycles);
    }

    #[test]
    fn huge_activation_overflows_working_set() {
        // Un-stripable working set (spatial size 1, channels alone blow
        // the scratchpad) must be rejected.
        let mut cfg = AcceleratorConfig::baseline();
        cfg.local_memory_mb = 0.5;
        let li = LayerInstance {
            op: Layer::Dense { cin: 2_000_000, cout: 16 },
            in_h: 1,
            in_w: 1,
        };
        assert!(matches!(
            layer_cost(&cfg, &li, false, false),
            Err(SimError::WorkingSetTooLarge { .. })
        ));
    }

    #[test]
    fn oversized_tile_is_row_striped_not_rejected() {
        // A high-resolution conv that exceeds one PE's scratchpad must
        // stripe (slower) rather than fail — the segmentation workloads
        // of Table 4 depend on this.
        let mut cfg = AcceleratorConfig::baseline();
        cfg.local_memory_mb = 0.5;
        cfg.pe_x = 1;
        cfg.pe_y = 1;
        let li = LayerInstance {
            op: Layer::Conv2d { kh: 3, kw: 3, cin: 512, cout: 512, stride: 1, groups: 1 },
            in_h: 112,
            in_w: 112,
        };
        let striped = layer_cost(&cfg, &li, false, false).unwrap();
        cfg.local_memory_mb = 4.0;
        let roomy = layer_cost(&cfg, &li, false, false).unwrap();
        assert!(striped.cycles >= roomy.cycles, "striping cannot be faster");
        assert!(striped.dram_read_bytes >= roomy.dram_read_bytes, "halo re-fetch");
    }

    #[test]
    fn scalar_ops_are_expensive_per_mac() {
        let cfg = AcceleratorConfig::baseline();
        let se = layer_cost(
            &cfg,
            &LayerInstance { op: Layer::SePool { c: 128, reduced: 32 }, in_h: 14, in_w: 14 },
            true,
            false,
        )
        .unwrap();
        let cv = layer_cost(&cfg, &conv(1, 128, 128, 1), true, false).unwrap();
        assert!(se.utilization < cv.utilization / 5.0);
    }
}
