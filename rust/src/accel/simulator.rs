//! Whole-network simulation: walks the IR layer list through the
//! cycle-level timing model, decides inter-layer on-chip retention, and
//! aggregates latency / energy / power / utilization.
//!
//! This is the inner loop of every search (`search::*` evaluates tens of
//! thousands of (model, hw) pairs through it), so the hot entry point
//! [`simulate_network`] allocates nothing.

use super::area::chip_area_mm2;
use super::config::{AcceleratorConfig, CLOCK_GHZ};
use super::energy::{layer_dynamic_energy_j, leakage_energy_j};
use super::timing::{layer_cost_ctx, CostCtx, LayerCost};
use crate::model::NetworkIr;

/// Why a (model, hw) pairing could not be simulated — the paper's
/// "invalid points" in the HAS space (§3.3): configurations the
/// compiler/mapper rejects for the given network.
#[derive(Clone, Debug)]
pub enum SimError {
    /// One layer's activation working set exceeds PE-local memory.
    WorkingSetTooLarge { layer: String, need: u64, have: u64 },
    /// Static hardware validity rule failed (see `has::validity`).
    InvalidHardware(String),
    /// The network has no layers.
    EmptyNetwork,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::WorkingSetTooLarge { layer, need, have } => write!(
                f,
                "working set of {layer} needs {need} B but PE memory offers {have} B"
            ),
            SimError::InvalidHardware(msg) => write!(f, "invalid hardware: {msg}"),
            SimError::EmptyNetwork => write!(f, "empty network"),
        }
    }
}

impl std::error::Error for SimError {}

/// Aggregate simulation result.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimReport {
    pub latency_ms: f64,
    pub energy_mj: f64,
    pub power_w: f64,
    pub area_mm2: f64,
    /// MAC-weighted average utilization of the array.
    pub utilization: f64,
    pub dram_traffic_mb: f64,
    pub total_cycles: u64,
    pub total_macs: u64,
}

/// Simulate `net` on `cfg`. Allocation-free hot path.
pub fn simulate_network(
    cfg: &AcceleratorConfig,
    net: &NetworkIr,
) -> Result<SimReport, SimError> {
    simulate_inner(cfg, net, None)
}

/// As [`simulate_network`], also filling `per_layer` with each layer's
/// cost breakdown (for reports and the perf benches).
pub fn simulate_network_detailed(
    cfg: &AcceleratorConfig,
    net: &NetworkIr,
    per_layer: &mut Vec<LayerCost>,
) -> Result<SimReport, SimError> {
    per_layer.clear();
    simulate_inner(cfg, net, Some(per_layer))
}

fn simulate_inner(
    cfg: &AcceleratorConfig,
    net: &NetworkIr,
    mut per_layer: Option<&mut Vec<LayerCost>>,
) -> Result<SimReport, SimError> {
    if net.layers.is_empty() {
        return Err(SimError::EmptyNetwork);
    }
    let area = chip_area_mm2(cfg);
    let retain_budget = cfg.total_local_memory_bytes() * 0.25;
    // Steady-state serving: weights pinned on-chip when the whole model
    // fits the weight slice of local memory; otherwise every inference
    // streams them (the memory-to-compute-ratio effect of paper §4.4).
    let weights_resident = (net.total_params() as f64)
        <= cfg.total_local_memory_bytes()
            * super::config::WEIGHT_RESIDENT_FRACTION;

    let mut cycles: u64 = 0;
    let mut dyn_energy = 0.0f64;
    let mut dram_bytes: u64 = 0;
    let mut macs: u64 = 0;
    let mut util_weighted = 0.0f64;
    // The network input arrives from DRAM.
    let mut prev_retained = false;
    // Per-config cost-model constants, hoisted out of the layer loop.
    let ctx = CostCtx::new(cfg);

    for li in &net.layers {
        let cost = layer_cost_ctx(cfg, &ctx, li, prev_retained, weights_resident)?;
        // Retain this layer's output on-chip iff it fits in the
        // retention slice of local memory (then the next layer skips its
        // input fetch and we skip this output's write-back).
        let retain_out = (cost.out_bytes as f64) <= retain_budget;
        let write_bytes = if retain_out { 0 } else { cost.out_bytes };

        cycles += cost.cycles;
        dram_bytes += cost.dram_read_bytes + write_bytes;
        macs += cost.macs;
        util_weighted += cost.utilization * cost.macs as f64;
        dyn_energy += layer_dynamic_energy_j(&cost, write_bytes);
        if let Some(v) = per_layer.as_deref_mut() {
            v.push(cost);
        }
        prev_retained = retain_out;
    }

    let latency_s = cycles as f64 / (CLOCK_GHZ * 1e9);
    let energy_j = dyn_energy + leakage_energy_j(area, latency_s);
    Ok(SimReport {
        latency_ms: latency_s * 1e3,
        energy_mj: energy_j * 1e3,
        power_w: energy_j / latency_s,
        area_mm2: area,
        utilization: if macs > 0 { util_weighted / macs as f64 } else { 0.0 },
        dram_traffic_mb: dram_bytes as f64 / 1e6,
        total_cycles: cycles,
        total_macs: macs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Layer, NetworkIr};
    use crate::util::proptest;
    use crate::util::Rng;

    fn tiny_net() -> NetworkIr {
        let mut net = NetworkIr::new("tiny", 32, 32, 3);
        net.push(Layer::Conv2d { kh: 3, kw: 3, cin: 3, cout: 16, stride: 2, groups: 1 });
        net.push_ibn(3, 6, 16, 1);
        net.push_ibn(5, 6, 24, 2);
        net.push(Layer::GlobalPool { c: 24 });
        net.push(Layer::Dense { cin: 24, cout: 10 });
        net
    }

    #[test]
    fn basic_report_sane() {
        let r = simulate_network(&AcceleratorConfig::baseline(), &tiny_net()).unwrap();
        assert!(r.latency_ms > 0.0 && r.latency_ms < 10.0, "{r:?}");
        assert!(r.energy_mj > 0.0 && r.power_w > 0.0);
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        assert_eq!(r.total_macs, tiny_net().total_macs());
    }

    #[test]
    fn empty_network_rejected() {
        let net = NetworkIr::new("empty", 8, 8, 3);
        assert!(matches!(
            simulate_network(&AcceleratorConfig::baseline(), &net),
            Err(SimError::EmptyNetwork)
        ));
    }

    #[test]
    fn detailed_matches_aggregate() {
        let mut per = Vec::new();
        let cfg = AcceleratorConfig::baseline();
        let r = simulate_network_detailed(&cfg, &tiny_net(), &mut per).unwrap();
        assert_eq!(per.len(), tiny_net().layers.len());
        assert_eq!(per.iter().map(|c| c.cycles).sum::<u64>(), r.total_cycles);
    }

    #[test]
    fn latency_monotone_in_depth() {
        let cfg = AcceleratorConfig::baseline();
        let mut small = NetworkIr::new("s", 32, 32, 16);
        small.push_ibn(3, 6, 16, 1);
        let mut big = small.clone();
        for _ in 0..4 {
            big.push_ibn(3, 6, 16, 1);
        }
        let rs = simulate_network(&cfg, &small).unwrap();
        let rb = simulate_network(&cfg, &big).unwrap();
        assert!(rb.latency_ms > rs.latency_ms);
        assert!(rb.energy_mj > rs.energy_mj);
    }

    #[test]
    fn prop_more_compute_never_increases_cycles_much() {
        // Quadrupling the PE array must never slow a network down by
        // more than the halo over-fetch it adds (bounded regression):
        // compute strictly parallelizes, but tiles gain halo bytes on a
        // fixed-bandwidth link, so a small DMA-side regression is
        // physical (and exactly the compute/memory-balance effect the
        // paper's HAS is searching over).
        proptest::check(
            "pe monotonicity",
            64,
            |r: &mut Rng| {
                let mut net = NetworkIr::new("p", 32, 32, 8);
                for _ in 0..(1 + r.below(4)) {
                    let k = [3, 5, 7][r.below(3)];
                    let e = [3, 6][r.below(2)];
                    let w = [8, 16, 24][r.below(3)];
                    let s = [1, 2][r.below(2)];
                    if r.below(2) == 0 {
                        net.push_ibn(k, e, w, s);
                    } else {
                        net.push_fused_ibn(k, e, w, s, 1);
                    }
                }
                net
            },
            |net| {
                let mut small = AcceleratorConfig::baseline();
                small.pe_x = 2;
                small.pe_y = 2;
                let mut big = small;
                big.pe_x = 4;
                big.pe_y = 4;
                let rs = simulate_network(&small, net).map_err(|e| e.to_string())?;
                let rb = simulate_network(&big, net).map_err(|e| e.to_string())?;
                if rb.total_cycles as f64 <= rs.total_cycles as f64 * 1.25 {
                    Ok(())
                } else {
                    Err(format!("{} -> {}", rs.total_cycles, rb.total_cycles))
                }
            },
        );
    }

    #[test]
    fn prop_power_times_latency_is_energy() {
        proptest::check(
            "energy identity",
            32,
            |r: &mut Rng| {
                let mut net = NetworkIr::new("p", 16, 16, 8);
                net.push_ibn([3, 5, 7][r.below(3)], 6, 16, 1);
                net
            },
            |net| {
                let r = simulate_network(&AcceleratorConfig::baseline(), net)
                    .map_err(|e| e.to_string())?;
                let e = r.power_w * (r.latency_ms * 1e-3) * 1e3;
                if (e - r.energy_mj).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("{} vs {}", e, r.energy_mj))
                }
            },
        );
    }
}
