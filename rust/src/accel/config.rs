//! Hardware configuration (paper Table 1) and model calibration constants.

/// One point in the accelerator design space. Fields mirror Table 1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AcceleratorConfig {
    /// PEs along x (chip aspect ratio knob). Table 1: {1, 2, 4, 6, 8}.
    pub pe_x: usize,
    /// PEs along y. Table 1: {1, 2, 4, 6, 8}.
    pub pe_y: usize,
    /// SIMD units per compute lane, each 4-way MAC. Table 1: {16..128}.
    pub simd_units: usize,
    /// Compute lanes per PE (share the PE-local memory). Table 1: {1..8}.
    pub compute_lanes: usize,
    /// PE-local scratchpad, MB. Table 1: {0.5, 1, 2, 3, 4}.
    pub local_memory_mb: f64,
    /// Per-lane register file, KB. Table 1: {8, 16, 32, 64, 128}.
    pub register_file_kb: usize,
    /// Off-chip IO bandwidth, GB/s. Table 1: {5, 10, 15, 20, 25}.
    pub io_bandwidth_gbps: f64,
}

impl AcceleratorConfig {
    /// The production-optimized baseline design the paper fixes for
    /// platform-aware NAS: 4x4 PEs, 2 MB/PE, 4 lanes, 32 KB RF, 64
    /// 4-way SIMD units, 26 TOPS/s peak at 0.8 GHz.
    pub fn baseline() -> Self {
        AcceleratorConfig {
            pe_x: 4,
            pe_y: 4,
            simd_units: 64,
            compute_lanes: 4,
            local_memory_mb: 2.0,
            register_file_kb: 32,
            io_bandwidth_gbps: 20.0,
        }
    }

    pub fn num_pes(&self) -> usize {
        self.pe_x * self.pe_y
    }

    /// MACs per lane per cycle (each SIMD unit is a 4-way MAC).
    pub fn macs_per_lane_cycle(&self) -> usize {
        self.simd_units * SIMD_WAY
    }

    /// Peak int8 throughput in TOPS/s (1 MAC = 2 ops).
    pub fn peak_tops(&self) -> f64 {
        (self.num_pes() * self.compute_lanes * self.macs_per_lane_cycle()) as f64
            * 2.0
            * CLOCK_GHZ
            / 1e3
    }

    /// Total on-chip scratchpad bytes.
    pub fn total_local_memory_bytes(&self) -> f64 {
        self.local_memory_mb * 1e6 * self.num_pes() as f64
    }
}

// ---------------------------------------------------------------------------
// Microarchitectural constants (calibrated so the baseline reproduces the
// paper's headline numbers: 26 TOPS/s peak; MobileNetV2 ~0.30 ms / 0.70 mJ;
// see rust/tests/calibration.rs).
// ---------------------------------------------------------------------------

/// Core clock, GHz (paper: 0.8 GHz).
pub const CLOCK_GHZ: f64 = 0.8;
/// Dot-product depth of one SIMD unit (paper: "4-way SIMD" MACs).
pub const SIMD_WAY: usize = 4;
/// Cycles to drain/refill one register-file accumulation chunk.
pub const RF_DRAIN_CYCLES: u64 = 32;
/// Fraction of the register file usable for output accumulators (the
/// rest holds operands for double buffering).
pub const RF_ACC_FRACTION: f64 = 0.5;
/// Bytes per accumulator word (int32 accumulation for int8 MACs).
pub const ACC_BYTES: usize = 4;
/// Fraction of PE-local memory usable for one layer's working set (the
/// rest double-buffers the next tile / layer).
pub const MEM_USABLE_FRACTION: f64 = 0.5;
/// Fraction of *total* on-chip memory reserved for pinned weights. A
/// network whose int8 weights fit under this budget runs steady-state
/// with weights resident (no per-inference weight streaming) — the
/// serving mode edge TPUs are provisioned for, and the mechanism that
/// makes "larger models require a higher memory-to-compute ratio"
/// (paper §4.4) emerge from the model.
pub const WEIGHT_RESIDENT_FRACTION: f64 = 0.5;
/// Per-layer fixed dispatch overhead (descriptor decode, sync), cycles.
pub const LAYER_OVERHEAD_CYCLES: u64 = 2_000;
/// Per-pass DMA/compute handshake overhead, cycles.
pub const PASS_OVERHEAD_CYCLES: u64 = 200;
/// Depthwise datapath efficiency: the 4-way reduction tree cannot be
/// fed from a single-channel k*k window every cycle (port conflicts on
/// the per-channel accumulator); calibrated to the paper's ~3x
/// regular-vs-depthwise utilization gap.
pub const DW_DATAPATH_EFF: f64 = 0.35;
/// Serialization penalty for squeeze-and-excite / swish passes, which
/// run on a scalar path (paper §1: "often not supported or inefficient").
pub const SCALAR_OP_MACS_PER_CYCLE: f64 = 2.0;
/// Global-sync cycles charged to each scalar (SE/Swish) pass.
pub const SCALAR_SYNC_CYCLES: u64 = 30_000;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_peak_matches_paper_26_tops() {
        let b = AcceleratorConfig::baseline();
        let tops = b.peak_tops();
        assert!((tops - 26.2).abs() < 0.5, "peak {tops} TOPS/s");
    }

    #[test]
    fn baseline_dimensions() {
        let b = AcceleratorConfig::baseline();
        assert_eq!(b.num_pes(), 16);
        assert_eq!(b.macs_per_lane_cycle(), 256);
        assert!((b.total_local_memory_bytes() - 32e6).abs() < 1.0);
    }

    #[test]
    fn peak_scales_linearly_in_pes() {
        let mut c = AcceleratorConfig::baseline();
        c.pe_x = 8;
        assert!((c.peak_tops() / AcceleratorConfig::baseline().peak_tops() - 2.0).abs() < 1e-9);
    }
}
