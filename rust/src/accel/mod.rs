//! The parameterized edge-accelerator substrate (paper Fig. 5, Table 1).
//!
//! The paper evaluates against an in-house, validated cycle-accurate
//! simulator of an industry-standard edge accelerator plus an analytical
//! area model from hardware synthesis. Neither is available, so this
//! module rebuilds the closest behavioural equivalent from scratch (see
//! DESIGN.md §Substitutions):
//!
//! * [`config`] — the hardware configuration knobs (Table 1) and the
//!   production-baseline design point (4×4 PEs, 4 lanes, 64×4-way SIMD,
//!   2 MB local memory, 32 KB RF ⇒ 26 TOPS/s at 0.8 GHz);
//! * [`area`] — analytical per-component area model;
//! * [`energy`] — MAC/SRAM/DRAM/leakage energy model;
//! * [`timing`] — cycle-level, pass-by-pass layer timing with
//!   double-buffered DMA/compute overlap, register-file-bounded
//!   accumulation chunks and depthwise-datapath penalties;
//! * [`simulator`] — whole-network simulation with inter-layer on-chip
//!   activation retention, utilization accounting and invalid-point
//!   detection.

pub mod area;
pub mod config;
pub mod energy;
pub mod simulator;
pub mod timing;

pub use config::AcceleratorConfig;
pub use simulator::{simulate_network, simulate_network_detailed, SimError, SimReport};
pub use timing::{layer_cost, layer_cost_ctx, CostCtx, LayerCost};
