//! Neural-network layer IR: the common language between the NAS search
//! spaces (`nas`), the accelerator simulator (`accel`) and the cost-model
//! featurizer (`costmodel`).
//!
//! A [`NetworkIr`] is an ordered list of primitive layers with concrete
//! input spatial dimensions, produced by decoding a NAS sample. The
//! simulator costs each primitive independently (the paper's accelerator
//! executes networks layer-by-layer with on-chip double buffering).

pub mod ir;

pub use ir::{Layer, LayerInstance, NetworkIr};
