//! Primitive layer IR with shape inference and MACs/params accounting.

/// A primitive operator, as the accelerator executes it. Activation
/// functions are fused into the producing op (free on the SIMD datapath)
/// except [`Layer::Swish`]/[`Layer::SePool`], which the paper calls out as
/// expensive on edge accelerators and which we model explicitly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Layer {
    /// Regular (possibly grouped) convolution, 'same' padding.
    Conv2d { kh: usize, kw: usize, cin: usize, cout: usize, stride: usize, groups: usize },
    /// Depthwise convolution, 'same' padding.
    DwConv { k: usize, c: usize, stride: usize },
    /// Fully connected.
    Dense { cin: usize, cout: usize },
    /// Global average pool over the spatial dims.
    GlobalPool { c: usize },
    /// Squeeze-and-excite block (pool + 2 tiny FC + scale): cheap in
    /// MACs, expensive in serialization on the PE array (paper §1).
    SePool { c: usize, reduced: usize },
    /// Standalone Swish/SiLU activation pass over the tensor (the paper:
    /// "often not supported or inefficient in many specialized
    /// accelerators").
    Swish { c: usize },
    /// Elementwise residual add.
    Add { c: usize },
}

/// A layer plus its concrete input spatial size.
#[derive(Clone, Copy, Debug)]
pub struct LayerInstance {
    pub op: Layer,
    pub in_h: usize,
    pub in_w: usize,
}

impl LayerInstance {
    /// Output (h, w, c).
    pub fn out_shape(&self) -> (usize, usize, usize) {
        let ceil_div = |a: usize, b: usize| a.div_ceil(b);
        match self.op {
            Layer::Conv2d { cout, stride, .. } => {
                (ceil_div(self.in_h, stride), ceil_div(self.in_w, stride), cout)
            }
            Layer::DwConv { c, stride, .. } => {
                (ceil_div(self.in_h, stride), ceil_div(self.in_w, stride), c)
            }
            Layer::Dense { cout, .. } => (1, 1, cout),
            Layer::GlobalPool { c } => (1, 1, c),
            Layer::SePool { c, .. } => (self.in_h, self.in_w, c),
            Layer::Swish { c } | Layer::Add { c } => (self.in_h, self.in_w, c),
        }
    }

    /// Multiply-accumulate count.
    pub fn macs(&self) -> u64 {
        let (oh, ow, _) = self.out_shape();
        let (oh, ow) = (oh as u64, ow as u64);
        match self.op {
            Layer::Conv2d { kh, kw, cin, cout, groups, .. } => {
                oh * ow * (cout as u64) * (cin as u64 / groups as u64) * (kh * kw) as u64
            }
            Layer::DwConv { k, c, .. } => oh * ow * (c as u64) * (k * k) as u64,
            Layer::Dense { cin, cout } => (cin * cout) as u64,
            Layer::GlobalPool { c } => (self.in_h * self.in_w * c) as u64,
            Layer::SePool { c, reduced } => {
                (self.in_h * self.in_w * c + 2 * c * reduced + self.in_h * self.in_w * c)
                    as u64
            }
            Layer::Swish { c } => (self.in_h * self.in_w * c * 4) as u64, // sigmoid approx
            Layer::Add { c } => (self.in_h * self.in_w * c) as u64,
        }
    }

    /// Trainable parameter count (weights + biases).
    pub fn params(&self) -> u64 {
        match self.op {
            Layer::Conv2d { kh, kw, cin, cout, groups, .. } => {
                (kh * kw * (cin / groups) * cout + cout) as u64
            }
            Layer::DwConv { k, c, .. } => (k * k * c + c) as u64,
            Layer::Dense { cin, cout } => (cin * cout + cout) as u64,
            Layer::SePool { c, reduced } => (2 * c * reduced + reduced + c) as u64,
            _ => 0,
        }
    }

    /// Weight bytes at int8 (the accelerator runs 8-bit quantized).
    pub fn weight_bytes(&self) -> u64 {
        self.params()
    }

    /// Input activation bytes at int8.
    pub fn input_bytes(&self) -> u64 {
        let cin = match self.op {
            Layer::Conv2d { cin, .. } => cin,
            Layer::DwConv { c, .. } => c,
            Layer::Dense { cin, .. } => cin,
            Layer::GlobalPool { c }
            | Layer::SePool { c, .. }
            | Layer::Swish { c }
            | Layer::Add { c } => c,
        };
        let mult = if matches!(self.op, Layer::Add { .. }) { 2 } else { 1 };
        (self.in_h * self.in_w * cin * mult) as u64
    }

    /// Output activation bytes at int8.
    pub fn output_bytes(&self) -> u64 {
        let (oh, ow, oc) = self.out_shape();
        (oh * ow * oc) as u64
    }
}

/// A whole network: input shape plus layers in execution order.
#[derive(Clone, Debug, Default)]
pub struct NetworkIr {
    pub name: String,
    pub input_h: usize,
    pub input_w: usize,
    pub input_c: usize,
    pub layers: Vec<LayerInstance>,
}

impl NetworkIr {
    pub fn new(name: &str, h: usize, w: usize, c: usize) -> Self {
        NetworkIr { name: name.to_string(), input_h: h, input_w: w, input_c: c, layers: vec![] }
    }

    /// Reset in place to the state [`NetworkIr::new`] would build,
    /// keeping the name and layer allocations. Decode-buffer reuse for
    /// the evaluation hot path: a batch decodes thousands of networks
    /// into one buffer instead of allocating each.
    pub fn reset(&mut self, name: &str, h: usize, w: usize, c: usize) {
        self.name.clear();
        self.name.push_str(name);
        self.input_h = h;
        self.input_w = w;
        self.input_c = c;
        self.layers.clear();
    }

    /// Append a layer; its input spatial size is the current output.
    pub fn push(&mut self, op: Layer) {
        let (h, w) = self.cur_hw();
        self.layers.push(LayerInstance { op, in_h: h, in_w: w });
    }

    /// Current output spatial size.
    pub fn cur_hw(&self) -> (usize, usize) {
        match self.layers.last() {
            None => (self.input_h, self.input_w),
            Some(l) => {
                let (h, w, _) = l.out_shape();
                (h, w)
            }
        }
    }

    /// Current output channel count.
    pub fn cur_c(&self) -> usize {
        match self.layers.last() {
            None => self.input_c,
            Some(l) => l.out_shape().2,
        }
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Convenience: IBN block = expand 1x1 + depthwise kxk + project 1x1
    /// (+ residual add when stride 1 and cin == cout).
    pub fn push_ibn(&mut self, k: usize, expansion: usize, cout: usize, stride: usize) {
        let cin = self.cur_c();
        let cexp = (cin * expansion).max(1);
        if expansion != 1 {
            self.push(Layer::Conv2d { kh: 1, kw: 1, cin, cout: cexp, stride: 1, groups: 1 });
        }
        self.push(Layer::DwConv { k, c: cexp, stride });
        self.push(Layer::Conv2d { kh: 1, kw: 1, cin: cexp, cout, stride: 1, groups: 1 });
        if stride == 1 && cin == cout {
            self.push(Layer::Add { c: cout });
        }
    }

    /// Fused-IBN block = full kxk conv (to the expanded width, possibly
    /// grouped) + project 1x1 (+ residual). Paper §3.2.2 / MobileDets.
    pub fn push_fused_ibn(
        &mut self,
        k: usize,
        expansion: usize,
        cout: usize,
        stride: usize,
        groups: usize,
    ) {
        let cin = self.cur_c();
        let cexp = (cin * expansion).max(1);
        // Group count must divide both widths; fall back to 1 otherwise.
        let g = if cin % groups == 0 && cexp % groups == 0 { groups } else { 1 };
        self.push(Layer::Conv2d { kh: k, kw: k, cin, cout: cexp, stride, groups: g });
        self.push(Layer::Conv2d { kh: 1, kw: 1, cin: cexp, cout, stride: 1, groups: 1 });
        if stride == 1 && cin == cout {
            self.push(Layer::Add { c: cout });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(k: usize, cin: usize, cout: usize, stride: usize) -> Layer {
        Layer::Conv2d { kh: k, kw: k, cin, cout, stride, groups: 1 }
    }

    #[test]
    fn conv_shape_and_macs() {
        let l = LayerInstance { op: conv(3, 3, 16, 2), in_h: 224, in_w: 224 };
        assert_eq!(l.out_shape(), (112, 112, 16));
        assert_eq!(l.macs(), 112 * 112 * 16 * 3 * 9);
        assert_eq!(l.params(), 3 * 3 * 3 * 16 + 16);
    }

    #[test]
    fn dwconv_macs_much_cheaper_than_full() {
        let dw = LayerInstance { op: Layer::DwConv { k: 3, c: 96, stride: 1 }, in_h: 56, in_w: 56 };
        let full = LayerInstance { op: conv(3, 96, 96, 1), in_h: 56, in_w: 56 };
        // The paper: regular conv has ~7x the FLOPs of depthwise+1x1 for
        // some shapes; here full/dw = cin = 96.
        assert_eq!(full.macs() / dw.macs(), 96);
    }

    #[test]
    fn grouped_conv_divides_macs_and_params() {
        let g1 = LayerInstance { op: conv(3, 32, 64, 1), in_h: 8, in_w: 8 };
        let g4 = LayerInstance {
            op: Layer::Conv2d { kh: 3, kw: 3, cin: 32, cout: 64, stride: 1, groups: 4 },
            in_h: 8,
            in_w: 8,
        };
        assert_eq!(g1.macs() / g4.macs(), 4);
        assert!(g4.params() < g1.params());
    }

    #[test]
    fn ibn_block_structure() {
        let mut net = NetworkIr::new("t", 32, 32, 16);
        net.push_ibn(5, 6, 16, 1);
        // expand + dw + project + residual
        assert_eq!(net.layers.len(), 4);
        assert!(matches!(net.layers[3].op, Layer::Add { c: 16 }));
        assert_eq!(net.cur_c(), 16);
        assert_eq!(net.cur_hw(), (32, 32));
    }

    #[test]
    fn fused_ibn_skips_dwconv() {
        let mut net = NetworkIr::new("t", 32, 32, 16);
        net.push_fused_ibn(3, 6, 24, 2, 1);
        assert_eq!(net.layers.len(), 2);
        assert_eq!(net.cur_hw(), (16, 16));
        assert_eq!(net.cur_c(), 24);
    }

    #[test]
    fn fused_ibn_invalid_groups_fall_back() {
        let mut net = NetworkIr::new("t", 8, 8, 10); // 10 % 4 != 0
        net.push_fused_ibn(3, 6, 16, 1, 4);
        match net.layers[0].op {
            Layer::Conv2d { groups, .. } => assert_eq!(groups, 1),
            _ => panic!(),
        }
    }

    #[test]
    fn stride_on_odd_input_rounds_up() {
        let l = LayerInstance { op: conv(3, 8, 8, 2), in_h: 7, in_w: 7 };
        assert_eq!(l.out_shape(), (4, 4, 8));
    }

    #[test]
    fn network_totals_accumulate() {
        let mut net = NetworkIr::new("t", 16, 16, 3);
        net.push(conv(3, 3, 8, 1));
        net.push_ibn(3, 3, 8, 1);
        assert_eq!(
            net.total_macs(),
            net.layers.iter().map(|l| l.macs()).sum::<u64>()
        );
        assert!(net.total_params() > 0);
    }

    #[test]
    fn se_and_swish_shapes_passthrough() {
        let se = LayerInstance { op: Layer::SePool { c: 64, reduced: 16 }, in_h: 14, in_w: 14 };
        assert_eq!(se.out_shape(), (14, 14, 64));
        let sw = LayerInstance { op: Layer::Swish { c: 64 }, in_h: 14, in_w: 14 };
        assert_eq!(sw.out_shape(), (14, 14, 64));
        assert!(se.params() > 0 && sw.params() == 0);
    }
}
