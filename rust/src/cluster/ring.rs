//! Rendezvous (highest-random-weight) hashing of joint decision keys
//! over a host pool, with optional per-host weights.
//!
//! Every (key, host) pair gets a deterministic score; a key routes to
//! the up host with the highest score. Three properties make this the
//! right router for a sharded evaluator:
//!
//! * **affinity** — repeat samples of the same joint decision always
//!   score the hosts identically, so they land on the same host while
//!   it is up, preserving that host's cache locality;
//! * **minimal disruption** — when a host goes down, only the keys it
//!   owned move (each to its second-ranked host); every other key's
//!   argmax is unchanged. No ring segments to rebalance, no state;
//! * **proportional sharding** — with weights (`--hosts A=2,B=1`), a
//!   host's expected key share is proportional to its weight (the
//!   classic `-w / ln(u)` weighted-rendezvous score), so heterogeneous
//!   pools load in proportion to capacity. Reweighting one host moves
//!   keys only to or from that host — everyone else's pairwise scores
//!   are untouched (property-tested below).

/// 64-bit FNV-1a over `bytes`, folded into a running hash `h`.
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Rendezvous router over an ordered host list. Host order is part of
/// the identity (index `i` here must match index `i` of the pool), but
/// scores depend only on the host *address* (and weight), so the same
/// weighted address list in any order routes every key to the same
/// address.
///
/// # Examples
///
/// ```
/// use nahas::cluster::HashRing;
///
/// let ring = HashRing::new(&["10.0.0.1:7878", "10.0.0.2:7878", "10.0.0.3:7878"]);
/// let key = vec![3, 1, 4, 1, 5];
/// // Affinity: the same joint key always routes to the same host...
/// let owner = ring.owner(&key).unwrap();
/// assert_eq!(ring.owner(&key), Some(owner));
/// // ...and when that host goes down, the key fails over to another
/// // host while every key owned by a surviving host stays put.
/// let mut up = vec![true; 3];
/// up[owner] = false;
/// assert_ne!(ring.route(&key, &up), Some(owner));
/// ```
#[derive(Clone, Debug)]
pub struct HashRing {
    /// Per-host seed: FNV-1a of the host address.
    seeds: Vec<u64>,
    /// Per-host weight (1.0 = unweighted).
    weights: Vec<f64>,
}

impl HashRing {
    pub fn new<S: AsRef<str>>(hosts: &[S]) -> Self {
        HashRing {
            seeds: hosts.iter().map(|h| fnv1a(FNV_OFFSET, h.as_ref().as_bytes())).collect(),
            weights: vec![1.0; hosts.len()],
        }
    }

    /// Weighted ring: host `i` receives an expected `w_i / sum(w)`
    /// share of the key space. Non-positive / non-finite weights are
    /// clamped to a tiny positive value (the host still serves as a
    /// failover target but attracts essentially no primary traffic).
    pub fn weighted<S: AsRef<str>>(hosts: &[(S, f64)]) -> Self {
        HashRing {
            seeds: hosts
                .iter()
                .map(|(h, _)| fnv1a(FNV_OFFSET, h.as_ref().as_bytes()))
                .collect(),
            weights: hosts
                .iter()
                .map(|(_, w)| if w.is_finite() && *w > 0.0 { *w } else { f64::MIN_POSITIVE })
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.seeds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seeds.is_empty()
    }

    /// Rendezvous score of `key` on host `i`: `-w_i / ln(u)` with `u`
    /// a uniform (0, 1) draw derived from hash(host, key). Strictly
    /// increasing in the hash, so with equal weights the argmax is the
    /// same host the unweighted u64-comparison ring picked — weights
    /// scale each host's share without reshuffling anyone else.
    fn score(&self, i: usize, key: &[usize]) -> f64 {
        let mut h = self.seeds[i];
        for &w in key {
            h = fnv1a(h, &(w as u64).to_le_bytes());
        }
        // Top 53 bits -> u in (0, 1): the +0.5 keeps u off both ends,
        // so ln(u) is finite and negative.
        let u = ((h >> 11) as f64 + 0.5) / (1u64 << 53) as f64;
        self.weights[i] / -u.ln()
    }

    /// Route `key` to the highest-scoring host with `up[i]` set. Ties
    /// break toward the lower index (deterministic). `None` iff no
    /// host is up.
    pub fn route(&self, key: &[usize], up: &[bool]) -> Option<usize> {
        debug_assert_eq!(up.len(), self.seeds.len());
        let mut best: Option<(f64, usize)> = None;
        for (i, &is_up) in up.iter().enumerate().take(self.seeds.len()) {
            if !is_up {
                continue;
            }
            let s = self.score(i, key);
            if best.map(|(bs, _)| s > bs).unwrap_or(true) {
                best = Some((s, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// The host that owns `key` when every host is up.
    pub fn owner(&self, key: &[usize]) -> Option<usize> {
        self.route(key, &vec![true; self.seeds.len()])
    }

    /// Add a host at the end of the ring (index `len()`), with the
    /// same weight clamping as [`HashRing::weighted`]. Rendezvous
    /// scores are per-(host, key), so a join moves keys only *to* the
    /// new host: every pairwise argmax among the existing hosts is
    /// untouched (property-tested in `tests/proptests.rs`).
    pub fn join(&mut self, addr: &str, weight: f64) {
        self.seeds.push(fnv1a(FNV_OFFSET, addr.as_bytes()));
        self.weights.push(if weight.is_finite() && weight > 0.0 {
            weight
        } else {
            f64::MIN_POSITIVE
        });
    }

    /// Remove the host at `index`, shifting later hosts down by one
    /// (the caller must shift its pool the same way). A leave moves
    /// keys only *from* the removed host — each to its second-ranked
    /// host, exactly like the down-host failover path.
    pub fn leave(&mut self, index: usize) {
        self.seeds.remove(index);
        self.weights.remove(index);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Rng};

    fn hosts(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{i}:7878")).collect()
    }

    fn random_key(r: &mut Rng) -> Vec<usize> {
        (0..(1 + r.below(30))).map(|_| r.below(8)).collect()
    }

    #[test]
    fn routing_is_deterministic_and_covers_all_hosts() {
        let ring = HashRing::new(&hosts(3));
        let mut rng = Rng::new(1);
        let mut seen = [0usize; 3];
        for _ in 0..600 {
            let key = random_key(&mut rng);
            let a = ring.owner(&key).unwrap();
            let b = ring.owner(&key).unwrap();
            assert_eq!(a, b);
            seen[a] += 1;
        }
        // Rendezvous hashing balances within a small constant factor.
        for (i, &n) in seen.iter().enumerate() {
            assert!(n > 600 / 3 / 3, "host {i} got only {n}/600 keys");
        }
    }

    #[test]
    fn unit_weights_route_like_the_unweighted_ring() {
        let named = hosts(4);
        let unweighted = HashRing::new(&named);
        let weighted: Vec<(String, f64)> = named.iter().map(|h| (h.clone(), 1.0)).collect();
        let weighted = HashRing::weighted(&weighted);
        let mut rng = Rng::new(4);
        for _ in 0..400 {
            let key = random_key(&mut rng);
            assert_eq!(unweighted.owner(&key), weighted.owner(&key));
        }
    }

    #[test]
    fn weights_shard_proportionally() {
        // A 3:1 weight split should give the heavy host roughly three
        // times the keys (rendezvous sharding is exact in expectation;
        // allow generous sampling noise).
        let named = hosts(2);
        let ring = HashRing::weighted(&[(named[0].clone(), 3.0), (named[1].clone(), 1.0)]);
        let mut rng = Rng::new(7);
        let mut seen = [0usize; 2];
        for _ in 0..4000 {
            seen[ring.owner(&random_key(&mut rng)).unwrap()] += 1;
        }
        let ratio = seen[0] as f64 / seen[1] as f64;
        assert!((2.2..4.0).contains(&ratio), "3:1 weights sharded {seen:?} (ratio {ratio:.2})");
    }

    #[test]
    fn prop_down_host_moves_only_its_own_keys() {
        let ring = HashRing::new(&hosts(4));
        proptest::check(
            "rendezvous minimal disruption",
            proptest::CASES,
            |r: &mut Rng| (random_key(r), r.below(4)),
            |(key, down)| {
                let all = ring.owner(key).unwrap();
                let mut up = vec![true; 4];
                up[*down] = false;
                let survivor = ring.route(key, &up).unwrap();
                if all != *down && survivor != all {
                    return Err(format!(
                        "key owned by {all} moved to {survivor} when {down} went down"
                    ));
                }
                if survivor == *down {
                    return Err(format!("routed to the down host {down}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_reweighting_moves_keys_only_to_or_from_the_changed_host() {
        // Changing one host's weight must not shuffle keys between the
        // *other* hosts: a key that neither ring assigns to the changed
        // host keeps its owner. (Scores are per-(host, key); only the
        // changed host's score moved, so every other pairwise argmax is
        // untouched.)
        let named = hosts(4);
        let base: Vec<(String, f64)> =
            named.iter().zip([1.0, 2.0, 1.5, 1.0]).map(|(h, w)| (h.clone(), w)).collect();
        let ring_a = HashRing::weighted(&base);
        proptest::check(
            "weighted rendezvous reweighting isolation",
            proptest::CASES,
            |r: &mut Rng| {
                let key = random_key(r);
                let host = r.below(4);
                // Both directions: grow or shrink the host's weight.
                let factor = if r.below(2) == 0 { 4.0 } else { 0.25 };
                (key, host, factor)
            },
            |(key, host, factor)| {
                let mut rew = base.clone();
                rew[*host].1 *= factor;
                let ring_b = HashRing::weighted(&rew);
                let a = ring_a.owner(key).unwrap();
                let b = ring_b.owner(key).unwrap();
                if a != *host && b != *host && a != b {
                    return Err(format!(
                        "reweighting host {host} x{factor} moved a key from {a} to {b}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn all_hosts_down_routes_nowhere() {
        let ring = HashRing::new(&hosts(2));
        assert_eq!(ring.route(&[1, 2, 3], &[false, false]), None);
        assert_eq!(ring.route(&[1, 2, 3], &[false, true]), Some(1));
    }
}
