//! Rendezvous (highest-random-weight) hashing of joint decision keys
//! over a host pool.
//!
//! Every (key, host) pair gets a deterministic score; a key routes to
//! the up host with the highest score. Two properties make this the
//! right router for a sharded evaluator:
//!
//! * **affinity** — repeat samples of the same joint decision always
//!   score the hosts identically, so they land on the same host while
//!   it is up, preserving that host's cache locality;
//! * **minimal disruption** — when a host goes down, only the keys it
//!   owned move (each to its second-ranked host); every other key's
//!   argmax is unchanged. No ring segments to rebalance, no state.

/// 64-bit FNV-1a over `bytes`, folded into a running hash `h`.
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Rendezvous router over an ordered host list. Host order is part of
/// the identity (index `i` here must match index `i` of the pool), but
/// scores depend only on the host *address*, so the same address list
/// in any order routes every key to the same address.
#[derive(Clone, Debug)]
pub struct HashRing {
    /// Per-host seed: FNV-1a of the host address.
    seeds: Vec<u64>,
}

impl HashRing {
    pub fn new<S: AsRef<str>>(hosts: &[S]) -> Self {
        HashRing {
            seeds: hosts.iter().map(|h| fnv1a(FNV_OFFSET, h.as_ref().as_bytes())).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.seeds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seeds.is_empty()
    }

    /// Rendezvous score of `key` on host `i`.
    fn score(&self, i: usize, key: &[usize]) -> u64 {
        let mut h = self.seeds[i];
        for &w in key {
            h = fnv1a(h, &(w as u64).to_le_bytes());
        }
        h
    }

    /// Route `key` to the highest-scoring host with `up[i]` set. Ties
    /// break toward the lower index (deterministic). `None` iff no
    /// host is up.
    pub fn route(&self, key: &[usize], up: &[bool]) -> Option<usize> {
        debug_assert_eq!(up.len(), self.seeds.len());
        let mut best: Option<(u64, usize)> = None;
        for (i, &is_up) in up.iter().enumerate().take(self.seeds.len()) {
            if !is_up {
                continue;
            }
            let s = self.score(i, key);
            if best.map(|(bs, _)| s > bs).unwrap_or(true) {
                best = Some((s, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// The host that owns `key` when every host is up.
    pub fn owner(&self, key: &[usize]) -> Option<usize> {
        self.route(key, &vec![true; self.seeds.len()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Rng};

    fn hosts(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{i}:7878")).collect()
    }

    fn random_key(r: &mut Rng) -> Vec<usize> {
        (0..(1 + r.below(30))).map(|_| r.below(8)).collect()
    }

    #[test]
    fn routing_is_deterministic_and_covers_all_hosts() {
        let ring = HashRing::new(&hosts(3));
        let mut rng = Rng::new(1);
        let mut seen = [0usize; 3];
        for _ in 0..600 {
            let key = random_key(&mut rng);
            let a = ring.owner(&key).unwrap();
            let b = ring.owner(&key).unwrap();
            assert_eq!(a, b);
            seen[a] += 1;
        }
        // Rendezvous hashing balances within a small constant factor.
        for (i, &n) in seen.iter().enumerate() {
            assert!(n > 600 / 3 / 3, "host {i} got only {n}/600 keys");
        }
    }

    #[test]
    fn prop_down_host_moves_only_its_own_keys() {
        let ring = HashRing::new(&hosts(4));
        proptest::check(
            "rendezvous minimal disruption",
            proptest::CASES,
            |r: &mut Rng| (random_key(r), r.below(4)),
            |(key, down)| {
                let all = ring.owner(key).unwrap();
                let mut up = vec![true; 4];
                up[*down] = false;
                let survivor = ring.route(key, &up).unwrap();
                if all != *down && survivor != all {
                    return Err(format!(
                        "key owned by {all} moved to {survivor} when {down} went down"
                    ));
                }
                if survivor == *down {
                    return Err(format!("routed to the down host {down}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn all_hosts_down_routes_nowhere() {
        let ring = HashRing::new(&hosts(2));
        assert_eq!(ring.route(&[1, 2, 3], &[false, false]), None);
        assert_eq!(ring.route(&[1, 2, 3], &[false, true]), Some(1));
    }
}
