//! Host health probing: a one-shot protocol probe (used by `nahas
//! cluster-status`) and the background monitor thread that keeps a
//! pool's up/down flags fresh between batches.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::pool::HostState;
use crate::service::{Client, Wire};
use crate::util::json::Json;

/// Result of probing one host.
#[derive(Clone, Debug)]
pub struct HostProbe {
    pub addr: String,
    pub up: bool,
    /// Connect + request/response roundtrip time.
    pub rtt_ms: f64,
    /// "ok" or the failure reason.
    pub detail: String,
}

impl HostProbe {
    fn down(addr: &str, t0: Instant, detail: String) -> HostProbe {
        HostProbe { addr: addr.to_string(), up: false, rtt_ms: rtt(t0), detail }
    }
}

fn rtt(t0: Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e3
}

/// Probe one `nahas serve` host: TCP connect, then one intentionally
/// unknown-space request. Any well-formed JSON reply — the server
/// answers `{"valid": false, "error": "unknown space"}` — proves the
/// whole serve loop (accept, parse, dispatch, respond) is alive
/// without costing a simulation.
///
/// # Examples
///
/// ```no_run
/// use std::time::Duration;
/// use nahas::cluster::probe_host;
///
/// let p = probe_host("127.0.0.1:7878", Duration::from_millis(500));
/// println!("{}: up={} rtt={:.2}ms ({})", p.addr, p.up, p.rtt_ms, p.detail);
/// ```
pub fn probe_host(addr: &str, timeout: Duration) -> HostProbe {
    let t0 = Instant::now();
    let sock = match addr.to_socket_addrs().ok().and_then(|mut a| a.next()) {
        Some(s) => s,
        None => return HostProbe::down(addr, t0, "unresolvable address".to_string()),
    };
    let stream = match TcpStream::connect_timeout(&sock, timeout) {
        Ok(s) => s,
        Err(e) => return HostProbe::down(addr, t0, format!("connect: {e}")),
    };
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_write_timeout(Some(timeout));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => return HostProbe::down(addr, t0, format!("clone: {e}")),
    };
    if let Err(e) = writeln!(writer, "{{\"space\": \"__probe__\"}}") {
        return HostProbe::down(addr, t0, format!("write: {e}"));
    }
    let mut line = String::new();
    if let Err(e) = BufReader::new(stream).read_line(&mut line) {
        return HostProbe::down(addr, t0, format!("read: {e}"));
    }
    match Json::parse(line.trim()) {
        Ok(_) => HostProbe {
            addr: addr.to_string(),
            up: true,
            rtt_ms: rtt(t0),
            detail: "ok".to_string(),
        },
        Err(e) => HostProbe::down(addr, t0, format!("bad response: {e}")),
    }
}

/// Negotiated wire protocol for one host: open a client preferring
/// the binary frame protocol and report what the versioned hello
/// settled on — `"bin-v1"` when the host acked it, `"json"` when the
/// host predates the hello and the client fell back, `None` when the
/// host is unreachable. Used by `nahas cluster-status` to show each
/// host's protocol column.
pub fn probe_wire(addr: &str, timeout: Duration) -> Option<&'static str> {
    let client = Client::connect_wire(addr, Some(timeout), Wire::Binary).ok()?;
    Some(if client.is_binary() { "bin-v1" } else { "json" })
}

/// One host's server-side counters, as reported by the `{"stats":
/// true}` protocol request (see `service::serve_conn`).
#[derive(Clone, Debug)]
pub struct HostServeStats {
    /// Request lines served, of any kind.
    pub requests: u64,
    /// Simulate requests answered from the server-side result cache.
    pub cache_hits: u64,
    /// Simulate requests actually simulated.
    pub sim_evals: u64,
    /// Resident entries in the server-side result cache (0 when the
    /// host predates the field).
    pub cache_size: u64,
    /// Entries installed by warm-cache handoffs (0 when the host
    /// predates the field).
    pub installed: u64,
}

/// One stats roundtrip against a `nahas serve` host. `None` if the
/// host is unreachable or does not answer the stats protocol.
pub fn query_host_stats(addr: &str, timeout: Duration) -> Option<HostServeStats> {
    let sock = addr.to_socket_addrs().ok().and_then(|mut a| a.next())?;
    let stream = TcpStream::connect_timeout(&sock, timeout).ok()?;
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_write_timeout(Some(timeout));
    let mut writer = stream.try_clone().ok()?;
    writeln!(writer, "{{\"stats\": true}}").ok()?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).ok()?;
    let j = Json::parse(line.trim()).ok()?;
    let field = |k: &str| j.get(k).and_then(Json::as_f64).map(|n| n as u64);
    Some(HostServeStats {
        requests: field("requests")?,
        cache_hits: field("cache_hits")?,
        sim_evals: field("sim_evals")?,
        cache_size: field("cache_size").unwrap_or(0),
        installed: field("installed").unwrap_or(0),
    })
}

/// Background health monitor: probes every host each `interval` and
/// writes the verdict into the shared [`HostState`] up flags, so a
/// crashed host stops receiving new routes between batches and a
/// recovered one rejoins the ring. Stops (and joins) on drop.
pub struct HealthMonitor {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl HealthMonitor {
    pub fn start(
        hosts: Arc<Vec<HostState>>,
        interval: Duration,
        timeout: Duration,
    ) -> HealthMonitor {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let tick = Duration::from_millis(20);
            loop {
                for h in hosts.iter() {
                    if stop2.load(Ordering::Relaxed) {
                        return;
                    }
                    h.set_up(probe_host(h.addr(), timeout).up);
                }
                let mut slept = Duration::ZERO;
                while slept < interval {
                    if stop2.load(Ordering::Relaxed) {
                        return;
                    }
                    std::thread::sleep(tick);
                    slept += tick;
                }
            }
        });
        HealthMonitor { stop, handle: Some(handle) }
    }
}

impl Drop for HealthMonitor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::Server;

    #[test]
    fn probes_live_host_up_and_dead_host_down() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let p = probe_host(&server.addr.to_string(), Duration::from_millis(500));
        assert!(p.up, "{p:?}");
        assert_eq!(p.detail, "ok");
        assert!(p.rtt_ms >= 0.0);
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let p = probe_host(&dead, Duration::from_millis(500));
        assert!(!p.up, "{p:?}");
        server.stop();
    }

    #[test]
    fn wire_probe_reports_negotiated_protocol() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let w = probe_wire(&server.addr.to_string(), Duration::from_millis(500));
        assert_eq!(w, Some("bin-v1"));
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(probe_wire(&dead, Duration::from_millis(300)).is_none());
        server.stop();
    }

    #[test]
    fn stats_query_roundtrips_counters() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let st =
            query_host_stats(&server.addr.to_string(), Duration::from_millis(500)).unwrap();
        assert_eq!(st.cache_hits, 0);
        assert_eq!(st.sim_evals, 0);
        assert_eq!(st.cache_size, 0);
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(query_host_stats(&dead, Duration::from_millis(300)).is_none());
        server.stop();
    }

    #[test]
    fn monitor_flips_flags_as_hosts_die() {
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let pool = super::super::pool::HostPool::connect(&[addr], 1).unwrap();
        let shared = pool.shared_hosts();
        let (ivl, tmo) = (Duration::from_millis(30), Duration::from_millis(200));
        let mon = HealthMonitor::start(shared.clone(), ivl, tmo);
        assert!(shared[0].is_up());
        server.stop();
        // The listener is gone; within a few probe rounds the monitor
        // must mark the host down.
        let deadline = Instant::now() + Duration::from_secs(5);
        while shared[0].is_up() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(!shared[0].is_up(), "monitor never marked the dead host down");
        drop(mon);
    }
}
