//! Health-checked host pool: per-host connection sub-pools over the
//! PR 1 service [`Client`], plus the shared up/down + routing counters
//! that the ring, the failover path and the background health monitor
//! all read and write.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::service::{Client, Wire};

/// Default socket read/write timeout for cluster connections: a
/// stalled host must surface as a transport failure (and fail over)
/// rather than hang a shard worker — and with it the whole batch —
/// forever. Overridable per pool ([`HostPool::connect_opts`],
/// `--io-timeout` on the CLI) so churn tests can use sub-second
/// timeouts instead of sleeping through real 10s stalls.
pub(crate) const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Shared per-host state. The up flag and the counters are atomics so
/// shard worker threads, the health-probe thread and the coordinator
/// can all touch them without a lock.
#[derive(Debug)]
pub struct HostState {
    addr: String,
    up: AtomicBool,
    /// Samples routed to this host (cache hits included).
    pub requests: AtomicUsize,
    /// Service roundtrips this host answered.
    pub evals: AtomicUsize,
    /// Pipelined bursts this host answered: each burst keeps a whole
    /// key slice in flight on one connection
    /// ([`Client::query_pipelined`]) instead of one
    /// request/response at a time, so `evals / bursts` is the average
    /// multiplexing depth the event-loop server actually saw.
    pub bursts: AtomicUsize,
}

impl HostState {
    fn new(addr: &str, up: bool) -> Self {
        HostState {
            addr: addr.to_string(),
            up: AtomicBool::new(up),
            requests: AtomicUsize::new(0),
            evals: AtomicUsize::new(0),
            bursts: AtomicUsize::new(0),
        }
    }

    /// A fresh state carrying over another state's counters and flag.
    /// Membership changes rebuild the shared host `Arc` (the health
    /// monitor holds the old one), and the per-host attribution in
    /// `EvalStats` must survive the rebuild.
    fn copy_of(other: &HostState) -> Self {
        HostState {
            addr: other.addr.clone(),
            up: AtomicBool::new(other.is_up()),
            requests: AtomicUsize::new(other.requests.load(Ordering::Relaxed)),
            evals: AtomicUsize::new(other.evals.load(Ordering::Relaxed)),
            bursts: AtomicUsize::new(other.bursts.load(Ordering::Relaxed)),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn is_up(&self) -> bool {
        self.up.load(Ordering::Relaxed)
    }

    pub fn set_up(&self, up: bool) {
        self.up.store(up, Ordering::Relaxed);
    }
}

/// Point-in-time copy of one host's state, for reporting.
#[derive(Clone, Debug)]
pub struct HostSnapshot {
    pub addr: String,
    pub up: bool,
    pub requests: usize,
    pub evals: usize,
    /// Pipelined bursts answered (see [`HostState::bursts`]).
    pub bursts: usize,
}

/// The host pool: shared states (also held by the health monitor) and
/// this evaluator's private connection sub-pools, one per host.
pub struct HostPool {
    hosts: Arc<Vec<HostState>>,
    conns: Vec<Vec<Client>>,
    /// Target sub-pool size, for refilling after a host recovers.
    per_host: usize,
    /// Wire preference for every connection this pool opens (including
    /// refills): binary-negotiating by default, per-host fallback to
    /// JSON against old servers, forced JSON under `--wire json`.
    wire: Wire,
    /// Socket read/write timeout for every connection this pool opens.
    io_timeout: Duration,
}

impl HostPool {
    /// [`HostPool::connect_wire`] preferring the binary wire protocol
    /// (each host falls back to JSON independently if it predates the
    /// hello, so mixed clusters keep working).
    pub fn connect<S: AsRef<str>>(addrs: &[S], conns_per_host: usize) -> Result<HostPool> {
        Self::connect_wire(addrs, conns_per_host, Wire::Binary)
    }

    /// Open `conns_per_host` connections to every host. A host with at
    /// least one live connection is up (a transiently refused extra
    /// connection just shrinks its sub-pool); a host with none starts
    /// *down* (the health monitor or a later batch may find it again).
    /// Only a pool with zero reachable hosts is an error.
    pub fn connect_wire<S: AsRef<str>>(
        addrs: &[S],
        conns_per_host: usize,
        wire: Wire,
    ) -> Result<HostPool> {
        Self::connect_opts(addrs, conns_per_host, wire, DEFAULT_IO_TIMEOUT)
    }

    /// [`HostPool::connect_wire`] with an explicit socket timeout.
    /// Any positive `Duration` is accepted here (churn tests run with
    /// sub-second timeouts); the CLI layer validates `--io-timeout` to
    /// whole seconds ≥ 1.
    pub fn connect_opts<S: AsRef<str>>(
        addrs: &[S],
        conns_per_host: usize,
        wire: Wire,
        io_timeout: Duration,
    ) -> Result<HostPool> {
        let per_host = conns_per_host.max(1);
        let mut hosts = Vec::with_capacity(addrs.len());
        let mut conns = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let addr = addr.as_ref();
            let pool: Vec<Client> = (0..per_host)
                .filter_map(|_| Client::connect_wire(addr, Some(io_timeout), wire).ok())
                .collect();
            if pool.is_empty() {
                eprintln!("cluster: host {addr} unreachable at connect; starting it as down");
            } else if pool.len() < per_host {
                eprintln!("cluster: host {addr} opened {}/{per_host} connections", pool.len());
            }
            hosts.push(HostState::new(addr, !pool.is_empty()));
            conns.push(pool);
        }
        let pool = HostPool { hosts: Arc::new(hosts), conns, per_host, wire, io_timeout };
        if pool.hosts_up() == 0 {
            bail!("no cluster host reachable (tried {} hosts)", addrs.len());
        }
        Ok(pool)
    }

    /// The wire preference this pool connects with.
    pub fn wire(&self) -> Wire {
        self.wire
    }

    /// The socket read/write timeout every connection in this pool
    /// (including refills and the ephemeral failover connects) uses.
    pub fn io_timeout(&self) -> Duration {
        self.io_timeout
    }

    pub fn len(&self) -> usize {
        self.hosts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.hosts.is_empty()
    }

    pub fn hosts_up(&self) -> usize {
        self.hosts.iter().filter(|h| h.is_up()).count()
    }

    /// Pooled connections across every host — the cluster tier's
    /// concurrency capacity (its [`crate::search::Evaluator::capacity`]
    /// hint). At least 1: a pool cannot be constructed with zero
    /// reachable hosts.
    pub fn total_conns(&self) -> usize {
        self.conns.iter().map(Vec::len).sum::<usize>().max(1)
    }

    /// Total (bytes written, bytes read) across every host's
    /// connection sub-pool. Connections replaced by a transparent
    /// reconnect (or a host-recovery refill) restart their counters.
    pub fn wire_bytes(&self) -> (u64, u64) {
        self.conns
            .iter()
            .flat_map(|sub| sub.iter())
            .map(Client::wire_bytes)
            .fold((0, 0), |(tx, rx), (t, r)| (tx + t, rx + r))
    }

    /// Shared states, for handing to a [`super::HealthMonitor`].
    pub fn shared_hosts(&self) -> Arc<Vec<HostState>> {
        self.hosts.clone()
    }

    pub fn host(&self, i: usize) -> &HostState {
        &self.hosts[i]
    }

    /// Current up flags, index-aligned with the ring.
    pub fn up_flags(&self) -> Vec<bool> {
        self.hosts.iter().map(|h| h.is_up()).collect()
    }

    /// Per-host `(state, connection sub-pool)`, for fan-out.
    pub(crate) fn shards(&mut self) -> impl Iterator<Item = (&HostState, &mut Vec<Client>)> {
        self.hosts.iter().zip(self.conns.iter_mut())
    }

    pub(crate) fn conns_empty(&self, i: usize) -> bool {
        self.conns[i].is_empty()
    }

    /// Top up host `i`'s connection sub-pool (it was unreachable at
    /// connect time, or died and recovered). Stops at the first
    /// failure — a still-dead host costs one bounded connect attempt
    /// and falls back to the ephemeral-connection path.
    pub(crate) fn refill(&mut self, i: usize) {
        let addr = self.hosts[i].addr().to_string();
        let wire = self.wire;
        let io_timeout = self.io_timeout;
        let conns = &mut self.conns[i];
        while conns.len() < self.per_host {
            match Client::connect_wire(&addr, Some(io_timeout), wire) {
                Ok(c) => conns.push(c),
                Err(_) => break,
            }
        }
    }

    /// Membership join: append `addr` at index `len()`, spin up its
    /// connection sub-pool and rebuild the shared host `Arc` (existing
    /// counters carry over via [`HostState::copy_of`]). The caller must
    /// re-hand the new `Arc` to its health monitor — the old one keeps
    /// probing the pre-join states otherwise. Returns `true` if the new
    /// host was reachable (it starts up), `false` if it starts down.
    pub fn add_host(&mut self, addr: &str) -> bool {
        let sub: Vec<Client> = (0..self.per_host)
            .filter_map(|_| Client::connect_wire(addr, Some(self.io_timeout), self.wire).ok())
            .collect();
        let reachable = !sub.is_empty();
        if !reachable {
            eprintln!("cluster: joining host {addr} unreachable; starting it as down");
        }
        let mut hosts: Vec<HostState> = self.hosts.iter().map(HostState::copy_of).collect();
        hosts.push(HostState::new(addr, reachable));
        self.hosts = Arc::new(hosts);
        self.conns.push(sub);
        reachable
    }

    /// Membership leave: drop host `i`'s state and drain its
    /// connection sub-pool, shifting later hosts down by one (ring
    /// index `i` must be removed in the same breath). Counters of the
    /// surviving hosts carry over; the departed host's attribution is
    /// gone with it. Same `Arc`-rebuild caveat as [`Self::add_host`].
    pub fn remove_host(&mut self, i: usize) {
        let mut hosts: Vec<HostState> = self.hosts.iter().map(HostState::copy_of).collect();
        hosts.remove(i);
        self.hosts = Arc::new(hosts);
        self.conns.remove(i);
    }

    pub fn snapshot(&self) -> Vec<HostSnapshot> {
        self.hosts
            .iter()
            .map(|h| HostSnapshot {
                addr: h.addr.clone(),
                up: h.is_up(),
                requests: h.requests.load(Ordering::Relaxed),
                evals: h.evals.load(Ordering::Relaxed),
                bursts: h.bursts.load(Ordering::Relaxed),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::Server;

    #[test]
    fn connects_reachable_hosts_and_marks_dead_ones_down() {
        let live = Server::spawn("127.0.0.1:0").unwrap();
        // A dead address: bind, read the port, drop the listener.
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let pool = HostPool::connect(&[live.addr.to_string(), dead.clone()], 2).unwrap();
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.hosts_up(), 1);
        assert_eq!(pool.up_flags(), vec![true, false]);
        assert_eq!(pool.host(1).addr(), dead);
        live.stop();
    }

    #[test]
    fn all_hosts_dead_is_an_error() {
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(HostPool::connect(&[dead], 1).is_err());
    }
}
