//! [`ShardedEvaluator`] — `Evaluator::evaluate_batch` over a pool of
//! `nahas serve` hosts.
//!
//! One batch flows through the same `BatchPlan` memo-cache front as
//! the single-host tiers, then the deduped misses are routed by
//! rendezvous hash of the joint key ([`super::HashRing`]) to their
//! owning host and fanned out over that host's connection sub-pool —
//! each connection's share travelling as one **pipelined** id-tagged
//! burst ([`Client::query_pipelined`]) so the host's event loop keeps
//! the whole slice in flight at once.
//! Because every evaluation is a deterministic function of (space,
//! task, seed, decisions) — hardware metrics from the simulator
//! service, accuracy from the local [`SurrogateSim`] — *where* a
//! sample is computed can never change *what* it computes: results are
//! bit-identical to the serial and single-host paths for the same
//! seed, with or without failover (`tests/parallel_equivalence.rs`,
//! `tests/cluster_failover.rs`).
//!
//! Failover is deterministic re-routing: a host that fails a roundtrip
//! twice (once on the pooled connection, once on a fresh one) is
//! marked down; its pending keys — and, by rendezvous hashing, exactly
//! its key range — move to the surviving hosts, and the batch retries
//! until everything resolves or no host is up (those samples score
//! invalid and are *not* memoized, so a later resample retries).
//!
//! Cross-run persistence composes with the cluster tier at both ends,
//! and the per-host caches stay coherent without any protocol, because
//! every cache key is the full joint decision vector: a broker-side
//! `--cache-dir` spill replays identically whichever host (or tier)
//! originally computed an entry, and each host's own `nahas serve
//! --cache-dir` file can be copied between hosts or survive a
//! re-shard — rendezvous routing only decides *where* a key is
//! evaluated, never *what* the key means. The non-cacheable markers
//! that failover produces are dropped before any cache, so they can
//! never be spilled either (`tests/cluster_failover.rs`).

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::health::HealthMonitor;
use super::membership::{self, MembershipCmd, MembershipEvent, MembershipLog, WarmSource};
use super::pool::{HostPool, HostSnapshot, HostState};
use super::ring::HashRing;
use crate::nas::{NasSpace, NasSpaceId};
use crate::search::evaluator::{EvalCounters, EvalResult, EvalStats, Evaluator, HostEvalStats};
use crate::search::parallel::BatchPlan;
use crate::search::{joint_key, MemoCache, SurrogateSim};
use crate::service::{query_with_reconnect, remote_result, service_space_name, Client, Wire};

/// Shared read-only query context for shard worker threads.
struct ShardCtx<'a> {
    sim: &'a SurrogateSim,
    space_name: &'static str,
    seg: bool,
    nas_len: usize,
    /// Wire preference for ephemeral/replacement connections, matching
    /// the pool's so failover never silently changes protocol policy.
    wire: Wire,
    /// I/O timeout for those connections, matching the pool's.
    io_timeout: Duration,
}

/// Sharded multi-host remote evaluator (the cluster tier).
pub struct ShardedEvaluator {
    pool: HostPool,
    ring: HashRing,
    /// Local accuracy half (decode + task dispatch), exactly as in the
    /// other tiers, so cluster accuracy can never diverge.
    sim: SurrogateSim,
    space_name: &'static str,
    seg: bool,
    cache: MemoCache,
    counters: EvalCounters,
    monitor: Option<HealthMonitor>,
    /// Probe cadence, kept so membership changes (which swap the
    /// pool's shared host `Arc`) can restart the monitor on it.
    probe_interval: Option<Duration>,
    /// Batches evaluated so far — the clock `schedule_membership`
    /// indices run on.
    batches: usize,
    /// Programmatic membership commands: (apply before batch N, cmd).
    scheduled: Vec<(usize, MembershipCmd)>,
    /// Plan-file admin channel: (dir, plan lines already consumed).
    plan: Option<(PathBuf, usize)>,
    warm: WarmSource,
    events: MembershipLog,
}

impl ShardedEvaluator {
    /// Connect `conns_per_host` clients to every host (all hosts
    /// weighted equally). Hosts that are unreachable start down (their
    /// key ranges go to the survivors); only an entirely unreachable
    /// pool is an error.
    pub fn connect<S: AsRef<str>>(
        hosts: &[S],
        id: NasSpaceId,
        seed: u64,
        conns_per_host: usize,
    ) -> Result<Self> {
        let weighted: Vec<(String, f64)> =
            hosts.iter().map(|h| (h.as_ref().to_string(), 1.0)).collect();
        Self::connect_weighted(&weighted, id, seed, conns_per_host)
    }

    /// [`ShardedEvaluator::connect`] with per-host weights (`--hosts
    /// A=2,B=1`): a host's expected share of the key space is
    /// proportional to its weight, so heterogeneous pools shard in
    /// proportion to capacity. Weights change routing only — health,
    /// failover and connection sub-pools are weight-blind.
    pub fn connect_weighted(
        hosts: &[(String, f64)],
        id: NasSpaceId,
        seed: u64,
        conns_per_host: usize,
    ) -> Result<Self> {
        Self::connect_weighted_wire(hosts, id, seed, conns_per_host, Wire::Binary)
    }

    /// [`ShardedEvaluator::connect_weighted`] with an explicit wire
    /// preference (`--wire json|binary`). Every pooled, refilled and
    /// ephemeral connection the evaluator opens inherits it; with
    /// [`Wire::Binary`] each host still falls back to JSON
    /// independently if its server predates the hello.
    pub fn connect_weighted_wire(
        hosts: &[(String, f64)],
        id: NasSpaceId,
        seed: u64,
        conns_per_host: usize,
        wire: Wire,
    ) -> Result<Self> {
        Self::connect_weighted_opts(
            hosts,
            id,
            seed,
            conns_per_host,
            wire,
            super::pool::DEFAULT_IO_TIMEOUT,
        )
    }

    /// [`ShardedEvaluator::connect_weighted_wire`] with an explicit
    /// per-roundtrip I/O timeout (`--io-timeout SECS` on the CLI,
    /// which validates whole seconds >= 1; the API takes any positive
    /// [`Duration`] so churn tests can use sub-second timeouts).
    pub fn connect_weighted_opts(
        hosts: &[(String, f64)],
        id: NasSpaceId,
        seed: u64,
        conns_per_host: usize,
        wire: Wire,
        io_timeout: Duration,
    ) -> Result<Self> {
        let addrs: Vec<&str> = hosts.iter().map(|(a, _)| a.as_str()).collect();
        let pool = HostPool::connect_opts(&addrs, conns_per_host, wire, io_timeout)?;
        Ok(ShardedEvaluator {
            ring: HashRing::weighted(hosts),
            pool,
            sim: SurrogateSim::new(NasSpace::new(id), seed),
            space_name: service_space_name(id),
            seg: false,
            cache: MemoCache::new(16 * 1024),
            counters: EvalCounters::default(),
            monitor: None,
            probe_interval: None,
            batches: 0,
            scheduled: Vec::new(),
            plan: None,
            warm: WarmSource::default(),
            events: MembershipLog::default(),
        })
    }

    pub fn segmentation(mut self) -> Self {
        self.seg = true;
        self.sim = self.sim.segmentation();
        self
    }

    /// Start background health probes every `interval` (the CLI does;
    /// tests mostly leave routing to the query-failure path so runs
    /// stay deterministic).
    pub fn with_health_probes(mut self, interval: Duration) -> Self {
        let timeout = interval.min(Duration::from_millis(500));
        self.probe_interval = Some(interval);
        self.monitor = Some(HealthMonitor::start(self.pool.shared_hosts(), interval, timeout));
        self
    }

    /// Whether a background [`HealthMonitor`] is running.
    pub fn health_probes_active(&self) -> bool {
        self.monitor.is_some()
    }

    pub fn hosts(&self) -> usize {
        self.pool.len()
    }

    pub fn hosts_up(&self) -> usize {
        self.pool.hosts_up()
    }

    pub fn host_snapshots(&self) -> Vec<HostSnapshot> {
        self.pool.snapshot()
    }

    /// The wire preference every connection in the pool was opened
    /// with (individual hosts may still have negotiated down to JSON).
    pub fn wire(&self) -> Wire {
        self.pool.wire()
    }

    /// Poll `dir/membership.plan` before every batch and apply any
    /// commands appended since — the cross-process admin channel
    /// behind `nahas cluster join|leave --membership-dir DIR`.
    /// Commands already in the plan predate this evaluator and are
    /// skipped (otherwise every restart would replay the history).
    pub fn with_membership_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        let dir = dir.into();
        let cursor = membership::plan_len(&dir);
        self.plan = Some((dir, cursor));
        self
    }

    /// Schedule `cmd` to apply immediately before (0-based) batch
    /// `batch_index` — the deterministic trigger churn tests and the
    /// churn bench use. An index already passed applies before the
    /// next batch.
    pub fn schedule_membership(&mut self, batch_index: usize, cmd: MembershipCmd) {
        self.scheduled.push((batch_index, cmd));
    }

    /// The shared membership event log: every applied join/leave lands
    /// here. Clone it into a metrics sink
    /// ([`crate::metrics::MetricsSink::with_membership`]) to carry
    /// transitions in the metrics rows.
    pub fn membership_log(&self) -> MembershipLog {
        self.events.clone()
    }

    /// The warm-inventory slot join handoffs are carved from. The CLI
    /// fills it *after* boxing this evaluator into an
    /// [`crate::search::EvalBroker`], with a closure over a broker
    /// clone calling [`crate::search::EvalBroker::warm_entries`] —
    /// which takes only the broker's state lock, free while this
    /// backend is checked out and dispatching, so there is no cycle.
    pub fn warm_source(&self) -> WarmSource {
        self.warm.clone()
    }

    /// Batches evaluated so far (the clock membership scheduling runs
    /// on).
    pub fn batches_evaluated(&self) -> usize {
        self.batches
    }

    /// Add `addr` to the live pool: rank it into the rendezvous ring
    /// (keys move only *to* it — every other host's pairwise argmax is
    /// untouched), stream its key range from the warm source as a
    /// cache handoff, open its connection sub-pool, and restart the
    /// health monitor on the grown pool. The handoff is an
    /// optimization, never a correctness dependency: any failure is
    /// recorded in the event's `detail` and the host starts cold.
    pub fn join_host(&mut self, addr: &str, weight: f64) -> Result<MembershipEvent> {
        if (0..self.pool.len()).any(|i| self.pool.host(i).addr() == addr) {
            return Err(anyhow!("host {addr} is already in the pool"));
        }
        let join_index = self.pool.len();
        let mut ring = self.ring.clone();
        ring.join(addr, weight);
        // Hand off the joining host's key range BEFORE it takes
        // traffic, so its first shard batch is answerable from cache.
        let (mut handed_off, mut detail) = (0usize, String::new());
        if let Some(entries) = self.warm.entries() {
            let nas_len = self.sim.space.num_decisions();
            let key_len = nas_len + self.sim.has.num_decisions();
            let slice = membership::handoff_slice(
                &entries,
                &ring,
                join_index,
                self.sim.space.id,
                self.seg,
                nas_len,
                key_len,
            );
            match membership::send_handoff(addr, self.pool.io_timeout(), &slice) {
                Ok(n) => handed_off = n,
                Err(e) => detail = format!("handoff skipped: {e}"),
            }
        }
        let up = self.pool.add_host(addr);
        self.ring = ring;
        if !up && detail.is_empty() {
            detail = "unreachable at join; starting down".to_string();
        }
        self.restart_monitor();
        let event = MembershipEvent {
            batch: self.batches,
            action: "join",
            addr: addr.to_string(),
            hosts: self.pool.len(),
            handed_off,
            detail,
        };
        println!("{}", event.line());
        self.events.push(event.clone());
        Ok(event)
    }

    /// Remove `addr` from the live pool: its in-flight bursts are
    /// already drained (membership applies between batches, after the
    /// previous round's shard threads joined), its connection
    /// sub-pool closes, and its key range re-ranks onto the survivors
    /// — each key to its second-ranked host, exactly the route the
    /// failover ladder would have picked had the host crashed.
    pub fn leave_host(&mut self, addr: &str) -> Result<MembershipEvent> {
        let i = (0..self.pool.len())
            .find(|&i| self.pool.host(i).addr() == addr)
            .ok_or_else(|| anyhow!("host {addr} is not in the pool"))?;
        if self.pool.len() == 1 {
            return Err(anyhow!("refusing to remove the last host"));
        }
        self.pool.remove_host(i);
        self.ring.leave(i);
        self.restart_monitor();
        let event = MembershipEvent {
            batch: self.batches,
            action: "leave",
            addr: addr.to_string(),
            hosts: self.pool.len(),
            handed_off: 0,
            detail: String::new(),
        };
        println!("{}", event.line());
        self.events.push(event.clone());
        Ok(event)
    }

    /// Membership changes swap the pool's shared host `Arc`; a running
    /// monitor probes the stale one, so it is restarted on the new.
    fn restart_monitor(&mut self) {
        if let Some(interval) = self.probe_interval {
            let timeout = interval.min(Duration::from_millis(500));
            self.monitor = None; // drop joins the old thread first
            self.monitor =
                Some(HealthMonitor::start(self.pool.shared_hosts(), interval, timeout));
        }
    }

    /// Apply due membership changes. Runs at the front of every batch:
    /// the previous batch's scoped shard threads have joined, so this
    /// is the structural drain point — no burst is ever in flight
    /// across a membership change.
    fn apply_membership(&mut self) {
        let batch = self.batches;
        let mut due: Vec<MembershipCmd> = Vec::new();
        self.scheduled.retain(|(idx, cmd)| {
            if *idx <= batch {
                due.push(cmd.clone());
                false
            } else {
                true
            }
        });
        if let Some((dir, cursor)) = self.plan.take() {
            let (cmds, cursor) = membership::read_plan(&dir, cursor);
            due.extend(cmds);
            self.plan = Some((dir, cursor));
        }
        for cmd in due {
            let res = match &cmd {
                MembershipCmd::Join { addr, weight } => self.join_host(addr, *weight),
                MembershipCmd::Leave { addr } => self.leave_host(addr),
            };
            if let Err(e) = res {
                eprintln!("cluster membership: '{}' failed: {e}", cmd.to_line());
            }
        }
    }

    /// One roundtrip through the shared
    /// [`query_with_reconnect`] ladder (same policy as the single-host
    /// tier). `Err(())` means the host failed both attempts; the
    /// caller marks it down and re-routes.
    fn query_via(
        client: &mut Client,
        state: &HostState,
        ctx: &ShardCtx<'_>,
        key: &[usize],
    ) -> Result<EvalResult, ()> {
        let (addr, nas_len) = (state.addr(), ctx.nas_len);
        match query_with_reconnect(client, addr, ctx.space_name, ctx.seg, key, nas_len) {
            Ok(resp) => Ok(remote_result(&resp, ctx.sim, &key[..nas_len])),
            Err(_) => Err(()),
        }
    }

    /// Worker body: evaluate `keys` (indices into `pending`) against
    /// one connection of one host. The fast path pipelines the whole
    /// share as one id-tagged burst; any burst failure falls back to
    /// the serial ladder on a *fresh* connection (a dirty pipelined
    /// socket may hold unread responses and must never answer another
    /// query), which localizes the failure to an exact key. On double
    /// transport failure the host is marked down and the unfinished
    /// keys are returned for re-routing.
    fn shard_task(
        mut client: Option<&mut Client>,
        state: &HostState,
        ctx: &ShardCtx<'_>,
        keys: &[usize],
        pending: &[Vec<usize>],
    ) -> (Vec<(usize, EvalResult)>, Vec<usize>) {
        // A host that is up but was unreachable at connect time gets an
        // ephemeral connection for this round.
        let mut ephemeral;
        let client: &mut Client = match client.take() {
            Some(c) => c,
            None => match Client::connect_wire(state.addr(), Some(ctx.io_timeout), ctx.wire) {
                Ok(c) => {
                    ephemeral = c;
                    &mut ephemeral
                }
                Err(_) => {
                    state.set_up(false);
                    return (Vec::new(), keys.to_vec());
                }
            },
        };
        if keys.len() > 1 {
            let burst: Vec<Vec<usize>> = keys.iter().map(|&ki| pending[ki].clone()).collect();
            match client.query_pipelined(ctx.space_name, ctx.seg, &burst, ctx.nas_len) {
                Ok(resps) => {
                    state.bursts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let done = keys
                        .iter()
                        .zip(&resps)
                        .map(|(&ki, resp)| {
                            state.evals.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            (ki, remote_result(resp, ctx.sim, &pending[ki][..ctx.nas_len]))
                        })
                        .collect();
                    return (done, Vec::new());
                }
                Err(_) => match Client::connect_wire(state.addr(), Some(ctx.io_timeout), ctx.wire) {
                    Ok(fresh) => *client = fresh,
                    Err(_) => {
                        state.set_up(false);
                        eprintln!(
                            "cluster: host {} failed a pipelined burst and a reconnect; \
                             re-routing {} sample(s)",
                            state.addr(),
                            keys.len()
                        );
                        return (Vec::new(), keys.to_vec());
                    }
                },
            }
        }
        let mut done = Vec::with_capacity(keys.len());
        for (pos, &ki) in keys.iter().enumerate() {
            match Self::query_via(client, state, ctx, &pending[ki]) {
                Ok(r) => {
                    state.evals.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    done.push((ki, r));
                }
                Err(()) => {
                    state.set_up(false);
                    eprintln!(
                        "cluster: host {} failed twice; re-routing {} sample(s)",
                        state.addr(),
                        keys.len() - pos
                    );
                    return (done, keys[pos..].to_vec());
                }
            }
        }
        (done, Vec::new())
    }

    /// One fan-out round: route `todo` over the up hosts, drive each
    /// host's share through its connection sub-pool on scoped threads,
    /// and return the keys that need re-routing (their host died).
    fn query_round(
        &mut self,
        pending: &[Vec<usize>],
        nas_len: usize,
        todo: &[usize],
        fresh: &mut [Option<(EvalResult, bool)>],
        served: &mut [Option<usize>],
    ) -> Vec<usize> {
        let up = self.pool.up_flags();
        let mut by_host: Vec<Vec<usize>> = vec![Vec::new(); self.pool.len()];
        for &ki in todo {
            match self.ring.route(&pending[ki], &up) {
                Some(h) => by_host[h].push(ki),
                // No host up: score invalid but do NOT memoize, so the
                // next resample retries a possibly-recovered pool.
                None => fresh[ki] = Some((EvalResult::invalid(), false)),
            }
        }
        // A host that routes traffic but has no pooled connections
        // (unreachable at startup, recovered since) gets its sub-pool
        // topped up so it fans out like everyone else.
        for (h, keys) in by_host.iter().enumerate() {
            if !keys.is_empty() && self.pool.conns_empty(h) {
                self.pool.refill(h);
            }
        }
        let ctx = ShardCtx {
            sim: &self.sim,
            space_name: self.space_name,
            seg: self.seg,
            nas_len,
            wire: self.pool.wire(),
            io_timeout: self.pool.io_timeout(),
        };
        let mut failed: Vec<usize> = Vec::new();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (h, (state, conns)) in self.pool.shards().enumerate() {
                let keys = &by_host[h];
                if keys.is_empty() {
                    continue;
                }
                let ctx = &ctx;
                if conns.is_empty() {
                    let task = move || (h, Self::shard_task(None, state, ctx, keys, pending));
                    handles.push(s.spawn(task));
                    continue;
                }
                let tasks = conns.len().min(keys.len());
                let chunk = keys.len().div_ceil(tasks);
                for (client, ck) in conns.iter_mut().zip(keys.chunks(chunk)) {
                    let t = move || {
                        (h, Self::shard_task(Some(client), state, ctx, ck, pending))
                    };
                    handles.push(s.spawn(t));
                }
            }
            for handle in handles {
                let (h, (ok, fail)) = handle.join().expect("cluster shard worker panicked");
                for (ki, r) in ok {
                    fresh[ki] = Some((r, true));
                    served[ki] = Some(h);
                }
                failed.extend(fail);
            }
        });
        // Deterministic retry order (thread join order is not).
        failed.sort_unstable();
        failed
    }

    /// Evaluate all deduped keys, re-routing around dead hosts until
    /// everything resolves (bounded by the pool size: each extra round
    /// requires at least one more host to have died). Also reports
    /// which host served each key, for per-host attribution.
    fn query_pending(
        &mut self,
        pending: &[Vec<usize>],
        nas_len: usize,
    ) -> (Vec<(EvalResult, bool)>, Vec<Option<usize>>) {
        let mut fresh: Vec<Option<(EvalResult, bool)>> = vec![None; pending.len()];
        let mut served: Vec<Option<usize>> = vec![None; pending.len()];
        let mut todo: Vec<usize> = (0..pending.len()).collect();
        for _ in 0..=self.pool.len() {
            if todo.is_empty() {
                break;
            }
            todo = self.query_round(pending, nas_len, &todo, &mut fresh, &mut served);
        }
        // Only reachable if hosts flap up/down mid-batch faster than
        // the round bound: fail those samples without memoizing them.
        for ki in todo {
            fresh[ki] = Some((EvalResult::invalid(), false));
        }
        let out = fresh.into_iter().map(|r| r.expect("all pending slots resolved")).collect();
        (out, served)
    }

    /// Attribute each sample of the batch to a host: misses go to the
    /// host that actually served their key this batch (failover moves
    /// the attribution with the eval, so a dead host never collects
    /// phantom traffic), cache hits to the host their key routes to
    /// right now (affinity: that host answered the original miss).
    fn attribute_requests(
        &self,
        keys: &[Vec<usize>],
        pending: &[Vec<usize>],
        served: &[Option<usize>],
    ) {
        let by_key: HashMap<&[usize], usize> = pending
            .iter()
            .zip(served)
            .filter_map(|(k, s)| s.map(|h| (k.as_slice(), h)))
            .collect();
        let up = self.pool.up_flags();
        for key in keys {
            let host =
                by_key.get(key.as_slice()).copied().or_else(|| self.ring.route(key, &up));
            if let Some(h) = host {
                self.pool.host(h).requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
        }
    }
}

impl Evaluator for ShardedEvaluator {
    fn evaluate(&mut self, nas_d: &[usize], has_d: &[usize]) -> EvalResult {
        self.evaluate_batch(&[(nas_d.to_vec(), has_d.to_vec())])[0]
    }

    fn evaluate_batch(&mut self, batch: &[(Vec<usize>, Vec<usize>)]) -> Vec<EvalResult> {
        self.evaluate_batch_tagged(batch).into_iter().map(|(r, _)| r).collect()
    }

    fn evaluate_batch_tagged(
        &mut self,
        batch: &[(Vec<usize>, Vec<usize>)],
    ) -> Vec<(EvalResult, bool)> {
        if batch.is_empty() {
            return Vec::new();
        }
        self.apply_membership();
        self.batches += 1;
        self.counters.requests += batch.len();
        let nas_len = batch[0].0.len();
        assert!(
            batch.iter().all(|(nas_d, _)| nas_d.len() == nas_len),
            "mixed decision lengths in one batch"
        );
        let keys: Vec<Vec<usize>> = batch.iter().map(|(n, h)| joint_key(n, h)).collect();
        let plan = BatchPlan::build(&mut self.cache, batch);
        let (fresh, served) = self.query_pending(plan.pending(), nas_len);
        self.counters.evals += fresh.len();
        self.attribute_requests(&keys, plan.pending(), &served);
        // The per-slot markers survive into the tagged result, so an
        // all-hosts-down invalid is never memoized upstream either.
        let out = plan.finish_tagged(&mut self.cache, fresh);
        self.counters.invalid += out.iter().filter(|(r, _)| !r.valid).count();
        out
    }

    /// The pool's total pooled connections: each can carry one service
    /// roundtrip at a time, so that is how much concurrent batch work
    /// the broker can usefully admit against this tier.
    fn capacity(&self) -> usize {
        self.pool.total_conns()
    }

    fn wire_bytes(&self) -> (u64, u64) {
        self.pool.wire_bytes()
    }

    fn stats(&self) -> EvalStats {
        let mut st = self.counters.stats();
        let snaps = self.pool.snapshot();
        st.hosts_down = snaps.iter().filter(|s| !s.up).count();
        st.per_host = snaps
            .into_iter()
            .map(|s| HostEvalStats {
                host: s.addr,
                requests: s.requests,
                evals: s.evals,
                down: !s.up,
            })
            .collect();
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::has::HasSpace;
    use crate::service::Server;
    use crate::util::Rng;

    fn spawn_cluster(n: usize) -> (Vec<Server>, Vec<String>) {
        let servers: Vec<Server> =
            (0..n).map(|_| Server::spawn("127.0.0.1:0").unwrap()).collect();
        let hosts = servers.iter().map(|s| s.addr.to_string()).collect();
        (servers, hosts)
    }

    #[test]
    fn sharded_batch_matches_local_simulator() {
        let (servers, hosts) = spawn_cluster(3);
        let mut cluster =
            ShardedEvaluator::connect(&hosts, NasSpaceId::EfficientNet, 3, 2).unwrap();
        let mut local = SurrogateSim::new(NasSpace::new(NasSpaceId::EfficientNet), 3);
        let has = HasSpace::new();
        let mut rng = Rng::new(9);
        let batch: Vec<(Vec<usize>, Vec<usize>)> = (0..24)
            .map(|_| (local.space.random(&mut rng), has.random(&mut rng)))
            .collect();
        let cs = cluster.evaluate_batch(&batch);
        let ls = local.evaluate_batch(&batch);
        for (c, l) in cs.iter().zip(&ls) {
            assert_eq!(c.valid, l.valid);
            if c.valid {
                assert_eq!(c.acc.to_bits(), l.acc.to_bits());
                assert_eq!(c.latency_ms.to_bits(), l.latency_ms.to_bits());
                assert_eq!(c.energy_mj.to_bits(), l.energy_mj.to_bits());
                assert_eq!(c.area_mm2.to_bits(), l.area_mm2.to_bits());
            }
        }
        // Replay: all memo-cache hits, no new service traffic.
        let evals_before: usize = cluster.host_snapshots().iter().map(|s| s.evals).sum();
        let again = cluster.evaluate_batch(&batch);
        let evals_after: usize = cluster.host_snapshots().iter().map(|s| s.evals).sum();
        assert_eq!(evals_before, evals_after, "replay must be pure cache hits");
        for (a, b) in cs.iter().zip(&again) {
            assert_eq!(a.acc.to_bits(), b.acc.to_bits());
        }
        let st = cluster.stats();
        assert_eq!(st.requests, 48);
        assert_eq!(st.evals + st.cache_hits, st.requests);
        assert_eq!(st.hosts_down, 0);
        assert_eq!(st.per_host.len(), 3);
        assert_eq!(st.per_host.iter().map(|h| h.requests).sum::<usize>(), 48);
        for s in servers {
            s.stop();
        }
    }

    #[test]
    fn repeat_samples_keep_host_affinity() {
        let (servers, hosts) = spawn_cluster(3);
        let mut cluster =
            ShardedEvaluator::connect(&hosts, NasSpaceId::MobileNetV2, 1, 1).unwrap();
        let space = NasSpace::new(NasSpaceId::MobileNetV2);
        let has = HasSpace::new();
        let mut rng = Rng::new(2);
        let nas_d = space.random(&mut rng);
        let sample = vec![(nas_d, has.baseline_decisions())];
        cluster.evaluate_batch(&sample);
        let one: Vec<usize> = cluster.host_snapshots().iter().map(|s| s.evals).collect();
        assert_eq!(one.iter().sum::<usize>(), 1, "exactly one host evaluated the sample");
        // Ten repeats: all requests route to the same host, zero new evals.
        for _ in 0..10 {
            cluster.evaluate_batch(&sample);
        }
        let snaps = cluster.host_snapshots();
        let owner = one.iter().position(|&e| e == 1).unwrap();
        assert_eq!(snaps[owner].requests, 11);
        assert_eq!(snaps[owner].evals, 1);
        for (i, s) in snaps.iter().enumerate() {
            if i != owner {
                assert_eq!((s.requests, s.evals), (0, 0), "host {i} saw foreign traffic");
            }
        }
        for s in servers {
            s.stop();
        }
    }
}
