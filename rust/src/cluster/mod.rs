//! Sharded multi-host evaluation (the cluster tier).
//!
//! PR 1's service tier parallelized one search against *one* `nahas
//! serve` host; this subsystem shards a search across a pool of them —
//! the paper's "multiple NAHAS clients can send parallel requests"
//! scaled past a single box. Four parts:
//!
//! * [`ring`] — rendezvous hashing of the joint decision key (with
//!   optional per-host weights for heterogeneous pools: `--hosts
//!   A=2,B=1`), so repeat samples of the same (alpha, h) always land
//!   on the same host while it is up (cache affinity), and a dead
//!   host's key range re-routes to the survivors without touching
//!   anyone else's;
//! * [`pool`] — the host pool: shared up/down flags + routing counters
//!   and a per-host connection sub-pool over the service [`Client`];
//! * [`health`] — one-shot protocol probes (`nahas cluster-status`)
//!   and the background [`HealthMonitor`] thread;
//! * [`evaluator`] — [`ShardedEvaluator`], the `Evaluator` that ties
//!   them together behind the same memo-cache front as the other
//!   tiers. Bit-identical to the serial path for the same seed, with
//!   or without failover. It advertises the pool's total pooled
//!   connections as its [`crate::search::Evaluator::capacity`] hint,
//!   so a shared [`crate::search::EvalBroker`] admits overlapping
//!   session batches against it (`--broker-inflight`);
//! * [`membership`] — elastic membership: hosts join and leave the
//!   live pool between batches (`nahas cluster join|leave`), with a
//!   joining host's key range streamed from the broker's warm cache
//!   as a checksummed segment handoff so it answers its first shard
//!   traffic without simulating.
//!
//! CLI: `nahas search --evaluator cluster --hosts a:7878,b:7878` and
//! `nahas cluster-status --hosts ...`. The whole stack, including how
//! this tier composes with the broker and the persistent caches, is
//! documented in `docs/ARCHITECTURE.md`.
//!
//! [`Client`]: crate::service::Client

pub mod evaluator;
pub mod health;
pub mod membership;
pub mod pool;
pub mod ring;

pub use evaluator::ShardedEvaluator;
pub use health::{
    probe_host, probe_wire, query_host_stats, HealthMonitor, HostProbe, HostServeStats,
};
pub use membership::{MembershipCmd, MembershipEvent, MembershipLog, WarmSource};
pub use pool::{HostPool, HostSnapshot, HostState};
pub use ring::HashRing;
