//! Elastic cluster membership: the commands, events and warm-cache
//! handoff machinery that let hosts join and leave a live
//! [`super::ShardedEvaluator`].
//!
//! A membership change is applied *between* batches (the previous
//! round's shard threads have joined, so in-flight bursts are drained
//! structurally) and touches exactly three things: the rendezvous ring
//! gains or loses one seed, the pool gains or loses one connection
//! sub-pool, and — on join — the new host receives its key range from
//! the broker's warm cache as a [`crate::search::store`] segment
//! stream over the binary wire ([`send_handoff`]), so it answers its
//! first shard traffic from cache instead of cold simulation.
//!
//! Rendezvous scores are per-(host, key), so the PR 2 invariant
//! carries over verbatim: a join moves keys only *to* the new host, a
//! leave only *from* the departed one — every other pairwise argmax is
//! untouched (property-tested in `tests/proptests.rs`). Results are
//! bit-identical either way: routing decides *where* a key is
//! evaluated, never *what* it computes.
//!
//! Two triggers feed a live evaluator:
//!
//! * [`super::ShardedEvaluator::schedule_membership`] applies a
//!   command immediately before a given batch index — the
//!   deterministic trigger churn tests and benches use;
//! * a *plan file* (`membership.plan` under `--membership-dir`),
//!   appended to by the `nahas cluster join|leave` admin commands and
//!   polled before every batch — the cross-process admin channel.

use std::fs::{self, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::ring::HashRing;
use crate::nas::NasSpaceId;
use crate::search::evaluator::EvalResult;
use crate::search::store;
use crate::service::{Client, Wire};
use crate::util::json::obj;

/// One membership change, as scheduled or read from a plan file.
#[derive(Clone, Debug, PartialEq)]
pub enum MembershipCmd {
    /// Add `addr` to the pool with the given ring weight.
    Join { addr: String, weight: f64 },
    /// Remove `addr` from the pool.
    Leave { addr: String },
}

impl MembershipCmd {
    /// One plan-file line: `join ADDR WEIGHT` or `leave ADDR`.
    pub fn to_line(&self) -> String {
        match self {
            MembershipCmd::Join { addr, weight } => format!("join {addr} {weight}"),
            MembershipCmd::Leave { addr } => format!("leave {addr}"),
        }
    }

    /// Inverse of [`MembershipCmd::to_line`]; `None` on anything else.
    pub fn parse(line: &str) -> Option<MembershipCmd> {
        let mut it = line.split_ascii_whitespace();
        let cmd = match (it.next()?, it.next()) {
            ("join", Some(addr)) => {
                let weight = match it.next() {
                    Some(w) => w.parse().ok()?,
                    None => 1.0,
                };
                MembershipCmd::Join { addr: addr.to_string(), weight }
            }
            ("leave", Some(addr)) => MembershipCmd::Leave { addr: addr.to_string() },
            _ => return None,
        };
        if it.next().is_some() {
            return None;
        }
        Some(cmd)
    }

    /// The address this command is about.
    pub fn addr(&self) -> &str {
        match self {
            MembershipCmd::Join { addr, .. } | MembershipCmd::Leave { addr } => addr,
        }
    }
}

/// A membership transition that was applied to a live evaluator.
#[derive(Clone, Debug)]
pub struct MembershipEvent {
    /// Batch index the change was applied before (0-based).
    pub batch: usize,
    /// `"join"` or `"leave"`.
    pub action: &'static str,
    pub addr: String,
    /// Pool size after the change.
    pub hosts: usize,
    /// Warm-cache entries handed off to the joining host (0 on leave,
    /// or when no warm source / no binary wire was available).
    pub handed_off: usize,
    /// Why something was skipped or degraded; empty on a clean apply.
    pub detail: String,
}

impl MembershipEvent {
    /// The human-readable transition line (printed by the evaluator,
    /// grepped by the CI churn-smoke job).
    pub fn line(&self) -> String {
        let detail = if self.detail.is_empty() {
            String::new()
        } else {
            format!("; {}", self.detail)
        };
        format!(
            "cluster membership: {} {} ({} hosts, {} entries handed off{})",
            self.action, self.addr, self.hosts, self.handed_off, detail
        )
    }
}

/// Shared, cloneable log of applied membership events. The evaluator
/// appends; the metrics sink (and anyone else holding a clone) reads
/// incrementally via [`MembershipLog::since`].
#[derive(Clone, Default)]
pub struct MembershipLog {
    events: Arc<Mutex<Vec<MembershipEvent>>>,
}

impl MembershipLog {
    pub fn push(&self, event: MembershipEvent) {
        self.events.lock().expect("membership log poisoned").push(event);
    }

    /// Events `from..` plus the new cursor (pass the cursor back next
    /// call for an incremental drain without consuming the log).
    pub fn since(&self, from: usize) -> (Vec<MembershipEvent>, usize) {
        let events = self.events.lock().expect("membership log poisoned");
        (events[from.min(events.len())..].to_vec(), events.len())
    }

    pub fn len(&self) -> usize {
        self.events.lock().expect("membership log poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The warm-inventory source a joining host's handoff slice is carved
/// from: a shared slot filled *after* the evaluator is boxed into a
/// broker (the closure captures an [`crate::search::EvalBroker`]
/// clone; [`crate::search::EvalBroker::warm_entries`] takes only the
/// state lock, which is free while the broker's backend — this
/// evaluator — is checked out and dispatching, so there is no
/// deadlock). An unset slot just means joins start cold.
#[derive(Clone, Default)]
pub struct WarmSource {
    #[allow(clippy::type_complexity)]
    source: Arc<Mutex<Option<Box<dyn Fn() -> Vec<(Vec<usize>, EvalResult)> + Send>>>>,
}

impl WarmSource {
    pub fn set(&self, f: impl Fn() -> Vec<(Vec<usize>, EvalResult)> + Send + 'static) {
        *self.source.lock().expect("warm source poisoned") = Some(Box::new(f));
    }

    /// The current warm inventory; `None` when no source was attached.
    pub fn entries(&self) -> Option<Vec<(Vec<usize>, EvalResult)>> {
        self.source.lock().expect("warm source poisoned").as_ref().map(|f| f())
    }
}

/// The plan file the admin commands append to and a live evaluator
/// polls.
pub fn plan_path(dir: &Path) -> PathBuf {
    dir.join("membership.plan")
}

/// Append one command to `dir`'s plan file (creating both as needed) —
/// the `nahas cluster join|leave` admin path. The line lands as one
/// `O_APPEND` write, so a concurrent reader sees whole lines only.
pub fn append_cmd(dir: &Path, cmd: &MembershipCmd) -> Result<()> {
    fs::create_dir_all(dir)?;
    let path = plan_path(dir);
    let mut f = OpenOptions::new().create(true).append(true).open(&path)?;
    f.write_all(format!("{}\n", cmd.to_line()).as_bytes())?;
    Ok(())
}

/// Number of complete (newline-terminated) lines currently in `dir`'s
/// plan — the cursor a fresh evaluator starts at, so it never replays
/// commands that predate it.
pub fn plan_len(dir: &Path) -> usize {
    fs::read_to_string(plan_path(dir))
        .map(|c| c.bytes().filter(|&b| b == b'\n').count())
        .unwrap_or(0)
}

/// Read plan commands starting at (0-based) line `from`, returning
/// them plus the new cursor. Only newline-terminated lines are
/// consumed — a torn final line stays pending for the next poll —
/// and unparseable complete lines are warned about and skipped.
pub fn read_plan(dir: &Path, from: usize) -> (Vec<MembershipCmd>, usize) {
    let Ok(content) = fs::read_to_string(plan_path(dir)) else {
        return (Vec::new(), from);
    };
    let complete = match content.rfind('\n') {
        Some(i) => &content[..=i],
        None => return (Vec::new(), from),
    };
    let mut cmds = Vec::new();
    let mut cursor = 0usize;
    for (i, line) in complete.lines().enumerate() {
        cursor = i + 1;
        if i < from || line.trim().is_empty() {
            continue;
        }
        match MembershipCmd::parse(line) {
            Some(cmd) => cmds.push(cmd),
            None => eprintln!("cluster membership: ignoring bad plan line {}: '{line}'", i + 1),
        }
    }
    (cmds, cursor.max(from))
}

/// Carve the joining host's slice out of a warm inventory: exactly
/// the entries whose owner on the *post-join* ring is `join_index`
/// (everything else stays put — the moves-only-changed-host
/// invariant), valid and finite only, re-encoded as serve-cache
/// entries (serve key + response line) ready for [`send_handoff`].
///
/// Bit-identity of the replay: both wire protocols derive the
/// client-visible f64s by parsing the cached response text, Rust's
/// f64 `Display` is shortest-round-trip (`parse(format(x)) == x`),
/// and the accuracy half is always computed client-side — so a
/// synthesized line answers exactly like the line the host would have
/// cached by simulating. `utilization` is omitted: no client reads it
/// and the broker result does not carry it. Invalid results are
/// skipped because their response lines carry backend-specific error
/// strings this side cannot know; the joining host re-derives them
/// deterministically on first contact.
pub fn handoff_slice(
    entries: &[(Vec<usize>, EvalResult)],
    ring_after: &HashRing,
    join_index: usize,
    space: NasSpaceId,
    seg: bool,
    nas_len: usize,
    key_len: usize,
) -> Vec<(Vec<usize>, String)> {
    let mut out = Vec::new();
    for (key, r) in entries {
        if !r.valid
            || key.len() != key_len
            || ![r.latency_ms, r.energy_mj, r.area_mm2].iter().all(|v| v.is_finite())
        {
            continue;
        }
        if ring_after.owner(key) != Some(join_index) {
            continue;
        }
        out.push((serve_key(key, space, seg, nas_len), serve_line(r)));
    }
    out
}

/// The serve-cache key of a joint decision key, exactly as the server
/// derives it from a simulate request: `[space, seg, nas_len, nas...,
/// hw...]`.
fn serve_key(joint: &[usize], space: NasSpaceId, seg: bool, nas_len: usize) -> Vec<usize> {
    let mut key = Vec::with_capacity(3 + joint.len());
    key.push(space as usize);
    key.push(seg as usize);
    key.push(nas_len);
    key.extend_from_slice(joint);
    key
}

/// The response line the owning server would serve for this result.
fn serve_line(r: &EvalResult) -> String {
    obj(vec![
        ("valid", true.into()),
        ("latency_ms", r.latency_ms.into()),
        ("energy_mj", r.energy_mj.into()),
        ("area_mm2", r.area_mm2.into()),
    ])
    .to_string()
}

/// Stream a handoff slice to `addr`: the serve fingerprint plus the
/// slice as checksummed [`store::encode_handoff`] segments, one
/// `CACHE_INSTALL` frame over the binary wire. Returns how many
/// entries the host installed. A JSON-only peer (predates the
/// protocol) is an error — the caller records it and the host simply
/// starts cold; correctness never depends on a handoff landing.
pub fn send_handoff(
    addr: &str,
    io_timeout: Duration,
    entries: &[(Vec<usize>, String)],
) -> Result<usize> {
    if entries.is_empty() {
        return Ok(0);
    }
    let mut client = Client::connect_wire(addr, Some(io_timeout), Wire::Binary)?;
    if !client.is_binary() {
        return Err(anyhow!("host speaks JSON only (predates the handoff protocol)"));
    }
    let segments = store::encode_handoff(entries);
    client.install_cache(&store::serve_fingerprint(), &segments)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_lines_roundtrip() {
        for cmd in [
            MembershipCmd::Join { addr: "10.0.0.9:7878".into(), weight: 2.5 },
            MembershipCmd::Join { addr: "h:1".into(), weight: 1.0 },
            MembershipCmd::Leave { addr: "10.0.0.9:7878".into() },
        ] {
            assert_eq!(MembershipCmd::parse(&cmd.to_line()), Some(cmd));
        }
        assert_eq!(
            MembershipCmd::parse("join h:1"),
            Some(MembershipCmd::Join { addr: "h:1".into(), weight: 1.0 })
        );
        for bad in ["", "join", "leave", "join h:1 x", "leave h:1 extra", "restart h:1"] {
            assert_eq!(MembershipCmd::parse(bad), None, "'{bad}' parsed");
        }
    }

    #[test]
    fn plan_file_appends_and_reads_incrementally() {
        let dir = std::env::temp_dir()
            .join(format!("nahas-membership-plan-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let (cmds, cursor) = read_plan(&dir, 0);
        assert!(cmds.is_empty());
        assert_eq!(cursor, 0);
        let join = MembershipCmd::Join { addr: "h:1".into(), weight: 1.0 };
        let leave = MembershipCmd::Leave { addr: "h:2".into() };
        append_cmd(&dir, &join).unwrap();
        let (cmds, cursor) = read_plan(&dir, 0);
        assert_eq!(cmds, vec![join]);
        assert_eq!(cursor, 1);
        append_cmd(&dir, &leave).unwrap();
        let (cmds, cursor) = read_plan(&dir, cursor);
        assert_eq!(cmds, vec![leave]);
        assert_eq!(cursor, 2);
        // Nothing new: the cursor holds.
        let (cmds, cursor) = read_plan(&dir, cursor);
        assert!(cmds.is_empty());
        assert_eq!(cursor, 2);
        // A torn final line (no newline yet) stays pending.
        let mut f =
            OpenOptions::new().append(true).open(plan_path(&dir)).unwrap();
        f.write_all(b"join h:3").unwrap();
        drop(f);
        let (cmds, cursor) = read_plan(&dir, cursor);
        assert!(cmds.is_empty(), "torn line must not be consumed");
        assert_eq!(cursor, 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn membership_log_drains_incrementally() {
        let log = MembershipLog::default();
        assert!(log.is_empty());
        let ev = |a: &str| MembershipEvent {
            batch: 0,
            action: "join",
            addr: a.to_string(),
            hosts: 2,
            handed_off: 0,
            detail: String::new(),
        };
        log.push(ev("h:1"));
        let (events, cursor) = log.since(0);
        assert_eq!(events.len(), 1);
        log.push(ev("h:2"));
        let (events, cursor) = log.since(cursor);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].addr, "h:2");
        assert_eq!(log.since(cursor).0.len(), 0);
    }

    #[test]
    fn event_line_is_the_grep_target() {
        let line = MembershipEvent {
            batch: 3,
            action: "join",
            addr: "10.0.0.4:7878".to_string(),
            hosts: 3,
            handed_off: 42,
            detail: String::new(),
        }
        .line();
        assert_eq!(line, "cluster membership: join 10.0.0.4:7878 (3 hosts, 42 entries handed off)");
    }
}
