//! One binary codec, two transports: the length-prefixed frame and
//! checksummed-segment primitives shared by the service wire protocol
//! (`service::Client`/`Conn` binary mode) and the persistent store
//! (`search::store` `nahas-cache v2` segments, `search::sweep`
//! checkpoints).
//!
//! Everything here is defensive by construction: decoders never panic
//! on hostile bytes — they return `None`/`Err` and let the caller
//! degrade (cold start, JSON fallback, salvage the verified prefix).
//! f64 values always travel as raw `to_bits` u64s so NaN payloads,
//! infinities and signed zeros roundtrip bit-exactly; that is what
//! makes "binary is bit-identical to JSON" a structural property
//! rather than a numerical accident.
//!
//! Layouts (all integers little-endian):
//!
//! * **Wire frame** (`frame_*`): `[u32 payload_len][payload]` where
//!   `payload[0]` is the frame kind byte. The length prefix covers the
//!   whole payload including the kind byte.
//! * **Store segment** (`write_segment`/`read_segments`):
//!   `[u8 0xC5][u8 flags][u32 payload_len][u32 entry_count]
//!   [u64 fnv1a(payload)][payload]`, flag bit 0 = payload is
//!   block-compressed ([`compress`]). The `(offset, entries)` pairs a
//!   reader accumulates form the explicit `Pos`-style segment index —
//!   the checkpoint state resumable readers seek by.

/// Maximum segment payload accepted by [`read_segments`] (64 MiB) —
/// a corrupt length prefix must not drive a multi-gigabyte allocation.
const MAX_SEGMENT_PAYLOAD: usize = 64 << 20;

/// Maximum wire-frame payload accepted by [`frame_payload`] (16 MiB).
pub const MAX_FRAME_PAYLOAD: usize = 16 << 20;

/// First byte of every store segment block.
pub const SEG_MAGIC: u8 = 0xC5;

/// Segment flag bit 0: payload is [`compress`]ed.
pub const SEG_FLAG_COMPRESSED: u8 = 0b0000_0001;

/// Fixed bytes of a segment header preceding the payload.
pub const SEG_HEADER_LEN: usize = 1 + 1 + 4 + 4 + 8;

// ---------------------------------------------------------------------------
// Primitive writers
// ---------------------------------------------------------------------------

/// Append a LEB128-style varint (7 bits per byte, high bit = more).
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Append a little-endian u32.
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a little-endian u64.
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append an f64 as its raw little-endian bit pattern (NaN-preserving).
pub fn put_f64_bits(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

/// Append a length-prefixed UTF-8 string (varint byte length + bytes).
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

/// Append a length-prefixed `usize` slice (varint count + varint elems).
pub fn put_usize_slice(out: &mut Vec<u8>, v: &[usize]) {
    put_varint(out, v.len() as u64);
    for &x in v {
        put_varint(out, x as u64);
    }
}

// ---------------------------------------------------------------------------
// ByteReader — bounds-checked sequential decoder
// ---------------------------------------------------------------------------

/// Bounds-checked cursor over a byte slice. Every accessor returns
/// `None` past the end instead of panicking, so truncated or hostile
/// input degrades into a decode failure the caller can translate
/// (cold start, protocol error, salvage).
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True when every byte has been consumed — decoders use this to
    /// reject trailing garbage.
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    pub fn u8(&mut self) -> Option<u8> {
        let b = *self.buf.get(self.pos)?;
        self.pos += 1;
        Some(b)
    }

    pub fn u32(&mut self) -> Option<u32> {
        let bytes = self.take(4)?;
        Some(u32::from_le_bytes(bytes.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Option<u64> {
        let bytes = self.take(8)?;
        Some(u64::from_le_bytes(bytes.try_into().unwrap()))
    }

    pub fn f64_bits(&mut self) -> Option<f64> {
        self.u64().map(f64::from_bits)
    }

    /// LEB128 varint; rejects encodings longer than 10 bytes (which
    /// could not have been produced by [`put_varint`]).
    pub fn varint(&mut self) -> Option<u64> {
        let mut v: u64 = 0;
        for shift in (0..64).step_by(7) {
            let b = self.u8()?;
            v |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                return Some(v);
            }
        }
        None
    }

    /// Varint narrowed to usize (decode fails on overflow).
    pub fn varint_usize(&mut self) -> Option<usize> {
        usize::try_from(self.varint()?).ok()
    }

    /// Consume `n` raw bytes.
    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Some(s)
    }

    /// Length-prefixed UTF-8 string ([`put_str`] inverse).
    pub fn str(&mut self) -> Option<String> {
        let n = self.varint_usize()?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).ok()
    }

    /// Length-prefixed `usize` slice ([`put_usize_slice`] inverse).
    /// The count is clamped against the remaining bytes before
    /// allocating, so a corrupt length cannot force a huge allocation.
    pub fn usize_slice(&mut self) -> Option<Vec<usize>> {
        let n = self.varint_usize()?;
        if n > self.remaining() {
            return None; // each element takes >= 1 byte
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.varint_usize()?);
        }
        Some(v)
    }
}

// ---------------------------------------------------------------------------
// FNV-1a checksum
// ---------------------------------------------------------------------------

/// 64-bit FNV-1a over `bytes` — the segment payload checksum. Not
/// cryptographic; it only needs to catch truncation, bit rot and torn
/// writes, and to be dependency-free.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Wire frames
// ---------------------------------------------------------------------------

/// Prefix `payload` with its u32 length (the wire frame envelope).
pub fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + payload.len());
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(payload);
    out
}

/// Try to split one complete frame off the front of `buf`. Returns
/// `Ok(None)` when more bytes are needed, `Ok(Some((payload, total)))`
/// with the payload slice and the total frame size consumed, or
/// `Err(reason)` when the prefix itself is invalid (oversized length,
/// zero-length payload) and the connection should be dropped.
pub fn frame_payload(buf: &[u8]) -> Result<Option<(&[u8], usize)>, String> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if len == 0 {
        return Err("zero-length frame".to_string());
    }
    if len > MAX_FRAME_PAYLOAD {
        return Err(format!("oversized frame ({len} bytes)"));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some((&buf[4..4 + len], 4 + len)))
}

// ---------------------------------------------------------------------------
// Block compression (LZSS, dependency-free)
// ---------------------------------------------------------------------------

/// Minimum match length the compressor emits (shorter matches cost
/// more than the literals they replace).
const MIN_MATCH: usize = 4;
/// Maximum match length one token can carry: 0x80..=0xFF encode
/// lengths MIN_MATCH..=MIN_MATCH+127.
const MAX_MATCH: usize = MIN_MATCH + 127;
/// Match window (u16 offset, 0 is invalid).
const MAX_OFFSET: usize = u16::MAX as usize;

/// Block-compress `data` with a greedy LZSS coder: token bytes
/// `0x00..=0x7F` mean "copy the next `token+1` literal bytes"; tokens
/// with the high bit set mean "copy `(token & 0x7F) + MIN_MATCH`
/// bytes from `offset` (the following little-endian u16) back". Cold
/// store segments are highly self-similar (repeated key prefixes), so
/// even this dependency-free coder cuts them substantially.
pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    // Hash table of the most recent position of each 4-byte prefix.
    const HASH_BITS: u32 = 15;
    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let hash = |w: &[u8]| -> usize {
        let v = u32::from_le_bytes([w[0], w[1], w[2], w[3]]);
        (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
    };
    let mut lit_start = 0;
    let mut i = 0;
    let flush_literals = |out: &mut Vec<u8>, from: usize, to: usize| {
        let mut p = from;
        while p < to {
            let run = (to - p).min(128);
            out.push((run - 1) as u8);
            out.extend_from_slice(&data[p..p + run]);
            p += run;
        }
    };
    while i + MIN_MATCH <= data.len() {
        let h = hash(&data[i..i + 4]);
        let cand = table[h];
        table[h] = i;
        let mut matched = 0;
        if cand != usize::MAX && i - cand <= MAX_OFFSET && data[cand..cand + 4] == data[i..i + 4]
        {
            matched = 4;
            let limit = (data.len() - i).min(MAX_MATCH);
            while matched < limit && data[cand + matched] == data[i + matched] {
                matched += 1;
            }
        }
        if matched >= MIN_MATCH {
            flush_literals(&mut out, lit_start, i);
            out.push(0x80 | (matched - MIN_MATCH) as u8);
            out.extend_from_slice(&((i - cand) as u16).to_le_bytes());
            // Seed the table through the match so later repeats of its
            // interior still find a candidate.
            let end = (i + matched).min(data.len().saturating_sub(MIN_MATCH - 1));
            let mut j = i + 1;
            while j < end {
                table[hash(&data[j..j + 4])] = j;
                j += 1;
            }
            i += matched;
            lit_start = i;
        } else {
            i += 1;
        }
    }
    flush_literals(&mut out, lit_start, data.len());
    out
}

/// Inverse of [`compress`]. Returns `None` on any malformed token
/// (offset before the start of the output, truncated literal run or
/// offset bytes) — corrupt compressed payloads degrade, never panic.
pub fn decompress(data: &[u8]) -> Option<Vec<u8>> {
    let mut out = Vec::with_capacity(data.len() * 2);
    let mut r = ByteReader::new(data);
    while let Some(tok) = r.u8() {
        if tok & 0x80 == 0 {
            let run = usize::from(tok) + 1;
            out.extend_from_slice(r.take(run)?);
        } else {
            let len = usize::from(tok & 0x7f) + MIN_MATCH;
            let off_bytes = r.take(2)?;
            let off = usize::from(u16::from_le_bytes(off_bytes.try_into().unwrap()));
            if off == 0 || off > out.len() {
                return None;
            }
            let start = out.len() - off;
            for k in 0..len {
                // Byte-at-a-time: matches may overlap their own output
                // (RLE-style back-references with offset < len).
                let b = out[start + k];
                out.push(b);
            }
        }
    }
    Some(out)
}

// ---------------------------------------------------------------------------
// Store segments
// ---------------------------------------------------------------------------

/// One segment's position in a file — the explicit `Pos`-style index
/// entry a resumable reader seeks by.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SegPos {
    /// Byte offset of the segment header within the segment stream.
    pub offset: usize,
    /// Entries the segment claims to carry.
    pub entries: usize,
    /// Whether the payload was block-compressed.
    pub compressed: bool,
}

/// How [`read_segments`] treats a defective tail.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadPolicy {
    /// Any defect anywhere fails the whole read (the eval cache: a
    /// damaged file degrades to a cold start, all-or-nothing).
    Strict,
    /// A defective or torn trailing segment is dropped and the
    /// verified prefix returned (sweep checkpoints: a kill mid-write
    /// must not discard the scenarios already completed).
    Salvage,
}

/// A decoded segment: its payload (decompressed if needed), claimed
/// entry count, and position index entry.
pub struct Segment {
    pub payload: Vec<u8>,
    pub entries: usize,
    pub pos: SegPos,
}

/// Append one segment block (header + checksummed payload) to `out`.
/// `compress_payload` block-compresses the payload first (cold
/// segments); appends of fresh single entries stay uncompressed so a
/// crash tears at most the final partial block.
pub fn write_segment(out: &mut Vec<u8>, payload: &[u8], entries: usize, compress_payload: bool) {
    let stored: std::borrow::Cow<[u8]> =
        if compress_payload { compress(payload).into() } else { payload.into() };
    out.push(SEG_MAGIC);
    out.push(if compress_payload { SEG_FLAG_COMPRESSED } else { 0 });
    put_u32(out, stored.len() as u32);
    put_u32(out, entries as u32);
    put_u64(out, fnv1a64(&stored));
    out.extend_from_slice(&stored);
}

/// Parse a stream of segment blocks. `Strict` returns `Err(reason)`
/// on the first defect; `Salvage` stops at the first defect and
/// returns the verified prefix. Either way every returned segment has
/// a verified checksum and (when compressed) a valid decompression.
pub fn read_segments(bytes: &[u8], policy: ReadPolicy) -> Result<Vec<Segment>, String> {
    let mut segs = Vec::new();
    let mut off = 0;
    while off < bytes.len() {
        match read_one_segment(&bytes[off..], off) {
            Ok(seg) => {
                off += SEG_HEADER_LEN + seg.pos_payload_len;
                segs.push(seg.segment);
            }
            Err(why) => {
                return match policy {
                    ReadPolicy::Strict => Err(format!("{why} at offset {off}")),
                    ReadPolicy::Salvage => Ok(segs),
                };
            }
        }
    }
    Ok(segs)
}

struct ReadSeg {
    segment: Segment,
    pos_payload_len: usize,
}

fn read_one_segment(bytes: &[u8], offset: usize) -> Result<ReadSeg, String> {
    let mut r = ByteReader::new(bytes);
    let magic = r.u8().ok_or("truncated segment header")?;
    if magic != SEG_MAGIC {
        return Err(format!("bad segment magic 0x{magic:02x}"));
    }
    let flags = r.u8().ok_or("truncated segment header")?;
    if flags & !SEG_FLAG_COMPRESSED != 0 {
        return Err(format!("unknown segment flags 0x{flags:02x}"));
    }
    let payload_len = r.u32().ok_or("truncated segment header")? as usize;
    if payload_len > MAX_SEGMENT_PAYLOAD {
        return Err(format!("oversized segment ({payload_len} bytes)"));
    }
    let entries = r.u32().ok_or("truncated segment header")? as usize;
    let checksum = r.u64().ok_or("truncated segment header")?;
    let stored = r.take(payload_len).ok_or("truncated segment payload")?;
    if fnv1a64(stored) != checksum {
        return Err("segment checksum mismatch".to_string());
    }
    let compressed = flags & SEG_FLAG_COMPRESSED != 0;
    let payload = if compressed {
        decompress(stored).ok_or("corrupt compressed segment payload")?
    } else {
        stored.to_vec()
    };
    Ok(ReadSeg {
        segment: Segment {
            payload,
            entries,
            pos: SegPos { offset, entries, compressed },
        },
        pos_payload_len: payload_len,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn varints_roundtrip_across_the_range() {
        let vals = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        for &v in &vals {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut r = ByteReader::new(&buf);
            assert_eq!(r.varint(), Some(v));
            assert!(r.is_empty());
        }
    }

    #[test]
    fn strings_and_slices_roundtrip() {
        let mut buf = Vec::new();
        put_str(&mut buf, "hello|world\nwith newline");
        put_usize_slice(&mut buf, &[0, 1, 300, usize::from(u16::MAX)]);
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.str().as_deref(), Some("hello|world\nwith newline"));
        assert_eq!(r.usize_slice(), Some(vec![0, 1, 300, usize::from(u16::MAX)]));
        assert!(r.is_empty());
    }

    #[test]
    fn nan_and_inf_f64s_roundtrip_bit_exactly() {
        let vals = [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.0, 1.5e-300];
        let mut buf = Vec::new();
        for &v in &vals {
            put_f64_bits(&mut buf, v);
        }
        let mut r = ByteReader::new(&buf);
        for &v in &vals {
            assert_eq!(r.f64_bits().map(f64::to_bits), Some(v.to_bits()));
        }
    }

    #[test]
    fn frames_split_cleanly_and_reject_bad_prefixes() {
        let f = frame(b"payload");
        assert_eq!(frame_payload(&f).unwrap(), Some((&b"payload"[..], f.len())));
        // Partial frame: need more bytes.
        assert_eq!(frame_payload(&f[..3]).unwrap(), None);
        assert_eq!(frame_payload(&f[..6]).unwrap(), None);
        // Hostile prefixes are errors, not allocations.
        assert!(frame_payload(&[0xff, 0xff, 0xff, 0x7f, 0]).is_err());
        assert!(frame_payload(&frame(b"")).is_err());
    }

    #[test]
    fn compression_roundtrips_and_shrinks_redundant_data() {
        let mut data = Vec::new();
        for i in 0..200u32 {
            data.extend_from_slice(format!("key-prefix/{}/value|", i % 7).as_bytes());
        }
        let packed = compress(&data);
        assert!(packed.len() < data.len(), "{} !< {}", packed.len(), data.len());
        assert_eq!(decompress(&packed).as_deref(), Some(&data[..]));
        // Incompressible and empty inputs still roundtrip.
        let mut rng = Rng::new(42);
        let noise: Vec<u8> = (0..1000).map(|_| (rng.next_u64() & 0xff) as u8).collect();
        assert_eq!(decompress(&compress(&noise)).as_deref(), Some(&noise[..]));
        assert_eq!(decompress(&compress(&[])).as_deref(), Some(&[][..]));
    }

    #[test]
    fn decompress_rejects_bad_backrefs_without_panicking() {
        // Match token referencing before the start of output.
        assert_eq!(decompress(&[0x80, 0x05, 0x00]), None);
        // Zero offset.
        assert_eq!(decompress(&[0x00, b'a', 0x80, 0x00, 0x00]), None);
        // Truncated literal run.
        assert_eq!(decompress(&[0x05, b'a']), None);
        // Truncated offset.
        assert_eq!(decompress(&[0x00, b'a', 0x80]), None);
    }

    #[test]
    fn segments_roundtrip_and_carry_an_index() {
        let mut stream = Vec::new();
        write_segment(&mut stream, b"first payload first payload", 3, true);
        let second_at = stream.len();
        write_segment(&mut stream, b"second", 1, false);
        let segs = read_segments(&stream, ReadPolicy::Strict).unwrap();
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].payload, b"first payload first payload");
        assert_eq!(segs[0].entries, 3);
        assert_eq!(segs[0].pos, SegPos { offset: 0, entries: 3, compressed: true });
        assert_eq!(segs[1].payload, b"second");
        assert_eq!(segs[1].pos, SegPos { offset: second_at, entries: 1, compressed: false });
    }

    #[test]
    fn strict_fails_and_salvage_keeps_the_verified_prefix() {
        let mut stream = Vec::new();
        write_segment(&mut stream, b"complete", 1, false);
        let torn_from = stream.len();
        write_segment(&mut stream, b"will be torn", 1, false);
        let torn = &stream[..torn_from + SEG_HEADER_LEN + 3];
        assert!(read_segments(torn, ReadPolicy::Strict).is_err());
        let salvaged = read_segments(torn, ReadPolicy::Salvage).unwrap();
        assert_eq!(salvaged.len(), 1);
        assert_eq!(salvaged[0].payload, b"complete");
        // Flipping a payload bit fails the checksum under both modes.
        let mut flipped = stream.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x40;
        assert!(read_segments(&flipped, ReadPolicy::Strict).is_err());
        assert_eq!(read_segments(&flipped, ReadPolicy::Salvage).unwrap().len(), 1);
    }
}
