//! Deterministic, seedable PRNG (xoshiro256++).
//!
//! Every stochastic component in the coordinator (controllers, data
//! generation, surrogate noise) takes an explicit `Rng` so whole searches
//! replay bit-for-bit from a seed — a requirement for the paper-figure
//! benches to be reproducible run-to-run.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 expansion (the canonical seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for parallel workers / sub-tasks).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.f64() * n as f64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut u = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_range_uniformly() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 1.0])] += 1;
        }
        assert!(counts[1] > counts[0] && counts[1] > counts[2], "{counts:?}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(5);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
