//! Lightweight property-testing harness (offline build: the `proptest`
//! crate is not vendored, so coordinator invariants are checked with this
//! seeded-random driver instead — same spirit: many random cases, a
//! deterministic failure seed printed on the first counterexample).

use super::rng::Rng;

/// Default number of random cases per property.
pub const CASES: usize = 256;

/// Run `prop` on `cases` seeded random inputs produced by `gen`.
/// On failure, panics with the reproducing seed and a debug dump.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = 0x5EED_0000u64 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (seed {seed:#x}, case {case}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("abs-nonneg", 64, |r| r.normal(), |x| {
            if x.abs() >= 0.0 {
                Ok(())
            } else {
                Err("negative abs".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_counterexample() {
        check("always-fails", 4, |r| r.below(10), |_| Err("nope".into()));
    }
}
