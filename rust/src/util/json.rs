//! Minimal JSON parser + writer (offline build: serde is not vendored).
//!
//! Handles the machine-generated subset we exchange: `artifacts/
//! manifest.json` from aot.py and the simulator-service wire protocol.
//! Strings support `\"`, `\\`, `\n`, `\t`, `\r` and `\uXXXX` escapes;
//! numbers parse through `f64`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact single-line serialization (the wire format; `to_string`
/// comes with it through the blanket `ToString`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

/// Convenience constructors for building response objects.
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 char verbatim.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"config": {"IMG": 8, "WIDTHS": [8, 16, 16, 32, 32]},
                      "programs": {"train": {"file": "t.hlo.txt",
                        "inputs": [{"name": "x", "dtype": "f32", "shape": [32, 8]}]}}}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("config").unwrap().get("IMG").unwrap().as_usize(), Some(8));
        let widths = j.get("config").unwrap().get("WIDTHS").unwrap().as_arr().unwrap();
        assert_eq!(widths.len(), 5);
        let inputs = j
            .get("programs")
            .unwrap()
            .get("train")
            .unwrap()
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(inputs[0].get("dtype").unwrap().as_str(), Some("f32"));
    }

    #[test]
    fn roundtrips_through_to_string() {
        let doc = r#"{"a":[1,2.5,-3],"b":"hi\nthere","c":true,"d":null}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\":1} trailing").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }
}
