//! Small self-contained utilities.
//!
//! The build environment is offline with only the `xla` dependency tree
//! vendored, so the PRNG, JSON handling and property-testing helpers that
//! would normally come from `rand` / `serde_json` / `proptest` live here.

pub mod codec;
pub mod json;
pub mod proptest;
pub mod rng;

pub use rng::Rng;
