//! Rust-side driver for the AOT cost-model MLP (paper Table 2 / Eq. 7).
//!
//! Training runs `costmodel_train` (Adam, batch 128, loss = MSE(area) +
//! 10 x MSE(latency), dropout 0.1 — all baked into the L2 graph, which
//! differentiates through the L1 pallas matmul). Inference runs the
//! fused-trunk kernel via `costmodel_infer_b256` / `_b1`.

use anyhow::Result;

use crate::costmodel::dataset::{CostSample, Normalizer};
use crate::costmodel::features::FEATURE_DIM;
use crate::runtime::{lit_f32, lit_i32_scalar, scalar_f32, to_vec_f32, Runtime};
use crate::util::Rng;

const BATCH: usize = 128;
const INFER_BATCH: usize = 256;

/// Trained cost model state (parameters live as PJRT literals).
pub struct CostModel {
    flat: xla::Literal,
    m: xla::Literal,
    v: xla::Literal,
    step: i32,
    pub norm: Normalizer,
}

impl CostModel {
    /// Fresh parameters + the dataset's normalizer.
    pub fn init(rt: &mut Runtime, norm: Normalizer, seed: i32) -> Result<Self> {
        let out = rt.run("costmodel_init", &[&lit_i32_scalar(seed)])?;
        let mut it = out.into_iter();
        Ok(CostModel {
            flat: it.next().unwrap(),
            m: it.next().unwrap(),
            v: it.next().unwrap(),
            step: 0,
            norm,
        })
    }

    /// Train for `steps` minibatches sampled from `data`; returns the
    /// per-step losses.
    pub fn train(
        &mut self,
        rt: &mut Runtime,
        data: &[CostSample],
        steps: usize,
        rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let mut losses = Vec::with_capacity(steps);
        let mut x = vec![0.0f32; BATCH * FEATURE_DIM];
        let mut ylat = vec![0.0f32; BATCH];
        let mut yarea = vec![0.0f32; BATCH];
        for _ in 0..steps {
            for i in 0..BATCH {
                let s = &data[rng.below(data.len())];
                x[i * FEATURE_DIM..(i + 1) * FEATURE_DIM].copy_from_slice(&s.features);
                ylat[i] = s.lat;
                yarea[i] = s.area;
            }
            let xb = lit_f32(&x, &[BATCH, FEATURE_DIM])?;
            let lb = lit_f32(&ylat, &[BATCH])?;
            let ab = lit_f32(&yarea, &[BATCH])?;
            let out = rt.run(
                "costmodel_train",
                &[
                    &self.flat,
                    &self.m,
                    &self.v,
                    &lit_i32_scalar(self.step),
                    &lit_i32_scalar(17),
                    &xb,
                    &lb,
                    &ab,
                ],
            )?;
            let mut it = out.into_iter();
            self.flat = it.next().unwrap();
            self.m = it.next().unwrap();
            self.v = it.next().unwrap();
            losses.push(scalar_f32(&it.next().unwrap())?);
            self.step += 1;
        }
        Ok(losses)
    }

    /// Predict (latency_ms, area_mm2) for a batch of feature vectors.
    pub fn predict(&mut self, rt: &mut Runtime, feats: &[Vec<f32>]) -> Result<Vec<(f64, f64)>> {
        let mut out = Vec::with_capacity(feats.len());
        for chunk in feats.chunks(INFER_BATCH) {
            let mut x = vec![0.0f32; INFER_BATCH * FEATURE_DIM];
            for (i, f) in chunk.iter().enumerate() {
                x[i * FEATURE_DIM..(i + 1) * FEATURE_DIM].copy_from_slice(f);
            }
            let xb = lit_f32(&x, &[INFER_BATCH, FEATURE_DIM])?;
            let res = rt.run("costmodel_infer_b256", &[&self.flat, &xb])?;
            let lat = to_vec_f32(&res[0])?;
            let area = to_vec_f32(&res[1])?;
            for i in 0..chunk.len() {
                out.push((self.norm.denorm_lat(lat[i]), self.norm.denorm_area(area[i])));
            }
        }
        Ok(out)
    }

    /// Single-sample prediction through the b1 artifact (request-path
    /// latency benchmarking).
    pub fn predict_one(&mut self, rt: &mut Runtime, feat: &[f32]) -> Result<(f64, f64)> {
        let xb = lit_f32(feat, &[1, FEATURE_DIM])?;
        let res = rt.run("costmodel_infer_b1", &[&self.flat, &xb])?;
        let lat = to_vec_f32(&res[0])?[0];
        let area = to_vec_f32(&res[1])?[0];
        Ok((self.norm.denorm_lat(lat), self.norm.denorm_area(area)))
    }
}

/// Mean relative error + Pearson correlation of predictions vs
/// simulator ground truth (the paper's Fig. 6 quality metrics).
pub fn accuracy_metrics(pred: &[(f64, f64)], truth: &[&CostSample]) -> (f64, f64) {
    let n = pred.len() as f64;
    let rel: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p.0 - t.latency_ms).abs() / t.latency_ms.max(1e-9))
        .sum::<f64>()
        / n;
    let mx = pred.iter().map(|p| p.0).sum::<f64>() / n;
    let my = truth.iter().map(|t| t.latency_ms).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (p, t) in pred.iter().zip(truth) {
        cov += (p.0 - mx) * (t.latency_ms - my);
        vx += (p.0 - mx) * (p.0 - mx);
        vy += (t.latency_ms - my) * (t.latency_ms - my);
    }
    (rel, cov / (vx.sqrt() * vy.sqrt()).max(1e-12))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_metrics_perfect_prediction() {
        let truth: Vec<CostSample> = (1..=4)
            .map(|i| CostSample {
                features: vec![],
                lat: 0.0,
                area: 0.0,
                latency_ms: i as f64 * 0.1,
                area_mm2: 80.0,
            })
            .collect();
        let refs: Vec<&CostSample> = truth.iter().collect();
        let pred: Vec<(f64, f64)> = truth.iter().map(|t| (t.latency_ms, 80.0)).collect();
        let (rel, corr) = accuracy_metrics(&pred, &refs);
        assert!(rel < 1e-12);
        assert!((corr - 1.0).abs() < 1e-9);
    }
}
