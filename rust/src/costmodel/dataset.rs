//! Simulator-labelled training data for the cost model.
//!
//! The paper trains on 500k randomly generated (alpha, h) permutations
//! labelled by the performance simulator — "the collection of data can
//! utilize the vast amount of CPU resources, we do not consider the cost
//! of training a cost model". Our generator does the same against the
//! rust simulator (invalid points are skipped, as the paper trains on
//! simulable samples only) and z-scores log-latency / log-area targets.

use crate::accel::simulate_network;
use crate::costmodel::features::{featurize, FEATURE_DIM};
use crate::has::{validate, HasSpace};
use crate::nas::NasSpace;
use crate::util::Rng;

/// One labelled sample.
#[derive(Clone, Debug)]
pub struct CostSample {
    pub features: Vec<f32>,
    /// Normalized targets (see [`Normalizer`]).
    pub lat: f32,
    pub area: f32,
    /// Raw (un-normalized) values.
    pub latency_ms: f64,
    pub area_mm2: f64,
}

/// z-score normalization of log10 targets.
#[derive(Clone, Copy, Debug)]
pub struct Normalizer {
    pub lat_mean: f64,
    pub lat_std: f64,
    pub area_mean: f64,
    pub area_std: f64,
}

impl Normalizer {
    pub fn fit(lat_log: &[f64], area_log: &[f64]) -> Self {
        let stats = |v: &[f64]| {
            let m = v.iter().sum::<f64>() / v.len() as f64;
            let s = (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt();
            (m, s.max(1e-6))
        };
        let (lm, ls) = stats(lat_log);
        let (am, as_) = stats(area_log);
        Normalizer { lat_mean: lm, lat_std: ls, area_mean: am, area_std: as_ }
    }

    pub fn norm_lat(&self, latency_ms: f64) -> f32 {
        ((latency_ms.max(1e-9).log10() - self.lat_mean) / self.lat_std) as f32
    }

    pub fn denorm_lat(&self, z: f32) -> f64 {
        10f64.powf(z as f64 * self.lat_std + self.lat_mean)
    }

    pub fn norm_area(&self, area_mm2: f64) -> f32 {
        ((area_mm2.max(1e-9).log10() - self.area_mean) / self.area_std) as f32
    }

    pub fn denorm_area(&self, z: f32) -> f64 {
        10f64.powf(z as f64 * self.area_std + self.area_mean)
    }
}

/// Generate `n` valid labelled samples (plus the fitted normalizer).
pub fn generate_dataset(
    space: &NasSpace,
    n: usize,
    rng: &mut Rng,
) -> (Vec<CostSample>, Normalizer) {
    let has = HasSpace::new();
    let mut raw = Vec::with_capacity(n);
    let mut attempts = 0usize;
    while raw.len() < n && attempts < n * 20 {
        attempts += 1;
        let nas_d = space.random(rng);
        let has_d = has.random(rng);
        let cfg = has.decode(&has_d);
        if validate(&cfg).is_err() {
            continue;
        }
        let net = space.decode(&nas_d);
        let Ok(rep) = simulate_network(&cfg, &net) else { continue };
        let mut features = vec![0.0f32; FEATURE_DIM];
        featurize(space, &nas_d, &has_d, &mut features);
        raw.push((features, rep.latency_ms, rep.area_mm2));
    }
    let lat_log: Vec<f64> = raw.iter().map(|r| r.1.log10()).collect();
    let area_log: Vec<f64> = raw.iter().map(|r| r.2.log10()).collect();
    let norm = Normalizer::fit(&lat_log, &area_log);
    let samples = raw
        .into_iter()
        .map(|(features, lat, area)| CostSample {
            lat: norm.norm_lat(lat),
            area: norm.norm_area(area),
            latency_ms: lat,
            area_mm2: area,
            features,
        })
        .collect();
    (samples, norm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::NasSpaceId;

    #[test]
    fn generates_requested_count() {
        let sp = NasSpace::new(NasSpaceId::EfficientNet);
        let (data, _) = generate_dataset(&sp, 64, &mut Rng::new(3));
        assert_eq!(data.len(), 64);
        for s in &data {
            assert_eq!(s.features.len(), FEATURE_DIM);
            assert!(s.latency_ms > 0.0 && s.area_mm2 > 0.0);
        }
    }

    #[test]
    fn normalizer_roundtrips() {
        let n = Normalizer { lat_mean: -0.5, lat_std: 0.3, area_mean: 1.9, area_std: 0.2 };
        for v in [0.05, 0.3, 1.3, 4.0] {
            assert!((n.denorm_lat(n.norm_lat(v)) - v).abs() / v < 1e-4);
        }
        assert!((n.denorm_area(n.norm_area(80.0)) - 80.0).abs() < 0.01);
    }

    #[test]
    fn targets_zscored() {
        let sp = NasSpace::new(NasSpaceId::Evolved);
        let (data, _) = generate_dataset(&sp, 128, &mut Rng::new(4));
        let mean: f32 = data.iter().map(|s| s.lat).sum::<f32>() / data.len() as f32;
        let var: f32 =
            data.iter().map(|s| (s.lat - mean) * (s.lat - mean)).sum::<f32>() / data.len() as f32;
        assert!(mean.abs() < 0.15, "mean {mean}");
        assert!((var - 1.0).abs() < 0.3, "var {var}");
    }

    #[test]
    fn latency_spread_is_wide() {
        // The HAS x NAS joint space must produce a broad latency range —
        // otherwise the cost model has nothing to learn.
        let sp = NasSpace::new(NasSpaceId::EfficientNet);
        let (data, _) = generate_dataset(&sp, 128, &mut Rng::new(5));
        let min = data.iter().map(|s| s.latency_ms).fold(f64::MAX, f64::min);
        let max = data.iter().map(|s| s.latency_ms).fold(0.0f64, f64::max);
        assert!(max / min > 5.0, "latency spread {min}..{max}");
    }
}
