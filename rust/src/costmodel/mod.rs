//! The learned latency/area cost model (paper §3.5.2, Table 2, Fig. 6).
//!
//! * [`features`] — the 394-dim joint (alpha, h) encoding;
//! * [`dataset`] — simulator-labelled sample generation ("labelled data
//!   for accelerator performance is much cheaper than NAS accuracy");
//! * [`host`] — rust-side training/inference driver over the AOT MLP
//!   artifacts (`costmodel_train` / `costmodel_infer_*`), whose trunk is
//!   the L1 fused pallas kernel.

pub mod dataset;
pub mod features;
pub mod host;

pub use dataset::{generate_dataset, CostSample};
pub use features::{featurize, FEATURE_DIM};
pub use host::CostModel;
