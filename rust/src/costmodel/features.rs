//! 394-dim featurization of a joint (neural architecture, accelerator)
//! sample — the paper's "input feature size 394" (Table 2).
//!
//! Layout (fixed, padded with zeros):
//! * `[0, 280)`   — 20 block slots x 14 dims: one-hots for kernel(3),
//!   expansion(2), op(2), filter(4), groups(2) plus a presence bit;
//! * `[280, 313)` — HAS one-hots (5+5+4+4+5+5+5 = 33);
//! * `[313, 317)` — NAS-space id one-hot;
//! * `[317, 334)` — 17 scalar descriptors: log-MACs, log-params,
//!   log-weight-bytes, depth, input resolution, 8 per-stage MAC
//!   fractions, depthwise/fused MAC fractions, SE + Swish counts;
//! * `[334, 338)` — evolved-space global compound-scale one-hot;
//! * `[338, 394)` — zero padding (reserved).

use crate::model::{Layer, NetworkIr};
use crate::nas::{NasSpace, NasSpaceId};

pub const FEATURE_DIM: usize = 394;
const BLOCK_SLOTS: usize = 20;
const BLOCK_DIMS: usize = 14;
const HAS_OFF: usize = BLOCK_SLOTS * BLOCK_DIMS; // 280
const SPACE_OFF: usize = HAS_OFF + 33; // 313
const SCALAR_OFF: usize = SPACE_OFF + 4; // 317
const SCALE_OFF: usize = SCALAR_OFF + 17; // 334

/// Encode a joint sample. `nas_d` is indexed per the space's decision
/// layout; `has_d` per `has::HasSpace` (7 categorical decisions).
pub fn featurize(space: &NasSpace, nas_d: &[usize], has_d: &[usize], out: &mut [f32]) {
    assert_eq!(out.len(), FEATURE_DIM);
    out.fill(0.0);

    // Evolved-space global compound-scale decision precedes the blocks.
    let global = usize::from(space.id == NasSpaceId::Evolved);
    if global == 1 {
        out[SCALE_OFF + nas_d[0]] = 1.0;
    }
    // Per-block one-hots.
    let per_block = (nas_d.len() - global) / space.blocks.len();
    for (b, _) in space.blocks.iter().enumerate().take(BLOCK_SLOTS) {
        let base = b * BLOCK_DIMS;
        let d = &nas_d[global + b * per_block..global + (b + 1) * per_block];
        out[base + d[0]] = 1.0; // kernel (3)
        out[base + 3 + d[1]] = 1.0; // expansion (2)
        let (op, filt, groups) = match space.id {
            NasSpaceId::Evolved => (d[2], d[3], d[4]),
            NasSpaceId::Proxy => (d[2], d[3], 0),
            _ => (0, 2, 0),
        };
        out[base + 5 + op] = 1.0; // op (2)
        out[base + 7 + filt] = 1.0; // filter (4)
        out[base + 11 + groups] = 1.0; // groups (2)
        out[base + 13] = 1.0; // presence
    }

    // HAS one-hots.
    let cards = [5usize, 5, 4, 4, 5, 5, 5];
    let mut off = HAS_OFF;
    for (i, &c) in cards.iter().enumerate() {
        out[off + has_d[i]] = 1.0;
        off += c;
    }

    // Space id.
    let sid = match space.id {
        NasSpaceId::MobileNetV2 => 0,
        NasSpaceId::EfficientNet => 1,
        NasSpaceId::Evolved => 2,
        NasSpaceId::Proxy => 3,
    };
    out[SPACE_OFF + sid] = 1.0;

    // Scalars from the decoded IR.
    let net = space.decode(nas_d);
    write_scalars(&net, &mut out[SCALAR_OFF..SCALAR_OFF + 17]);
}

fn write_scalars(net: &NetworkIr, s: &mut [f32]) {
    let macs = net.total_macs() as f64;
    let params = net.total_params() as f64;
    s[0] = (macs.max(1.0)).log10() as f32 / 12.0;
    s[1] = (params.max(1.0)).log10() as f32 / 9.0;
    s[2] = ((params).max(1.0)).log10() as f32 / 9.0; // int8 weight bytes == params
    s[3] = net.layers.len() as f32 / 100.0;
    s[4] = net.input_h as f32 / 224.0;
    // Per-stage (8 equal layer buckets) MAC fractions.
    let nl = net.layers.len();
    for (i, l) in net.layers.iter().enumerate() {
        let bucket = (i * 8 / nl).min(7);
        s[5 + bucket] += (l.macs() as f64 / macs.max(1.0)) as f32;
    }
    let frac = |pred: &dyn Fn(&Layer) -> bool| -> f32 {
        (net.layers.iter().filter(|l| pred(&l.op)).map(|l| l.macs()).sum::<u64>() as f64
            / macs.max(1.0)) as f32
    };
    s[13] = frac(&|op| matches!(op, Layer::DwConv { .. }));
    s[14] = frac(&|op| matches!(op, Layer::Conv2d { kh, cin, .. } if *kh > 1 && *cin > 3));
    s[15] = net.layers.iter().filter(|l| matches!(l.op, Layer::SePool { .. })).count() as f32
        / 20.0;
    s[16] =
        net.layers.iter().filter(|l| matches!(l.op, Layer::Swish { .. })).count() as f32 / 40.0;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::has::HasSpace;
    use crate::util::proptest;
    use crate::util::Rng;

    fn feat(space: &NasSpace, nas_d: &[usize], has_d: &[usize]) -> Vec<f32> {
        let mut f = vec![0.0; FEATURE_DIM];
        featurize(space, nas_d, has_d, &mut f);
        f
    }

    #[test]
    fn paper_feature_dim() {
        assert_eq!(FEATURE_DIM, 394);
        assert!(SCALAR_OFF + 17 <= FEATURE_DIM);
    }

    #[test]
    fn onehots_sum_correctly() {
        let sp = NasSpace::new(NasSpaceId::Evolved);
        let hs = HasSpace::new();
        let mut rng = Rng::new(5);
        let f = feat(&sp, &sp.random(&mut rng), &hs.random(&mut rng));
        // 16 present blocks x (5 one-hots + presence) + 7 HAS + 1 space.
        let onehot_sum: f32 = f[..SPACE_OFF + 4].iter().sum();
        assert_eq!(onehot_sum, (16 * 6 + 7 + 1) as f32);
    }

    #[test]
    fn distinct_samples_get_distinct_features() {
        let sp = NasSpace::new(NasSpaceId::MobileNetV2);
        let hs = HasSpace::new();
        let mut rng = Rng::new(6);
        let a = (sp.random(&mut rng), hs.random(&mut rng));
        let b = (sp.random(&mut rng), hs.random(&mut rng));
        assert_ne!(feat(&sp, &a.0, &a.1), feat(&sp, &b.0, &b.1));
    }

    #[test]
    fn prop_features_bounded() {
        let sp = NasSpace::new(NasSpaceId::Evolved);
        let hs = HasSpace::new();
        proptest::check(
            "features in [0, 1.5]",
            128,
            |r| (sp.random(r), hs.random(r)),
            |(nd, hd)| {
                let f = feat(&sp, nd, hd);
                for (i, v) in f.iter().enumerate() {
                    if !v.is_finite() || *v < 0.0 || *v > 1.5 {
                        return Err(format!("f[{i}] = {v}"));
                    }
                }
                // Stage fractions sum to ~1.
                let stage_sum: f32 = f[SCALAR_OFF + 5..SCALAR_OFF + 13].iter().sum();
                if (stage_sum - 1.0).abs() > 1e-3 {
                    return Err(format!("stage fractions sum {stage_sum}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn hardware_changes_only_has_block() {
        let sp = NasSpace::new(NasSpaceId::EfficientNet);
        let hs = HasSpace::new();
        let mut rng = Rng::new(7);
        let nd = sp.random(&mut rng);
        let f1 = feat(&sp, &nd, &hs.baseline_decisions());
        let f2 = feat(&sp, &nd, &hs.random(&mut rng));
        assert_eq!(f1[..HAS_OFF], f2[..HAS_OFF]);
        assert_eq!(f1[SPACE_OFF..], f2[SPACE_OFF..]);
    }
}
